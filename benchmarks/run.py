"""Benchmark harness — one function per paper table/figure.

  * bench_strong_scaling   — paper fig. 6: N-body / RSim / WaveSim speedup
                             vs device count, ad-hoc baseline vs IDAG runtime
  * bench_overlap          — paper fig. 7: scheduler/executor overlap
  * bench_lookahead        — §4.3: resize elision (allocation counts + wall)
  * bench_executor_latency — §4.1: out-of-order engine issue latency
  * bench_reduction        — §2.2: distributed-reduction scaling over node
                             count and reduction size
  * bench_roofline         — §Roofline: three terms per (arch x shape) cell
                             from the dry-run artifacts

Output: ``name,us_per_call,derived`` CSV rows on stdout.

Run: PYTHONPATH=src python -m benchmarks.run [bench_name ...]
     [--json] [--trace out.json] [--dot prefix]

``--trace PATH`` exports the last traced run as a Chrome/Perfetto
trace-event file (fig.-7-style timeline, viewable at ui.perfetto.dev).
``--dot PREFIX`` writes Graphviz renders of a representative lowered
program as ``PREFIX.{tdag,cdag,idag}.dot`` (sanitizer findings, if any,
highlighted in the IDAG); with no bench names it exports and exits.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.core import (Box, Region, Runtime, all_range, fixed, neighborhood,
                        one_to_one, read, read_write, reduction,
                        write)  # noqa: E402

CSV: list[str] = []
TRACE_PATH: Path | None = None


def emit(name: str, us: float, derived: str = "") -> None:
    row = f"{name},{us:.1f},{derived}"
    CSV.append(row)
    print(row, flush=True)


def maybe_export_trace(tracer) -> None:
    """With ``--trace PATH``, write the tracer's span log as a Perfetto
    trace-event file (last traced run wins)."""
    if TRACE_PATH is not None and tracer is not None:
        n = tracer.to_chrome_trace(TRACE_PATH)
        print(f"# wrote {n} trace events to {TRACE_PATH}", file=sys.stderr)


# ---------------------------------------------------------------------------
# simulated-kernel applications (strong scaling is about RUNTIME overhead;
# kernel time is a deterministic sleep ∝ work/devices, as on a real cluster)

KERNEL_UNIT = 10e-6   # seconds of simulated compute per work unit


def _nbody_app(rt: Runtime, N: int, steps: int, devices: int) -> None:
    P = rt.buffer((N, 3), init=np.zeros((N, 3)), name="P")
    V = rt.buffer((N, 3), init=np.zeros((N, 3)), name="V")

    def timestep(chunk, p, v):
        n = chunk.max[0] - chunk.min[0]
        time.sleep(KERNEL_UNIT * n * N / 4096)     # O(N^2) / P
        v.set(chunk, v.get(chunk) + 1.0)

    def update(chunk, v, p):
        time.sleep(KERNEL_UNIT * (chunk.max[0] - chunk.min[0]) / 64)
        p.set(chunk, p.get(chunk) + v.get(chunk))

    for _ in range(steps):
        rt.submit("timestep", (N, 3),
                  [read(P, all_range()), read_write(V, one_to_one())],
                  timestep)
        rt.submit("update", (N, 3),
                  [read(V, one_to_one()), read_write(P, one_to_one())],
                  update)
    rt.sync(timeout=300)


def _rsim_app(rt: Runtime, T: int, W: int, devices: int) -> None:
    R = rt.buffer((T, W), init=np.zeros((T, W)), name="R")

    def row_cols(t):
        def rm(chunk, shape):
            return Region.from_box(
                Box((t, chunk.min[1]), (t + 1, chunk.max[1])))
        return rm

    for t in range(T):
        def radiosity(chunk, prev, row, t=t):
            time.sleep(KERNEL_UNIT * max(t, 1) * (chunk.max[1] - chunk.min[1])
                       / W * 8)
            row.set(Box((t, chunk.min[1]), (t + 1, chunk.max[1])),
                    np.full(chunk.max[1] - chunk.min[1], float(t)))

        rt.submit(f"rad{t}", Box((0, 0), (1, W)),
                  [read(R, fixed(Box((0, 0), (max(t, 1), W)))),
                   write(R, row_cols(t))], radiosity, split_dims=(1,))
    rt.sync(timeout=300)


def _wavesim_app(rt: Runtime, H: int, W: int, steps: int, devices: int) -> None:
    B = [rt.buffer((H, W), init=np.zeros((H, W)), name=f"u{i}")
         for i in range(3)]

    def step_kernel(chunk, um, u, un):
        time.sleep(KERNEL_UNIT * (chunk.max[0] - chunk.min[0]) / 32)
        un.set(chunk, um.get(chunk))

    for s in range(steps):
        um, u, un = B[s % 3], B[(s + 1) % 3], B[(s + 2) % 3]
        rt.submit(f"wave{s}", (H, W),
                  [read(um, one_to_one()), read(u, neighborhood((1, 0))),
                   write(un, one_to_one())], step_kernel)
    rt.sync(timeout=300)


def _run_app(app, kind: str, nodes: int, devs: int, **kw) -> float:
    """kind: 'idag' (full runtime) or 'adhoc' (baseline: no lookahead, one
    queue per device, one host thread — memory ops serialize with kernels)."""
    lookahead = kind == "idag"
    qpd = 2 if kind == "idag" else 1
    ht = 4 if kind == "idag" else 1
    t0 = time.perf_counter()
    with Runtime(num_nodes=nodes, devices_per_node=devs, lookahead=lookahead,
                 queues_per_device=qpd, host_threads=ht) as rt:
        app(rt, devices=nodes * devs, **kw)
    return time.perf_counter() - t0


def bench_strong_scaling() -> None:
    """Paper fig. 6 analogue (simulated kernels, in-process ranks)."""
    grids = [(1, 1), (1, 2), (2, 2), (4, 2), (4, 4)]
    apps = [
        ("nbody", _nbody_app, dict(N=2048, steps=6)),
        ("rsim", _rsim_app, dict(T=48, W=4096)),
        ("wavesim", _wavesim_app, dict(H=4096, W=64, steps=16)),
    ]
    for name, app, kw in apps:
        base = {}
        for kind in ("adhoc", "idag"):
            t1 = _run_app(app, kind, 1, 1, **kw)
            base[kind] = t1
            emit(f"strong_scaling/{name}/{kind}/1x1", t1 * 1e6, "speedup=1.00")
            for nodes, devs in grids[1:]:
                t = _run_app(app, kind, nodes, devs, **kw)
                emit(f"strong_scaling/{name}/{kind}/{nodes}x{devs}",
                     t * 1e6, f"speedup={base[kind] / t:.2f}")
        emit(f"strong_scaling/{name}/summary", 0.0,
             f"idag_vs_adhoc_1dev={base['adhoc'] / base['idag']:.2f}")


def bench_overlap() -> None:
    """Paper fig. 7: scheduling overlaps execution (single node, 4 devices)."""
    for name, app, kw in [
        ("nbody", _nbody_app, dict(N=1024, steps=8)),
        ("rsim", _rsim_app, dict(T=32, W=2048)),
        ("wavesim", _wavesim_app, dict(H=2048, W=64, steps=12)),
    ]:
        t0 = time.perf_counter()
        with Runtime(num_nodes=1, devices_per_node=4, trace=True) as rt:
            app(rt, devices=4, **kw)
            tr = rt.tracer
        wall = time.perf_counter() - t0
        f = tr.overlap_fraction("sched-N0", "N0.")
        emit(f"overlap/{name}", wall * 1e6,
             f"sched_busy_while_exec={f:.2f}")
        if name == "rsim":
            print(tr.timeline_text(70))
        maybe_export_trace(tr)


def bench_lookahead() -> None:
    """§4.3 resize elision on the RSim growing pattern."""
    for la in (False, True):
        t0 = time.perf_counter()
        with Runtime(num_nodes=1, devices_per_node=2, lookahead=la) as rt:
            _rsim_app(rt, T=48, W=4096, devices=2)
            allocs = rt.total_allocs()
        wall = time.perf_counter() - t0
        emit(f"lookahead/{'on' if la else 'off'}", wall * 1e6,
             f"allocs={allocs}")


def bench_executor_latency() -> None:
    """§4.1: per-instruction overhead of the out-of-order engine."""
    n_tasks = 300
    with Runtime(num_nodes=1, devices_per_node=2) as rt:
        B = rt.buffer((64,), init=np.zeros(64), name="b")
        t0 = time.perf_counter()
        for i in range(n_tasks):
            rt.submit(f"k{i}", (64,), [read_write(B, one_to_one())],
                      lambda c, v: None)
        rt.sync(timeout=300)
        wall = time.perf_counter() - t0
        n_instr = rt.total_instructions()
        lat = rt.executors[0]._issue_latency
        issue_us = float(np.mean(lat) * 1e6) if lat else 0.0
    emit("executor/task_throughput", wall / n_tasks * 1e6,
         f"instr={n_instr}")
    # NOTE: semantics changed in PR 1 — this is now the mean ready->submit
    # dispatch latency (the pre-PR executor recorded selection-scan time);
    # do not compare across that boundary
    emit("executor/issue_latency", issue_us, "mean ready->submit dispatch")


# ---------------------------------------------------------------------------
# roofline (TPU v5e constants; see DESIGN.md §6)

PEAK = 197e12
HBM = 819e9
ICI = 4 * 50e9   # per-chip aggregate link bandwidth


def roofline_terms(rec: dict) -> dict:
    """All terms in seconds (per step; dry-run numbers are per-device)."""
    coll_bytes = sum(rec.get("collectives", {}).values())
    compute = rec["flops"] / PEAK
    memory = rec["bytes_accessed"] / HBM
    collective = coll_bytes / ICI
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])
    mult = 6 if rec["kind"] == "train" else 2   # fwd+bwd vs fwd-only
    D = (rec["seq_len"] * rec["global_batch"] if rec["kind"] != "decode"
         else rec["global_batch"])
    model_flops = mult * rec["params_active"] * D
    useful = model_flops / max(rec["flops"] * rec["chips"], 1)
    step_time = max(compute, memory, collective)
    mfu = model_flops / (rec["chips"] * PEAK * step_time) if step_time else 0
    return dict(compute=compute, memory=memory, collective=collective,
                dominant=dom[0], useful_fraction=useful, mfu=mfu,
                step_time=step_time)


def bench_roofline(art_dir: Path | None = None) -> None:
    art_dir = art_dir or ROOT / "artifacts" / "dryrun"
    for f in sorted(art_dir.glob("*__single.json")):
        rec = json.loads(f.read_text())
        if "error" in rec or "skipped" in rec:
            emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
                 "skipped" if "skipped" in rec else "ERROR")
            continue
        t = roofline_terms(rec)
        emit(f"roofline/{rec['arch']}/{rec['shape']}",
             t["step_time"] * 1e6,
             f"dom={t['dominant']};mfu={t['mfu']:.3f};"
             f"c={t['compute']:.4f};m={t['memory']:.4f};"
             f"n={t['collective']:.4f};useful={t['useful_fraction']:.2f}")


# ---------------------------------------------------------------------------
# scheduler throughput (this repo's perf north-star: scheduling must run
# faster than execution to stay off the critical path, paper §4.1 / fig. 7)

SCHED_JSON: dict[str, float] = {}


def _time_loop(fn, min_reps: int = 3, min_time: float = 0.15) -> float:
    """Best-effort per-call seconds (median of reps, at least min_time total)."""
    times = []
    t_total = 0.0
    while len(times) < min_reps or t_total < min_time:
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        times.append(dt)
        t_total += dt
        if len(times) > 200:
            break
    times.sort()
    return times[len(times) // 2]


def bench_scheduler_throughput() -> None:
    """Region-algebra, IDAG-compile and executor fast-path microbenchmarks.

    Emits ops/sec style numbers and records them in ``SCHED_JSON`` for the
    ``--json`` flag (written to BENCH_scheduler.json).
    """
    from repro.core.command_graph import Command, CommandType
    from repro.core.communicator import Communicator
    from repro.core.executor import Executor
    from repro.core.instruction_graph import (IdagGenerator, Instruction,
                                              InstructionType)
    from repro.core.task_graph import DepKind, TaskGraph

    # -- region normalization: n disjoint boxes, merge-heavy and merge-free --
    n = 96
    rows = [Box((i, 0), (i + 1, 64)) for i in range(n)]
    checker = [Box((2 * i, 2 * j), (2 * i + 1, 2 * j + 1))
               for i in range(12) for j in range(8)]
    t_rows = _time_loop(lambda: Region(rows))
    t_checker = _time_loop(lambda: Region(checker))
    emit("sched/region_norm_rows96", t_rows * 1e6,
         f"ops_per_s={1.0 / t_rows:.0f}")
    emit("sched/region_norm_checker96", t_checker * 1e6,
         f"ops_per_s={1.0 / t_checker:.0f}")
    SCHED_JSON["region_norm_rows96_us"] = t_rows * 1e6
    SCHED_JSON["region_norm_checker96_us"] = t_checker * 1e6

    # -- region intersection: two 64-box regions with many overlaps ----------
    a64 = Region([Box((4 * i, 4 * j), (4 * i + 3, 4 * j + 3))
                  for i in range(8) for j in range(8)])
    b64 = Region([Box((4 * i + 2, 4 * j + 2), (4 * i + 5, 4 * j + 5))
                  for i in range(8) for j in range(8)])
    assert len(a64) >= 64 and len(b64) >= 64
    t_int = _time_loop(lambda: a64.intersect(b64))
    t_diff = _time_loop(lambda: a64.difference(b64))
    emit("sched/region_intersect_64x64", t_int * 1e6,
         f"ops_per_s={1.0 / t_int:.0f}")
    emit("sched/region_difference_64x64", t_diff * 1e6,
         f"ops_per_s={1.0 / t_diff:.0f}")
    SCHED_JSON["region_intersect_64x64_us"] = t_int * 1e6
    SCHED_JSON["region_difference_64x64_us"] = t_diff * 1e6

    # -- TDAG -> CDAG -> IDAG compile throughput (no threads, no executor) ---
    from repro.core.buffer import VirtualBuffer
    from repro.core.command_graph import CommandGraphGenerator

    def compile_stream() -> int:
        tdag = TaskGraph(horizon_step=4)
        cdag = CommandGraphGenerator(1)
        idag = IdagGenerator(0, 4)
        H = W = 256
        bufs = [VirtualBuffer(shape=(H, W), dtype=np.dtype(np.float64),
                              name=f"b{i}", initial_value=np.zeros((H, W)))
                for i in range(3)]
        count = 0
        for s in range(120):
            um, u, un = (bufs[s % 3], bufs[(s + 1) % 3], bufs[(s + 2) % 3])
            tdag.submit(f"w{s}", (H, W),
                        [read(um, one_to_one()),
                         read(u, neighborhood((1, 0))),
                         write(un, one_to_one())], None)
            for t in tdag.tasks[-2:]:          # task (+ auto horizon)
                if getattr(t, "_compiled", False) or (
                        t.ttype.value == "epoch" and t.name == "init"):
                    continue
                t._compiled = True
                for cmd in cdag.process(t):
                    if cmd.node == 0:
                        count += len(idag.compile(cmd))
        return count

    t0 = time.perf_counter()
    n_instr = compile_stream()
    t_compile = time.perf_counter() - t0
    ips = n_instr / t_compile
    emit("sched/idag_compile", t_compile / max(n_instr, 1) * 1e6,
         f"instr_per_s={ips:.0f};instr={n_instr}")
    SCHED_JSON["idag_instr_per_s"] = ips

    # -- executor issue latency: wide+deep no-op host-task chains -----------
    width, depth = 48, 25

    def issue_harness() -> tuple[float, int]:
        comm = Communicator(1)
        ex = Executor(0, 1, comm, host_threads=2)
        try:
            noop = lambda chunk: None  # noqa: E731
            last: list = [None] * width
            instrs = []
            for d in range(depth):
                for w in range(width):
                    i = Instruction(InstructionType.HOST_TASK, node=0,
                                    queue=("host",), kernel_fn=noop,
                                    name=f"c{w}.{d}")
                    if last[w] is not None:
                        i.add_dependency(last[w], DepKind.TRUE)
                    last[w] = i
                    instrs.append(i)
            ecmd = Command(CommandType.EPOCH, node=0)
            epoch = Instruction(InstructionType.EPOCH, node=0, queue=("host",),
                                name="bench-epoch", command=ecmd)
            for tail in last:
                epoch.add_dependency(tail, DepKind.SYNC)
            instrs.append(epoch)
            t0 = time.perf_counter()
            ex.submit(instrs)
            ex.wait_epoch(ecmd.cid, timeout=120)
            return time.perf_counter() - t0, len(instrs)
        finally:
            ex.shutdown()

    # best-of-5: container CPU noise is additive, the minimum is the signal
    runs = sorted(issue_harness() for _ in range(5))
    wall, n = runs[0]
    per_instr = wall / n
    emit("sched/executor_issue", per_instr * 1e6,
         f"instr={n};wall_ms={wall * 1e3:.1f}")
    SCHED_JSON["executor_issue_us"] = per_instr * 1e6

    # -- retained instructions on a long run (horizon retirement, §3.5) -----
    with Runtime(num_nodes=1, devices_per_node=2) as rt:
        _nbody_app(rt, N=256, steps=200, devices=2)
        ex0 = rt.executors[0]
        peak = getattr(ex0, "_peak_registered", None)
        if peak is None:
            peak = len(ex0._registered)
        final = len(ex0._registered)
        total = rt.total_instructions()
    emit("sched/peak_retained_nbody200", float(peak),
         f"final={final};total_instr={total}")
    SCHED_JSON["peak_retained_nbody200"] = float(peak)
    SCHED_JSON["final_retained_nbody200"] = float(final)
    SCHED_JSON["total_instr_nbody200"] = float(total)

    # -- out-of-order issue (DESIGN.md §13): allocation renaming on a
    #    slow-reader / overwrite chain.  Each step overwrites X, then a slow
    #    kernel reads X into its own private result row.  Without renaming
    #    the writer of step s+1 serializes behind step s's reader (WAR on X)
    #    so reader generations never overlap; with renaming the writer gets
    #    a fresh physical and consecutive readers pipeline onto the second
    #    device queue.  ``device_occupancy`` comes from the flight recorder:
    #    raw kernel time over device-lane capacity, so overlap raises it.
    def pipeline_run(renaming: bool):
        n, steps = 4096, 10
        with Runtime(num_nodes=1, devices_per_node=2, trace=True,
                     horizon_step=16, renaming=renaming, issue_width=8,
                     max_inflight_windows=4) as rt:
            X = rt.buffer((1, n), init=np.zeros((1, n)), name="X")
            R = rt.buffer((steps, n), init=np.zeros((steps, n)), name="R")

            t0 = time.perf_counter()
            for s in range(steps):
                def wk(chunk, xv, s=s):
                    w = chunk.max[1] - chunk.min[1]
                    xv.set(chunk, np.full((1, w), float(s + 1)))

                def rk(chunk, xv, rv, s=s):
                    time.sleep(3e-3)
                    rv.set(Box((s, chunk.min[1]), (s + 1, chunk.max[1])),
                           xv.get(chunk))

                def row(chunk, shape, s=s):
                    return Region.from_box(
                        Box((s, chunk.min[1]), (s + 1, chunk.max[1])))

                rt.submit(f"wr{s}", Box((0, 0), (1, n)),
                          [write(X, one_to_one())], wk, split_dims=(1,))
                rt.submit(f"rd{s}", Box((0, 0), (1, n)),
                          [read(X, one_to_one()), write(R, row)], rk,
                          split_dims=(1,))
            rt.sync(timeout=300)
            wall = time.perf_counter() - t0
            out = rt.gather(R)
            util = rt.utilization_report()
            n_instr = rt.total_instructions()
            renames = sum(r.get("renames", 0) for r in rt.memory_report())
        return out, float(util["device_occupancy"]), wall, n_instr, renames

    occ: dict[bool, float] = {}
    walls: dict[bool, float] = {}
    ips_pipe: dict[bool, float] = {}
    outs: dict[bool, np.ndarray] = {}
    renames_on = 0
    for _ in range(2):            # interleaved; noise only lowers occupancy,
        for rn in (False, True):  # so the max over reps is the signal
            out, o, wall, n_i, n_rn = pipeline_run(rn)
            if rn not in occ or o > occ[rn]:
                occ[rn], walls[rn] = o, wall
                ips_pipe[rn] = n_i / wall
            outs[rn] = out
            if rn:
                renames_on = max(renames_on, n_rn)
    assert (outs[True] == outs[False]).all(), \
        "renaming must be bit-identical to the renaming-off oracle"
    assert renames_on > 0, "renaming never fired on the overwrite chain"
    for rn, label in ((False, "off"), (True, "on")):
        emit(f"sched/pipeline_renaming_{label}", walls[rn] * 1e6,
             f"occupancy={occ[rn]:.3f};instr_per_s={ips_pipe[rn]:.0f}"
             + (f";renames={renames_on}" if rn else ""))
    SCHED_JSON["executor_occupancy"] = occ[True]
    SCHED_JSON["executor_occupancy_off"] = occ[False]
    SCHED_JSON["pipeline_renaming_instr_per_s"] = ips_pipe[True]
    SCHED_JSON["pipeline_renaming_off_instr_per_s"] = ips_pipe[False]


# ---------------------------------------------------------------------------
# observability (DESIGN.md §11): flight-recorder overhead on the executor
# issue path, and critical-path analyzer wall time on a real trace


def bench_observability() -> None:
    """Instrumentation cost + analyzer throughput.

    The §11 overhead budget: a bare executor (no tracer, no metrics) must
    pay nothing for the observability hooks — ``obs_issue_plain_us`` is the
    same configuration as ``executor_issue_us`` and is gated by the same CI
    regression check.  The metrics/traced variants quantify what turning
    instrumentation ON costs; the variants run interleaved so container
    noise hits all three equally.
    """
    from repro.core import MetricsRegistry, Tracer, critical_path
    from repro.core.command_graph import Command, CommandType
    from repro.core.communicator import Communicator
    from repro.core.executor import Executor
    from repro.core.instruction_graph import Instruction, InstructionType
    from repro.core.task_graph import DepKind

    width, depth = 48, 25

    def harness(tracer, metrics) -> tuple[float, int]:
        comm = Communicator(1)
        ex = Executor(0, 1, comm, host_threads=2, tracer=tracer,
                      metrics=metrics)
        try:
            noop = lambda chunk: None  # noqa: E731
            last: list = [None] * width
            instrs = []
            for d in range(depth):
                for w in range(width):
                    i = Instruction(InstructionType.HOST_TASK, node=0,
                                    queue=("host",), kernel_fn=noop,
                                    name=f"c{w}.{d}")
                    if last[w] is not None:
                        i.add_dependency(last[w], DepKind.TRUE)
                    last[w] = i
                    instrs.append(i)
            ecmd = Command(CommandType.EPOCH, node=0)
            epoch = Instruction(InstructionType.EPOCH, node=0, queue=("host",),
                                name="bench-epoch", command=ecmd)
            for tail in last:
                epoch.add_dependency(tail, DepKind.SYNC)
            instrs.append(epoch)
            t0 = time.perf_counter()
            ex.submit(instrs)
            ex.wait_epoch(ecmd.cid, timeout=120)
            return time.perf_counter() - t0, len(instrs)
        finally:
            ex.shutdown()

    variants = {
        "plain": lambda: harness(None, None),
        "metrics": lambda: harness(None, MetricsRegistry()),
        "traced": lambda: harness(Tracer(), MetricsRegistry()),
        # 1-in-16 InstrRecord capture: most of the traced overhead is the
        # record build + locked append, so sampling should recover most of
        # the gap to the metrics-only variant
        "sampled": lambda: harness(Tracer(record_sample=16),
                                   MetricsRegistry()),
    }
    best: dict[str, tuple[float, int]] = {}
    for _ in range(5):                   # interleaved best-of-5 per variant
        for key, fn in variants.items():
            r = fn()
            if key not in best or r[0] < best[key][0]:
                best[key] = r
    plain_us = best["plain"][0] / best["plain"][1] * 1e6
    for key in ("plain", "metrics", "traced", "sampled"):
        wall, n = best[key]
        per_us = wall / n * 1e6
        pct = 100.0 * (per_us - plain_us) / plain_us if key != "plain" else 0.0
        emit(f"obs/issue_{key}", per_us,
             f"instr={n};overhead_pct={pct:+.1f}")
        SCHED_JSON[f"obs_issue_{key}_us"] = per_us
        if key != "plain":
            SCHED_JSON[f"obs_overhead_{key}_pct"] = pct

    # -- critical-path analyzer wall time on an nbody-200 trace --------------
    with Runtime(num_nodes=1, devices_per_node=2, trace=True) as rt:
        _nbody_app(rt, N=256, steps=200, devices=2)
        tracer = rt.tracer
        n_rec = len(tracer.records)
        t_walk = _time_loop(lambda: critical_path(tracer))
        rep = critical_path(tracer)
        maybe_export_trace(tracer)
    emit("obs/critical_path_walk", t_walk * 1e6,
         f"records={n_rec};chain={rep.chain_len};"
         f"sched_frac={rep.scheduler_fraction:.4f}")
    SCHED_JSON["obs_critpath_us"] = t_walk * 1e6
    SCHED_JSON["obs_critpath_records"] = float(n_rec)


# ---------------------------------------------------------------------------
# memory layer (DESIGN.md §8): steady-state throughput + spill overhead
# at device budgets of 100% / 50% / 25% of the measured working set


def bench_memory() -> None:
    """Budgeted MemoryManager overhead on a phased multi-group workload.

    Six buffer groups are touched round-robin (working set = 6 groups, any
    one phase's footprint = 1 group), so at 50%/25% budgets the eviction
    policy must cycle allocations through spill/reload chains.  Emits
    steady-state instructions/s and the spill/reload counts per budget
    level; records ``memory_*`` keys in ``SCHED_JSON`` (--json).
    """
    groups, n, steps, rounds = 6, 32768, 3, 2

    def app(rt) -> None:
        rng = np.random.default_rng(0)
        bufs = [(rt.buffer((n,), init=rng.normal(size=n), name=f"A{g}"),
                 rt.buffer((n,), init=np.zeros(n), name=f"B{g}"))
                for g in range(groups)]
        for r in range(rounds):
            for g in range(groups):
                A, B = bufs[g]
                for s in range(steps):
                    def k(chunk, av, bv, s=s):
                        bv.set(chunk, bv.get(chunk) + av.get(chunk) * (s + 1))
                    rt.submit(f"r{r}g{g}s{s}", (n,),
                              [read(A, one_to_one()),
                               read_write(B, one_to_one())], k)
        rt.sync(timeout=300)

    def run(budget):
        t0 = time.perf_counter()
        with Runtime(num_nodes=1, devices_per_node=2,
                     device_memory_budget=budget) as rt:
            app(rt)
            wall = time.perf_counter() - t0
            reports = rt.memory_report()
            n_instr = rt.total_instructions()
            peak = rt.device_peak_bytes()
        spills = sum(r["spills"] for r in reports)
        reloads = sum(r["reloads"] for r in reports)
        return wall, n_instr, peak, spills, reloads

    run(None)                       # warmup: thread/executor first-run costs
    first = run(None)
    hwm = first[2]
    # min over interleaved repetitions: container co-tenancy noise is
    # additive, so the minimum is the signal (see bench_scheduler_throughput)
    levels = [(None, "unbudgeted"), (1.0, "budget100"),
              (0.5, "budget50"), (0.25, "budget25")]
    best = {"unbudgeted": first}
    for _ in range(2):
        for frac, label in levels:
            budget = None if frac is None else int(hwm * frac)
            r = run(budget)
            if label not in best or r[0] < best[label][0]:
                best[label] = r
    base_wall = best["unbudgeted"][0]
    for frac, label in levels:
        wall, n_instr, peak, spills, reloads = best[label]
        if frac is None:
            emit("memory/unbudgeted", wall * 1e6,
                 f"instr_per_s={n_instr / wall:.0f};hwm={hwm}")
            SCHED_JSON["memory_unbudgeted_us"] = wall * 1e6
            SCHED_JSON["memory_unbudgeted_instr_per_s"] = n_instr / wall
            continue
        budget = int(hwm * frac)
        pct = int(frac * 100)
        over = wall / base_wall - 1.0
        emit(f"memory/{label}", wall * 1e6,
             f"instr_per_s={n_instr / wall:.0f};spills={spills};"
             f"reloads={reloads};overhead={over * 100:.0f}%;"
             f"peak_ok={'yes' if peak <= budget else 'NO'}")
        SCHED_JSON[f"memory_{label}_us"] = wall * 1e6
        SCHED_JSON[f"memory_{label}_instr_per_s"] = n_instr / wall
        SCHED_JSON[f"memory_{label}_spills"] = float(spills)
        SCHED_JSON[f"memory_{label}_reloads"] = float(reloads)
        SCHED_JSON[f"memory_{label}_overhead_pct"] = over * 100


# ---------------------------------------------------------------------------
# collective exchange layer (DESIGN.md §9): message count + steady-state
# exchange latency vs node count, point-to-point vs collective topologies,
# and fused vs unfused adjacent reductions


def bench_collective() -> None:
    """Replicated-exchange scaling: O(N^2) all-pairs vs O(N log N) rounds.

    Two workloads per node count: (a) the write-partitioned / read-all
    allgather pattern, (b) two adjacent scalar reductions per step (the
    nbody E+Mx shape) fused vs unfused.  Emits per-exchange message counts
    and steady-state latency; records ``collective_*`` keys in
    ``SCHED_JSON`` (--json).
    """
    n, steps = 2048, 4

    def allgather_app(rt) -> None:
        P = rt.buffer((n,), init=np.zeros(n), name="P")
        O = rt.buffer((n,), init=np.zeros(n), name="O")

        def step(chunk, p):
            p.set(chunk, p.get(chunk) + 1.0)

        def fold(chunk, pall, out):
            a = pall.get(Box((0,), (n,)))
            out.set(chunk, out.get(chunk) + a.sum())

        for _ in range(steps):
            rt.submit("step", (n,), [read_write(P, one_to_one())], step)
            rt.submit("fold", (n,), [read(P, all_range()),
                                     read_write(O, one_to_one())], fold)
        rt.sync(timeout=300)

    for nodes in (2, 4, 6):
        results = {}
        for coll in (False, True):
            with Runtime(num_nodes=nodes, devices_per_node=1,
                         collectives=coll, host_threads=2) as rt:
                allgather_app(rt)          # warmup window
                m0 = rt.comm.num_messages
                t0 = time.perf_counter()
                allgather_app(rt)          # steady state
                wall = time.perf_counter() - t0
                msgs = rt.comm.num_messages - m0
            results[coll] = (wall, msgs)
            label = "coll" if coll else "p2p"
            emit(f"collective/allgather/{nodes}n/{label}",
                 wall / steps * 1e6, f"msgs_per_run={msgs}")
            SCHED_JSON[f"collective_allgather_{nodes}n_{label}_us"] = \
                wall / steps * 1e6
            SCHED_JSON[f"collective_allgather_{nodes}n_{label}_msgs"] = \
                float(msgs)
        emit(f"collective/allgather/{nodes}n/summary", 0.0,
             f"msg_ratio={results[False][1] / max(results[True][1], 1):.2f}")

    def fused_app(rt) -> None:
        X = rt.buffer((n,), init=np.zeros(n), name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")
        M = rt.buffer((1,), init=np.zeros(1), name="M")

        def k1(chunk, xv, red):
            red.contribute(xv.get(chunk))

        def k2(chunk, xv, red):
            red.contribute(xv.get(chunk) * 2.0)

        for _ in range(steps):
            rt.submit("e", (n,), [read(X, one_to_one()),
                                  reduction(E, "sum")], k1)
            rt.submit("m", (n,), [read(X, one_to_one()),
                                  reduction(M, "sum")], k2)
        rt.sync(timeout=300)

    for nodes in (2, 4):
        for fused in (False, True):
            with Runtime(num_nodes=nodes, devices_per_node=1,
                         reduction_fusion=fused, host_threads=2) as rt:
                fused_app(rt)              # warmup
                m0 = rt.comm.coll_messages
                t0 = time.perf_counter()
                fused_app(rt)
                wall = time.perf_counter() - t0
                msgs = rt.comm.coll_messages - m0
            label = "fused" if fused else "unfused"
            emit(f"collective/reduction/{nodes}n/{label}",
                 wall / steps * 1e6, f"coll_msgs_per_run={msgs}")
            SCHED_JSON[f"collective_reduction_{nodes}n_{label}_us"] = \
                wall / steps * 1e6
            SCHED_JSON[f"collective_reduction_{nodes}n_{label}_msgs"] = \
                float(msgs)

    # reduce-scatter + allgather allreduce vs the full-partial slot
    # allgather (DESIGN.md §9): bytes/messages of a vector reduction
    def allreduce_app(rt) -> None:
        X = rt.buffer((n,), init=np.zeros(n), name="X")
        V = rt.buffer((4096,), init=np.zeros(4096), name="V")

        def k(chunk, xv, red):
            a = xv.get(chunk)
            out = np.zeros((a.shape[0], 4096))
            out[:, chunk.min[0] % 4096] = a
            red.contribute(out)

        for _ in range(steps):
            rt.submit("vred", (n,), [read(X, one_to_one()),
                                     reduction(V, "sum")], k)
        rt.sync(timeout=300)

    for nodes in (2, 4, 6):
        results = {}
        for arx in (False, True):
            with Runtime(num_nodes=nodes, devices_per_node=1,
                         reduction_allreduce=arx, host_threads=2) as rt:
                allreduce_app(rt)          # warmup
                m0, b0 = rt.comm.red_messages, rt.comm.red_bytes
                t0 = time.perf_counter()
                allreduce_app(rt)
                wall = time.perf_counter() - t0
                msgs = rt.comm.red_messages - m0
                nbytes = rt.comm.red_bytes - b0
            results[arx] = (wall, msgs, nbytes)
            label = "allreduce" if arx else "fullpartial"
            emit(f"collective/allreduce/{nodes}n/{label}",
                 wall / steps * 1e6,
                 f"red_msgs_per_run={msgs};red_bytes_per_run={nbytes}")
            SCHED_JSON[f"collective_allreduce_{nodes}n_{label}_us"] = \
                wall / steps * 1e6
            SCHED_JSON[f"collective_allreduce_{nodes}n_{label}_msgs"] = \
                float(msgs)
            SCHED_JSON[f"collective_allreduce_{nodes}n_{label}_bytes"] = \
                float(nbytes)
        ratio = results[True][2] / max(results[False][2], 1)
        emit(f"collective/allreduce/{nodes}n/summary", 0.0,
             f"bytes_ratio={ratio:.2f}")
        SCHED_JSON[f"collective_allreduce_{nodes}n_bytes_ratio"] = ratio


# ---------------------------------------------------------------------------
# distributed reductions (§2.2): node-count x reduction-size scaling


def bench_reduction() -> None:
    """End-to-end reduction latency + exact-sum verification.

    Scales the cluster grid and the number of contributed elements; the
    derived column verifies the result is bitwise equal to ``math.fsum``.
    Records ``reduction_<grid>_n<size>_us`` in ``SCHED_JSON`` (--json).
    """
    import math
    steps = 4
    rng = np.random.default_rng(11)
    for nodes, devs in [(1, 2), (2, 2), (4, 2)]:
        for size in (1024, 16384):
            data = rng.normal(size=(size,))
            trace = TRACE_PATH is not None
            with Runtime(num_nodes=nodes, devices_per_node=devs,
                         trace=trace) as rt:
                X = rt.buffer((size,), init=data, name="X")
                E = rt.buffer((1,), init=np.zeros(1), name="E")

                def k(chunk, xv, red):
                    red.contribute(xv.get(chunk))

                # warmup: first reduction pays allocation/coherence setup
                rt.submit("redwarm", (size,),
                          [read(X, one_to_one()), reduction(E, "sum")], k)
                rt.sync(timeout=300)
                # measure steady-state submit -> result only (no runtime
                # construction/teardown in the scaling numbers)
                t0 = time.perf_counter()
                for _ in range(steps):
                    rt.submit("redsum", (size,),
                              [read(X, one_to_one()), reduction(E, "sum")], k)
                rt.sync(timeout=300)
                wall = time.perf_counter() - t0
                val = float(rt.gather(E)[0])
                tr = rt.tracer
            ok = val == math.fsum(data)
            us = wall / steps * 1e6
            emit(f"reduction/{nodes}x{devs}/n{size}", us,
                 f"bitexact={'yes' if ok else 'NO'}")
            SCHED_JSON[f"reduction_{nodes}x{devs}_n{size}_us"] = us
            maybe_export_trace(tr)


# ---------------------------------------------------------------------------
# fault layer (DESIGN.md §10): zero-fault ack/retry overhead + recovery
# latency under injected faults


def bench_faults() -> None:
    """Resilient-transport cost model.

    (a) zero-fault overhead of the seq/ack/retransmit machinery on the
    executor-issue fast path and on the 4-node allreduce exchange —
    reliable on vs off, interleaved repetitions, min-over-runs (container
    noise is additive, the minimum is the signal);
    (b) recovery latency with 1% payload drops (retransmit path);
    (c) crash-to-attributed-error latency via watchdog + EPOCH_ABORT.
    Records ``faults_*`` keys in ``SCHED_JSON`` (--json).
    """
    from repro.core import FaultPlan
    from repro.core.command_graph import Command, CommandType
    from repro.core.communicator import Communicator
    from repro.core.executor import Executor
    from repro.core.instruction_graph import Instruction, InstructionType
    from repro.core.task_graph import DepKind

    # -- (a1) executor-issue fast path: reliable pump checks on vs off -------
    width, depth = 48, 25

    def issue_harness(reliable: bool) -> tuple[float, int]:
        comm = Communicator(1, reliable=reliable)
        ex = Executor(0, 1, comm, host_threads=2)
        try:
            noop = lambda chunk: None  # noqa: E731
            last: list = [None] * width
            instrs = []
            for d in range(depth):
                for w in range(width):
                    i = Instruction(InstructionType.HOST_TASK, node=0,
                                    queue=("host",), kernel_fn=noop,
                                    name=f"c{w}.{d}")
                    if last[w] is not None:
                        i.add_dependency(last[w], DepKind.TRUE)
                    last[w] = i
                    instrs.append(i)
            ecmd = Command(CommandType.EPOCH, node=0)
            epoch = Instruction(InstructionType.EPOCH, node=0, queue=("host",),
                                name="bench-epoch", command=ecmd)
            for tail in last:
                epoch.add_dependency(tail, DepKind.SYNC)
            instrs.append(epoch)
            t0 = time.perf_counter()
            ex.submit(instrs)
            ex.wait_epoch(ecmd.cid, timeout=120)
            return time.perf_counter() - t0, len(instrs)
        finally:
            ex.shutdown()

    best_issue = {False: float("inf"), True: float("inf")}
    for _ in range(5):
        for rel in (False, True):          # interleaved: same noise regime
            wall, n = issue_harness(rel)
            best_issue[rel] = min(best_issue[rel], wall / n)
    over = best_issue[True] / best_issue[False] - 1.0
    emit("faults/issue_overhead", best_issue[True] * 1e6,
         f"unreliable_us={best_issue[False] * 1e6:.1f};"
         f"overhead={over * 100:.1f}%")
    SCHED_JSON["faults_issue_reliable_us"] = best_issue[True] * 1e6
    SCHED_JSON["faults_issue_unreliable_us"] = best_issue[False] * 1e6
    SCHED_JSON["faults_issue_overhead_pct"] = over * 100

    # -- (a2) 4-node allreduce exchange: full ack/retransmit bookkeeping -----
    n, steps = 2048, 4

    def allreduce_app(rt) -> None:
        X = rt.buffer((n,), init=np.zeros(n), name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")

        def k(chunk, xv, red):
            red.contribute(xv.get(chunk))

        for _ in range(steps):
            rt.submit("e", (n,), [read(X, one_to_one()),
                                  reduction(E, "sum")], k)
        rt.sync(timeout=300)

    def allreduce_run(reliable: bool, plan=None, **kw) -> tuple[float, dict]:
        with Runtime(num_nodes=4, devices_per_node=1, host_threads=2,
                     reliable=reliable, fault_plan=plan, **kw) as rt:
            allreduce_app(rt)              # warmup window
            t0 = time.perf_counter()
            allreduce_app(rt)              # steady state
            wall = time.perf_counter() - t0
            stats = rt.comm_stats()
        return wall / steps, stats

    best_ar = {False: float("inf"), True: float("inf")}
    for _ in range(3):
        for rel in (False, True):
            us, _ = allreduce_run(rel)
            best_ar[rel] = min(best_ar[rel], us)
    over = best_ar[True] / best_ar[False] - 1.0
    emit("faults/allreduce_4n_overhead", best_ar[True] * 1e6,
         f"unreliable_us={best_ar[False] * 1e6:.1f};"
         f"overhead={over * 100:.1f}%")
    SCHED_JSON["faults_allreduce_4n_reliable_us"] = best_ar[True] * 1e6
    SCHED_JSON["faults_allreduce_4n_unreliable_us"] = best_ar[False] * 1e6
    SCHED_JSON["faults_allreduce_4n_overhead_pct"] = over * 100

    # -- (b) recovery under 5% drops: retransmits repair the stream ----------
    # Fault keys of reduction traffic are not identical across runs (msg-id
    # assignment follows execution order), so a low drop rate can leave an
    # entire rep drop-free.  5% over the ~48-message window makes every rep
    # exercise the retransmit path with high probability; latency is the
    # min over reps that actually retransmitted, retries the max over reps.
    plan = FaultPlan(seed=5, drop=0.05)
    reps: list[tuple[float, dict]] = []
    for _ in range(4):
        reps.append(allreduce_run(True, plan=plan, retransmit_timeout=0.005))
    hit = [r for r in reps if r[1].get("retries", 0) > 0] or reps
    best_drop = min(us for us, _ in hit)
    max_retries = max(s.get("retries", 0) for _, s in reps)
    over = best_drop / best_ar[True] - 1.0
    emit("faults/allreduce_4n_drop5pct", best_drop * 1e6,
         f"retries={max_retries};"
         f"overhead_vs_clean={over * 100:.1f}%")
    SCHED_JSON["faults_drop5pct_us"] = best_drop * 1e6
    SCHED_JSON["faults_drop5pct_retries"] = float(max_retries)
    SCHED_JSON["faults_drop5pct_overhead_pct"] = over * 100

    # -- (c) crash-to-attributed-error latency -------------------------------
    H, W = 24, 8
    lat = float("inf")
    for rep in range(3):
        rt = Runtime(num_nodes=2, devices_per_node=1,
                     fault_plan=FaultPlan(crash={1: 8}),
                     watchdog_timeout=0.25)
        try:
            u = rt.buffer((H, W), init=np.ones((H, W)), name="u")
            v = rt.buffer((H, W), init=np.zeros((H, W)), name="v")

            def k(chunk, uv, vv):
                lo, hi = chunk.min[0], chunk.max[0]
                ext = Box((max(0, lo - 1), 0), (min(H, hi + 1), W))
                pad = lo - ext.min[0]
                vv.set(chunk, uv.get(ext)[pad:pad + hi - lo])

            for s in range(4):
                a, b = (u, v) if s % 2 == 0 else (v, u)
                rt.submit(f"k{s}", (H, W),
                          [read(a, neighborhood((1, 0))),
                           write(b, one_to_one())], k)
            t0 = time.perf_counter()
            try:
                rt.sync(timeout=30)
            except RuntimeError:
                lat = min(lat, time.perf_counter() - t0)
        finally:
            rt.shutdown()
    emit("faults/crash_attribution", lat * 1e6, "watchdog=0.25s")
    SCHED_JSON["faults_crash_attribution_s"] = lat


# ---------------------------------------------------------------------------
# serving runtime (DESIGN.md §12): schedule memoization + multi-tenancy


def bench_serve() -> None:
    """Steady-state serving cost with and without the memo cache.

    Per-request *scheduling* cost is the submit-side wall time of one
    window (``submit`` + ``run``): cold it runs TDAG→CDAG→IDAG lowering,
    on a cache hit it clones + patches the captured instruction window.
    Also reports end-to-end window latency p99 and requests/s for 1- and
    4-tenant mixes; records ``serve_*`` keys in ``SCHED_JSON`` (--json).
    """
    from repro.core import ServingRuntime

    W = 64

    def kern(chunk, v):
        v.set(chunk, v.get(chunk) + 1.0)

    def run_cfg(n_tenants: int, memo: bool, rounds: int = 100):
        srv = ServingRuntime(2, 1, memo=memo)
        try:
            tens = []
            for i in range(n_tenants):
                t = srv.tenant(f"t{i}")
                buf = t.buffer((W,), init=np.zeros(W), name="A")
                tens.append((t, buf))

            def window(t, buf):
                t.submit("step", (W,), [read_write(buf, one_to_one())], kern)
                return t.run()

            for _ in range(8):              # warm past the capture fixpoint
                for t, buf in tens:
                    window(t, buf).wait()
            sched, lat = [], []
            t0 = time.perf_counter()
            for _ in range(rounds):
                for t, buf in tens:
                    s0 = time.perf_counter()
                    h = window(t, buf)
                    s1 = time.perf_counter()
                    h.wait()
                    sched.append((s1 - s0) * 1e6)
                    lat.append((time.perf_counter() - s0) * 1e6)
            wall = time.perf_counter() - t0
            stats = srv.memo_stats()
            if memo:
                assert stats["hits"] >= rounds * n_tenants, \
                    "steady state must be all cache hits"
            return (float(np.mean(sched)), float(np.percentile(lat, 99)),
                    rounds * n_tenants / wall)
        finally:
            srv.shutdown()

    best: dict[tuple[int, bool], tuple] = {}
    for _ in range(2):                      # interleaved best-of-2
        for n_tenants in (1, 4):
            for memo in (False, True):
                r = run_cfg(n_tenants, memo)
                k = (n_tenants, memo)
                if k not in best or r[0] < best[k][0]:
                    best[k] = r
    for n_tenants in (1, 4):
        cold_us, cold_p99, cold_rps = best[(n_tenants, False)]
        hit_us, hit_p99, hit_rps = best[(n_tenants, True)]
        speedup = cold_us / hit_us if hit_us else float("inf")
        tag = f"{n_tenants}t"
        emit(f"serve/sched_cold_{tag}", cold_us,
             f"p99={cold_p99:.0f}us;rps={cold_rps:.0f}")
        emit(f"serve/sched_hit_{tag}", hit_us,
             f"p99={hit_p99:.0f}us;rps={hit_rps:.0f};speedup={speedup:.1f}x")
        SCHED_JSON[f"serve_sched_cold_{tag}_us"] = cold_us
        SCHED_JSON[f"serve_sched_hit_{tag}_us"] = hit_us
        SCHED_JSON[f"serve_p99_cold_{tag}_us"] = cold_p99
        SCHED_JSON[f"serve_p99_hit_{tag}_us"] = hit_p99
        SCHED_JSON[f"serve_req_per_s_{tag}"] = hit_rps
        SCHED_JSON[f"serve_speedup_{tag}"] = speedup

    # -- pipelined replay (DESIGN.md §13): with ``max_inflight_windows=2``
    #    a burst of replayed windows overlaps on the executor instead of
    #    fencing at every replay boundary; ``serve_inflight_windows`` is the
    #    executor-observed peak (must reach the configured depth).  The
    #    window holds two independent chains — a fast kernel on X and a slow
    #    kernel on Y — so window w+1's fast kernel has no data dependence on
    #    window w's slow kernel and can only be held back by the fence.
    def run_pipelined(depth: int, rounds: int = 64):
        srv = ServingRuntime(2, 1, memo=True, max_inflight_windows=depth)
        try:
            t = srv.tenant("t0")
            X = t.buffer((W,), init=np.zeros(W), name="X")
            Y = t.buffer((W,), init=np.arange(W, dtype=np.float64), name="Y")

            def fast(chunk, v):
                v.set(chunk, v.get(chunk) + 1.0)

            def slow(chunk, v):
                time.sleep(5e-4)
                v.set(chunk, v.get(chunk) + 2.0)

            def window():
                t.submit("fast", (W,), [read_write(X, one_to_one())], fast)
                t.submit("slow", (W,), [read_write(Y, one_to_one())], slow)
                return t.run()

            for _ in range(8):          # warm past the capture fixpoint
                window().wait()
            burst, lat = 4, []
            t0 = time.perf_counter()
            for _ in range(rounds // burst):
                hs = [(time.perf_counter(), window()) for _ in range(burst)]
                for s0, h in hs:
                    h.wait()
                    lat.append((time.perf_counter() - s0) * 1e6)
            wall = time.perf_counter() - t0
            stats = srv.memo_stats()
            peak = max(stats["tenants"]["t0"]["window_peak"].values())
            return float(np.percentile(lat, 99)), len(lat) / wall, peak
        finally:
            srv.shutdown()

    pipe: dict[int, tuple[float, float, int]] = {}
    for _ in range(2):                  # interleaved best-of-2 (min p99)
        for depth in (1, 2):
            r = run_pipelined(depth)
            if depth not in pipe:
                pipe[depth] = r
            else:
                pipe[depth] = (min(pipe[depth][0], r[0]),
                               max(pipe[depth][1], r[1]),
                               max(pipe[depth][2], r[2]))
    assert pipe[2][2] >= 2, \
        f"depth-2 serving never overlapped windows (peak={pipe[2][2]})"
    for depth in (1, 2):
        p99, rps, peak = pipe[depth]
        emit(f"serve/pipelined_depth{depth}", p99,
             f"p99={p99:.0f}us;rps={rps:.0f};inflight_peak={peak}")
    SCHED_JSON["serve_p99_depth1_us"] = pipe[1][0]
    SCHED_JSON["serve_p99_pipelined_us"] = pipe[2][0]
    SCHED_JSON["serve_pipelined_req_per_s"] = pipe[2][1]
    SCHED_JSON["serve_inflight_windows"] = float(pipe[2][2])


# ---------------------------------------------------------------------------
# schedule sanitizer (DESIGN.md §14): concurrent-verification overhead


def bench_verify() -> None:
    """Cost of ``Runtime(verify="window")`` on the executor issue path.

    Window verification runs on a dedicated worker thread concurrent with
    the executor draining the same window, so the budget is <= 5% overhead
    against ``verify="off"``, measured the same way ``executor_issue_us``
    is (end-to-end wall over instructions issued, best-of-N minimum —
    container noise is additive, the min is the signal).  Capture is the
    only work the issue path pays for synchronously; the rest of the
    sanitizer cost is the worker's concurrent GIL share plus a ~2 ms
    finalize at sync.  Reps run interleaved (off, window) back to back so
    machine drift hits both variants.  ``verify_window_us`` is the mean
    per-window check wall time (gated by the CI perf baseline);
    ``verify_overhead_pct`` is the end-to-end delta (informational — its
    run-to-run noise exceeds the true ~3% overhead).
    """
    steps, n = 200, 2048

    def run(verify: str) -> tuple[float, float, int, float]:
        with Runtime(num_nodes=1, devices_per_node=2, horizon_step=8,
                     verify=verify) as rt:
            X = rt.buffer((n,), init=np.zeros(n), name="X")
            Y = rt.buffer((n,), init=np.zeros(n), name="Y")

            def bump(chunk, v):
                v.set(chunk, v.get(chunk) + 1.0)

            t0 = time.perf_counter()
            for s in range(steps):
                rt.submit(f"kx{s}", (n,), [read_write(X, one_to_one())], bump)
                rt.submit(f"ky{s}", (n,), [read_write(Y, one_to_one())], bump)
            rt.sync(timeout=300)
            wall = time.perf_counter() - t0
            n_instr = rt.total_instructions()
            vus = 0.0
            if verify == "window":
                h = rt.metrics_registry.snapshot()["histograms"].get(
                    "verify.window_us")
                if h and h["count"]:
                    vus = h["sum_us"] / h["count"]
        return wall / n_instr * 1e6, n_instr, vus

    pairs: list[tuple[tuple[float, int, float],
                      tuple[float, int, float]]] = []
    for _ in range(9):                   # 9 paired reps (single runs are noise)
        pairs.append((run("off"), run("window")))
    best_off = min((o for o, _ in pairs), key=lambda r: r[0])
    best_win = min((w for _, w in pairs), key=lambda r: r[0])
    off_us, win_us = best_off[0], best_win[0]
    pct = 100.0 * (win_us - off_us) / off_us
    emit("verify/issue_off", off_us, f"instr={best_off[1]}")
    emit("verify/issue_window", win_us,
         f"instr={best_win[1]};overhead_pct={pct:+.1f};budget=5.0")
    vus = sorted(w[2] for _, w in pairs)[len(pairs) // 2]
    emit("verify/window_check", vus, "median-rep mean per-window sanitizer wall")
    SCHED_JSON["verify_window_us"] = vus
    SCHED_JSON["verify_overhead_pct"] = pct


def export_dots(prefix: Path) -> None:
    """--dot PREFIX: write TDAG/CDAG/IDAG Graphviz exports of a
    representative program (wave + reduction on a 2x2 grid) next to
    ``PREFIX`` as ``PREFIX.{tdag,cdag,idag}.dot``; any sanitizer findings
    on the lowered graph are highlighted in the IDAG render."""
    from repro.core import (IdagGenerator, TaskGraph, VirtualBuffer,
                            cdag_to_dot, generate_cdag, idag_to_dot,
                            tdag_to_dot, verify_graph)
    from repro.core.command_graph import CommandType
    from repro.core.dot import write_dot

    nodes, devs, nn = 2, 2, 64
    tdag = TaskGraph(horizon_step=2)
    u0 = VirtualBuffer((nn,), name="u0", initial_value=np.zeros(nn))
    u1 = VirtualBuffer((nn,), name="u1", initial_value=np.zeros(nn))
    E = VirtualBuffer((1,), name="E", initial_value=np.zeros(1))
    cur, nxt = u0, u1
    for s in range(3):
        tdag.submit(f"step{s}", (nn,), [read(cur, all_range()),
                                        write(nxt, one_to_one())])
        tdag.submit(f"E{s}", (nn,), [read(nxt, one_to_one()),
                                     reduction(E, "sum")])
        cur, nxt = nxt, cur
    gen = generate_cdag(tdag, nodes)
    node_instrs, pilots = [], []
    for rank in range(nodes):
        idag = IdagGenerator(rank, devs)
        for cmd in gen.commands[rank]:
            if cmd.ctype == CommandType.EPOCH and cmd.task is None:
                continue
            idag.compile(cmd)
        node_instrs.append(idag.instructions)
        pilots.extend(idag.pilots)
    rep = verify_graph(node_instrs, pilots=pilots)
    cmds = [c for cs in gen.commands for c in cs]
    for suffix, text in (
            ("tdag", tdag_to_dot(tdag)),
            ("cdag", cdag_to_dot(cmds)),
            ("idag", idag_to_dot(node_instrs, issues=rep.issues))):
        p = write_dot(f"{prefix}.{suffix}.dot", text)
        print(f"# wrote {p}", file=sys.stderr)


BENCHES = {
    "bench_strong_scaling": bench_strong_scaling,
    "bench_overlap": bench_overlap,
    "bench_lookahead": bench_lookahead,
    "bench_executor_latency": bench_executor_latency,
    "bench_reduction": bench_reduction,
    "bench_collective": bench_collective,
    "bench_memory": bench_memory,
    "bench_faults": bench_faults,
    "bench_scheduler_throughput": bench_scheduler_throughput,
    "bench_observability": bench_observability,
    "bench_serve": bench_serve,
    "bench_verify": bench_verify,
    "bench_roofline": bench_roofline,
}


def main() -> None:
    global TRACE_PATH
    argv = sys.argv[1:]
    if "--trace" in argv:
        i = argv.index("--trace")
        if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
            sys.exit("--trace requires an output path (e.g. --trace out.json)")
        TRACE_PATH = Path(argv[i + 1])
        argv = argv[:i] + argv[i + 2:]
    if "--dot" in argv:
        i = argv.index("--dot")
        if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
            sys.exit("--dot requires an output prefix (e.g. --dot out/wave)")
        export_dots(Path(argv[i + 1]))
        argv = argv[:i] + argv[i + 2:]
        if not [a for a in argv if a != "--json"]:
            return                       # --dot alone: export only
    args = [a for a in argv if a != "--json"]
    write_json = "--json" in argv
    names = args or list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()
    if write_json and SCHED_JSON:
        out = ROOT / "BENCH_scheduler.json"
        data: dict = {}
        if out.exists():                 # keep e.g. the pre-PR baseline keys
            try:
                data = json.loads(out.read_text())
            except ValueError:
                data = {}
        data.update(SCHED_JSON)
        out.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
