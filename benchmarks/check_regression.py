"""CI perf-regression gate (DESIGN.md §11.5).

Compares a freshly generated ``BENCH_scheduler.json`` against the committed
baseline and fails when any tracked latency key (``*_us``) regresses by more
than the tolerance (default 25% — wide enough for shared-runner noise, tight
enough to catch an accidental O(n) slip on the issue path).

Two key classes are gated, by suffix:

  * ``*_us`` — latencies, lower is better: regression iff
    ``fresh > base * (1 + tol)``
  * ``*_occupancy`` / ``*_inflight_windows`` — pipelining depth, higher is
    better: regression iff ``fresh < base * (1 - tol)``

Other throughput keys (``*_per_s``) and structural counts
(``peak_retained_*``, ``*_msgs``) have their own acceptance tests, and
nested dicts (e.g. the ``baseline_pre_pr`` archive) are skipped.

Usage:  python benchmarks/check_regression.py BASELINE.json FRESH.json
        [--tolerance 0.25]
"""

from __future__ import annotations

import json
import sys
from pathlib import Path


# suffixes where a LOWER fresh value is the regression (utilization /
# pipelining-depth metrics, DESIGN.md §13)
HIGHER_IS_BETTER = ("_occupancy", "_inflight_windows")


def gated_keys(baseline: dict, fresh: dict) -> list[str]:
    """Tracked keys: numeric ``*_us`` / higher-is-better values present in
    both snapshots."""
    out = []
    for key, base in baseline.items():
        if not (key.endswith("_us") or key.endswith(HIGHER_IS_BETTER)):
            continue
        if not isinstance(base, (int, float)):
            continue
        if not isinstance(fresh.get(key), (int, float)):
            continue
        out.append(key)
    return sorted(out)


def compare(baseline: dict, fresh: dict,
            tolerance: float) -> tuple[list[str], list[str]]:
    """Returns (regressions, report_lines)."""
    regressions: list[str] = []
    lines: list[str] = []
    keys = gated_keys(baseline, fresh)
    if not keys:
        lines.append("no comparable *_us keys — nothing gated")
        return regressions, lines
    for key in keys:
        base, new = float(baseline[key]), float(fresh[key])
        if base <= 0:
            continue
        ratio = new / base
        higher_better = key.endswith(HIGHER_IS_BETTER)
        status = "ok"
        if higher_better:
            if ratio < 1.0 - tolerance:
                status = "REGRESSION"
                regressions.append(key)
            elif ratio > 1.0 + tolerance:
                status = "improved"
        elif ratio > 1.0 + tolerance:
            status = "REGRESSION"
            regressions.append(key)
        elif ratio < 1.0 - tolerance:
            status = "improved"
        lines.append(f"  {key:<40} {base:12.1f} -> {new:12.1f}  "
                     f"({ratio:6.2f}x)  {status}"
                     + ("  [higher=better]" if higher_better else ""))
    return regressions, lines


def main(argv: list[str]) -> int:
    tolerance = 0.25
    if "--tolerance" in argv:
        i = argv.index("--tolerance")
        tolerance = float(argv[i + 1])
        argv = argv[:i] + argv[i + 2:]
    if len(argv) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    baseline = json.loads(Path(argv[0]).read_text())
    fresh = json.loads(Path(argv[1]).read_text())
    regressions, lines = compare(baseline, fresh, tolerance)
    print(f"perf gate: {argv[0]} vs {argv[1]} "
          f"(tolerance +{tolerance:.0%})")
    for ln in lines:
        print(ln)
    if regressions:
        print(f"FAIL: {len(regressions)} key(s) regressed "
              f">{tolerance:.0%}: {', '.join(regressions)}")
        return 1
    print("PASS: no tracked latency key regressed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
