"""InternVL2-26B — InternViT (stub: precomputed patch embeddings) +
InternLM2-20B language backbone [arXiv:2404.16821; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92553,
    vis_tokens=256, rope_theta=1e6, mlp="swiglu",
)
