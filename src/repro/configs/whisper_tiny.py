"""Whisper-tiny — enc-dec backbone; conv/mel frontend is a stub
(precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    enc_layers=4, enc_frames=1500,
    rope_theta=0.0, mlp="gelu", tie_embeddings=True,
)
