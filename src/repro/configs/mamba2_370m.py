"""Mamba2-370M — pure SSM with state-space duality
[arXiv:2405.21060]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_chunk=64,
    rope_theta=0.0, tie_embeddings=True,
)
