"""Zamba2-7B — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].  81 SSM layers; the shared full-attention block is
invoked every ``attn_every`` layers (81 = 27 groups x 3)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_chunk=64, attn_every=3,
    rope_theta=1e4, mlp="swiglu",
)
