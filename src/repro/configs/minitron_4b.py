"""Minitron-4B — pruned Nemotron, dense GQA(kv=8), 256k vocab
[arXiv:2407.14679; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=9216, vocab_size=256000,
    rope_theta=1e4, mlp="swiglu", head_dim=128, tie_embeddings=True,
)
