"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the exact published config; ``get_config(name,
reduced=True)`` returns the smoke-test-sized variant of the same family.
"""

from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

ARCHITECTURES = [
    "starcoder2_3b",
    "minitron_4b",
    "h2o_danube_1_8b",
    "qwen2_1_5b",
    "granite_moe_1b_a400m",
    "granite_moe_3b_a800m",
    "zamba2_7b",
    "mamba2_370m",
    "whisper_tiny",
    "internvl2_26b",
]

# canonical CLI ids (dash form) -> module name
ALIASES = {a.replace("_", "-"): a for a in ARCHITECTURES}
ALIASES.update({
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen2-1.5b": "qwen2_1_5b",
})

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# archs that support long_500k (sub-quadratic attention path); pure
# full-attention archs skip it — recorded in DESIGN.md §Arch-applicability
LONG_CONTEXT_OK = {"h2o_danube_1_8b", "zamba2_7b", "mamba2_370m"}


def get_config(name: str, *, reduced: bool = False) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ArchConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def cells(arch: str) -> list[str]:
    """Shape names applicable to ``arch`` (all 4 unless long_500k is skipped
    for a pure full-attention family — still 40 total across the pool since
    the spec counts 4 shapes per arch; inapplicable ones are *reported* as
    skipped in the dry-run table)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if ALIASES.get(arch, arch).replace("-", "_") in LONG_CONTEXT_OK:
        out.append("long_500k")
    return out


ALL_CELLS = [(a, s) for a in ARCHITECTURES for s in SHAPES]
