from .rules import param_shardings, batch_shardings, cache_shardings
from .partition import named, data_axes, model_axis

__all__ = ["param_shardings", "batch_shardings", "cache_shardings",
           "named", "data_axes", "model_axis"]
