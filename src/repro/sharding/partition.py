"""NamedSharding helpers over the production mesh."""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> tuple:
    """All batch-parallel axes: ('pod', 'data') on multi-pod, ('data',) else."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: Mesh) -> str:
    return "model"


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
