"""Logical-to-mesh sharding rules for every parameter / input / cache tensor.

The rule table below maps parameter tree paths (regex over '/'-joined keys)
to *logical* PartitionSpecs; ``_fit`` then drops any axis whose size does not
divide the corresponding tensor dimension (e.g. 2 KV heads cannot shard over
a 16-way model axis) — the standard fallback used by production frameworks.

Scheme (Megatron-style TP over 'model', DP over ('pod','data'), EP for MoE
experts over 'model', ZeRO-1 handled in optim):
  * embeddings / lm head        -> vocab-sharded over model
  * attention wq/wk/wv          -> output(heads)-sharded; wo input-sharded
  * MLP wi/wg                   -> d_ff-sharded; wo input-sharded
  * MoE expert weights [E,D,F]  -> expert-sharded over model (EP)
  * Mamba in/out projections    -> inner-dim sharded
  * norms / scalars             -> replicated
Stacked-layer params carry a leading L axis (never sharded).
"""

from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .partition import data_axes

# (path regex, spec WITHOUT the leading stacked-layer axis)
# "D" placeholder = the data axes tuple, "M" = the model axis.
_RULES: list[tuple[str, tuple]] = [
    (r"embed/e$",                ("M", None)),          # vocab-sharded
    (r"head/w$",                 (None, "M")),
    (r"pos_dec$",                (None, None)),
    (r"(attn|xattn)/w[qkv]/w$",  (None, "M")),
    (r"(attn|xattn)/w[qkv]/b$",  ("M",)),
    (r"(attn|xattn)/wo/w$",      ("M", None)),
    (r"(attn|xattn)/wo/b$",      (None,)),
    (r"mlp/w[ig]/w$",            (None, "M")),
    (r"mlp/wo/w$",               ("M", None)),
    (r"moe/router/w$",           (None, None)),
    (r"moe/w[ig]$",              ("M", None, None)),    # expert-parallel
    (r"moe/wo$",                 ("M", None, None)),
    (r"in_proj/w$",              (None, "M")),
    (r"out_proj/w$",             ("M", None)),
    (r"conv_w$",                 (None, "M")),
    (r"conv_b$",                 ("M",)),
    (r"(A_log|dt_bias)$",        ("M",)),
    (r"/D$",                     ("M",)),
    (r"proj/w[12]/w$",           (None, "M")),
    (r"(ln1|ln2|lnx|ln|ln_f|ln_enc|norm)/g$", None),    # replicated
]


def _fit(spec_tpl, shape, mesh: Mesh, extra_leading: int) -> P:
    """Materialize a rule into a PartitionSpec that divides ``shape``."""
    if spec_tpl is None:
        return P()
    dp = data_axes(mesh)
    entries: list = [None] * extra_leading
    for axis_tag in spec_tpl:
        if axis_tag is None:
            entries.append(None)
        elif axis_tag == "M":
            entries.append("model")
        elif axis_tag == "D":
            entries.append(dp)
        else:
            entries.append(axis_tag)
    entries = entries[:len(shape)] + [None] * max(0, len(shape) - len(entries))
    # drop axes that do not divide the dim
    out = []
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(e if dim % size == 0 else None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for_param(path: str, shape, mesh: Mesh) -> P:
    # stacked layers carry 1 leading L axis; zamba groups carry none extra
    leading = 1 if re.search(r"(^|/)(layers|enc|dec)/", path) else 0
    for pat, tpl in _RULES:
        if re.search(pat, path):
            return _fit(tpl, shape, mesh, leading)
    return P()   # replicate by default


def param_shardings(param_tree, mesh: Mesh):
    """NamedShardings for a parameter pytree (works on ShapeDtypeStructs)."""

    def one(path, leaf):
        spec = spec_for_param(_path_str(path), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, param_tree)


def batch_shardings(batch_tree, mesh: Mesh):
    """Training/prefill batch: leading dim sharded over all data axes."""
    dp = data_axes(mesh)

    def one(leaf):
        if leaf.shape and leaf.shape[0] % _size(mesh, dp) == 0:
            return NamedSharding(mesh, P(dp, *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_tree)


def _size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def cache_shardings(cache_tree, mesh: Mesh, *, batch_dim: int = 1):
    """Decode caches: [L, B, T, K, hd] — shard batch over data axes and the
    kv-head dim over model when divisible (falls back per-dim)."""
    dp = data_axes(mesh)

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        spec: list = [None] * len(shape)
        if ps.endswith("pos") or not shape:
            return NamedSharding(mesh, P())
        if ps.endswith("kpos"):
            return NamedSharding(mesh, P())
        if len(shape) >= 2 and shape[batch_dim] % _size(mesh, dp) == 0:
            spec[batch_dim] = dp
        # shard kv heads (dim -2 of k/v; dim 2 of ssm [L,B,h,p,n]) over model
        for cand in (len(shape) - 2, 2):
            if 0 <= cand < len(shape) and spec[cand] is None and cand != batch_dim:
                if shape[cand] % mesh.shape["model"] == 0 and shape[cand] > 1:
                    spec[cand] = "model"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
