"""AdamW with optional ZeRO-1 sharding of the optimizer state.

Pure-pytree implementation (no optax): ``state = {m, v, step}``.  Under
ZeRO-1 the first/second-moment tensors are additionally sharded over the
*data* axes on their largest divisible dimension — each data-parallel rank
keeps only its shard of the optimizer state, which XLA turns into
reduce-scatter(grads) + all-gather(params) around the update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.partition import data_axes
from repro.sharding.rules import param_shardings


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, *, lr=3e-4, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    step = state["step"] + 1
    # global-norm clip
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / (gn + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gn


def zero1_shardings(param_tree, mesh: Mesh):
    """Shardings for the optimizer state: params' TP sharding PLUS data-axis
    sharding on the largest still-unsharded divisible dim (ZeRO-1)."""
    pshard = param_shardings(param_tree, mesh)
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def one(leaf, ns):
        spec = list(ns.spec) + [None] * (len(leaf.shape) - len(ns.spec))
        # choose the largest unsharded dim divisible by the data axes
        best, best_dim = -1, None
        for i, (dim, s) in enumerate(zip(leaf.shape, spec)):
            if s is None and dim % dp_size == 0 and dim > best:
                best, best_dim = dim, i
        if best_dim is not None and dp:
            spec[best_dim] = dp if len(dp) > 1 else dp[0]
        return NamedSharding(mesh, P(*spec))

    moments = jax.tree.map(one, param_tree, pshard)
    return {"m": moments, "v": moments,
            "step": NamedSharding(mesh, P())}
