"""Gradient compression: int8 block quantization with error feedback.

Used by the training loop when gradient compression is on: gradients are
quantized to int8 (per-block absmax scales) before crossing the data axes,
and the quantization error is fed back into the next step's gradients —
the standard trick that keeps convergence while cutting all-reduce bytes 4x.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quant(x: jnp.ndarray):
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads(grads, error_feedback=None):
    """int8-compress a gradient pytree.

    Returns ``(comp, new_error_feedback)`` where ``comp`` is a dict of leaf
    lists ({"q": [...], "s": [...]}) plus the treedef, and the error feedback
    has the gradients' own tree structure.
    """
    leaves, treedef = jax.tree.flatten(grads)
    if error_feedback is not None:
        err_leaves = treedef.flatten_up_to(error_feedback)
        leaves = [g.astype(jnp.float32) + e for g, e in zip(leaves, err_leaves)]
    qs, ss = [], []
    err = []
    for g in leaves:
        q, s = _quant(g)
        qs.append(q)
        ss.append(s)
        err.append(g.astype(jnp.float32) - _dequant(q, s, g.shape))
    shapes = [g.shape for g in leaves]
    comp = {"q": qs, "s": ss, "shapes": shapes, "treedef": treedef}
    return comp, jax.tree.unflatten(treedef, err)


def decompress_grads(comp):
    leaves = [_dequant(q, s, shape)
              for q, s, shape in zip(comp["q"], comp["s"], comp["shapes"])]
    return jax.tree.unflatten(comp["treedef"], leaves)


def compression_ratio(grads) -> float:
    """Bytes(int8+scales) / bytes(fp32) for reporting."""
    total_in = sum(g.size * 4 for g in jax.tree.leaves(grads))
    total_out = sum(g.size + (g.size + BLOCK - 1) // BLOCK * 4
                    for g in jax.tree.leaves(grads))
    return total_out / total_in
