from .adamw import adamw_init, adamw_update, zero1_shardings
from .compress import compress_grads, decompress_grads

__all__ = ["adamw_init", "adamw_update", "zero1_shardings",
           "compress_grads", "decompress_grads"]
