from .pipeline import SyntheticLMData, Prefetcher

__all__ = ["SyntheticLMData", "Prefetcher"]
