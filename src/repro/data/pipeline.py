"""Deterministic, shard-aware, resumable synthetic data pipeline.

Every batch is a pure function of ``(seed, step, dp_rank)`` — so a restart
from a checkpoint at step k, or an elastic reshard onto a different
data-parallel width, reproduces the exact token stream with no state to
persist beyond the step counter.

The ``Prefetcher`` runs the generator in a host thread with a bounded queue,
giving the compute/IO overlap the macro training loop schedules around.
"""

from __future__ import annotations

import queue
import threading
from typing import Optional

import numpy as np

from repro.models.config import ArchConfig
from repro.models.internvl import D_VIS


class SyntheticLMData:
    """Synthetic power-law token stream with next-token labels."""

    def __init__(self, cfg: ArchConfig, global_batch: int, seq_len: int,
                 *, seed: int = 0):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed

    def local_batch(self, step: int, dp_rank: int = 0, dp_size: int = 1) -> dict:
        assert self.global_batch % dp_size == 0
        lb = self.global_batch // dp_size
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, dp_rank]))
        # zipf-ish marginal over the vocab, cheap and deterministic
        v = self.cfg.vocab_size
        u = rng.random((lb, self.seq_len))
        toks = np.minimum((u ** 3 * v).astype(np.int32), v - 1)
        out = {"tokens": toks, "labels": toks}
        if self.cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (lb, self.cfg.enc_frames, self.cfg.d_model)).astype(np.float32)
        if self.cfg.family == "vlm":
            out["vis"] = rng.standard_normal(
                (lb, self.cfg.vis_tokens, D_VIS)).astype(np.float32)
        return out


class Prefetcher:
    """Bounded-depth background prefetch of ``SyntheticLMData`` batches."""

    def __init__(self, data: SyntheticLMData, *, start_step: int = 0,
                 depth: int = 2, dp_rank: int = 0, dp_size: int = 1):
        self.data = data
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._dp = (dp_rank, dp_size)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.data.local_batch(step, *self._dp)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self, timeout: float = 30.0) -> tuple[int, dict]:
        return self._q.get(timeout=timeout)

    def stop(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
