"""Fault tolerance and elasticity for the macro training loop.

``ElasticTrainer`` wraps ``TrainLoop`` in a supervision loop: a step failure
(node loss, injected fault) triggers (1) rebuilding the device mesh from the
surviving hosts, (2) restoring the newest committed checkpoint — stored
logically-global, so restoring onto a *different* mesh shape is just a
device_put with the new shardings — and (3) resuming from that step.  The
data pipeline is a pure function of (seed, step), so the token stream is
bit-identical across restarts and reshards.

``rebalance_weights`` consumes the executor's per-queue EWMA latency report
(micro runtime) and produces new work-split weights — persistent stragglers
get proportionally smaller chunks on the next split (paper §4.1 latency
sensitivity, applied as mitigation).
"""

from __future__ import annotations

from typing import Optional

from ..core.faults import run_with_restarts
from .train_loop import TrainLoop, TrainMetrics


class ElasticTrainer:
    def __init__(self, make_loop, *, max_restarts: int = 3):
        """``make_loop(world_size) -> TrainLoop`` — the factory is re-invoked
        with the surviving world size after every failure."""
        self.make_loop = make_loop
        self.max_restarts = max_restarts

    def run(self, num_steps: int, *, world_size: int = 4,
            fail_at: Optional[int] = None,
            lose_nodes_on_failure: int = 1) -> tuple[dict, TrainMetrics, int]:
        # one TrainMetrics for the whole supervised run: ``loop.run``
        # mutates it in place, so progress survives across restarts
        metrics = TrainMetrics()
        ctx = {"world": world_size, "fail_at": fail_at}

        def attempt(restarts: int) -> dict:
            loop = self.make_loop(ctx["world"])
            start, state = loop.restore_or_init()
            remaining = num_steps - start
            if remaining <= 0:
                return state
            _, state, _ = loop.run(remaining, start_step=start, state=state,
                                   metrics=metrics, fail_at=ctx["fail_at"])
            return state

        def on_failure(err: BaseException, restarts: int) -> None:
            metrics.restarts = restarts
            # a failure costs us nodes: rebuild smaller and restore
            ctx["world"] = max(1, ctx["world"] - lose_nodes_on_failure)
            ctx["fail_at"] = None   # the fault was transient

        state, _ = run_with_restarts(attempt, on_failure,
                                     max_restarts=self.max_restarts,
                                     recoverable=(RuntimeError,))
        return state, metrics, ctx["world"]


def rebalance_weights(report: dict[str, float],
                      *, floor: float = 0.25) -> dict[str, float]:
    """Inverse-latency work weights from a straggler report.

    ``report`` maps queue name -> EWMA seconds per instruction.  Returns
    normalized weights; a queue twice as slow gets half the work, floored so
    no device is starved entirely.
    """
    lanes = {k: v for k, v in report.items() if k.startswith("device")}
    if not lanes:
        return {}
    inv = {k: 1.0 / max(v, 1e-9) for k, v in lanes.items()}
    mean = sum(inv.values()) / len(inv)
    weights = {k: max(v / mean, floor) for k, v in inv.items()}
    total = sum(weights.values())
    return {k: v * len(weights) / total for k, v in weights.items()}
