"""Batched serving loop: continuous-batching-lite over a fixed slot grid.

Requests enter a queue; the loop packs up to ``max_batch`` prompts, runs one
prefill, then decodes all slots in lock-step until every request has either
finished (EOS/max tokens) or been replaced.  Per-slot completion uses the
position bookkeeping in the model caches; finished slots are refilled from
the queue between decode rounds (batch-level continuous batching).
"""

from __future__ import annotations

import itertools
import queue
import threading
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                     # [S] int32
    max_new: int = 16
    done: threading.Event = field(default_factory=threading.Event)
    output: list = field(default_factory=list)


class ServeLoop:
    def __init__(self, cfg, params=None, *, max_batch: int = 4,
                 max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params if params is not None else \
            self.model.init(jax.random.PRNGKey(seed))
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: "queue.Queue[Request]" = queue.Queue()
        # submit() is called from many client threads: itertools.count is
        # atomic under the GIL, unlike the read-modify-write `_rid += 1`
        # which could hand two threads the same rid (and lose a request to
        # anyone keying on it)
        self._rids = itertools.count(1)
        self._decode = jax.jit(self.model.decode_step)
        self.stats = {"batches": 0, "decode_steps": 0, "requests": 0}

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> Request:
        req = Request(next(self._rids), np.asarray(prompt, np.int32), max_new)
        self.queue.put(req)
        return req

    def _take_batch(self) -> list[Request]:
        out = []
        try:
            out.append(self.queue.get_nowait())
        except queue.Empty:
            return out
        while len(out) < self.max_batch:
            try:
                out.append(self.queue.get_nowait())
            except queue.Empty:
                break
        return out

    def run_until_idle(self) -> None:
        """Serve everything currently queued (used by tests/examples)."""
        while True:
            reqs = self._take_batch()
            if not reqs:
                return
            self._serve_batch(reqs)

    def _serve_batch(self, reqs: list[Request]) -> None:
        self.stats["batches"] += 1
        self.stats["requests"] += len(reqs)
        B = len(reqs)
        # left-pad prompts to a common length with token 0
        S = max(len(r.prompt) for r in reqs)
        ids = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            ids[i, S - len(r.prompt):] = r.prompt
        logits, cache = self.model.prefill(self.params, jnp.asarray(ids),
                                           max_len=self.max_len)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        live = np.ones(B, bool)
        produced = np.zeros(B, np.int32)
        while live.any():
            for i, r in enumerate(reqs):
                if live[i]:
                    r.output.append(int(tok[i]))
                    produced[i] += 1
                    if produced[i] >= r.max_new:
                        live[i] = False
                        r.done.set()
            if not live.any():
                break
            logits, cache = self._decode(self.params, cache, tok[:, None])
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.stats["decode_steps"] += 1
