"""Macro-scale training loop, orchestrated by the paper's IDAG machinery.

The instruction-graph runtime from ``repro.core`` schedules the *host-side*
stages of each training step — data prefetch into a staging ring, the jitted
``train_step`` dispatch, and asynchronous checkpoint I/O — as tasks over
virtual buffers.  The same dependency analysis that overlaps coherence
copies with kernels in the micro runtime here overlaps batch generation and
checkpoint writes with device compute:

  * ``stage[t % depth]``   written by prefetch task t, read by step task t —
    the WAR hazard between step t and prefetch t+depth is exactly the ring
    dependency the TDAG derives from the accessors;
  * checkpoint tasks read a ``ckpt_token`` buffer that step tasks write,
    serializing snapshots against parameter updates without blocking
    subsequent steps (the save itself is async in CheckpointManager).

On this CPU container the jitted step runs on the host; on a TPU deployment
the same loop drives pjit-compiled steps over the production mesh —
inside-step distribution belongs to XLA (see DESIGN.md §2).
"""

from __future__ import annotations

import queue as _queue
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import (Box, Runtime, fixed, one_to_one, read, read_write,
                        write)
from repro.core.task_graph import TaskType
from repro.data import SyntheticLMData
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import adamw_init


@dataclass
class TrainMetrics:
    steps: list = field(default_factory=list)
    losses: list = field(default_factory=list)
    restarts: int = 0

    def log(self, step, loss):
        self.steps.append(int(step))
        self.losses.append(float(loss))


class TrainLoop:
    def __init__(self, cfg, *, global_batch: int, seq_len: int,
                 ckpt_dir=None, ckpt_interval: int = 50, lr: float = 3e-4,
                 prefetch_depth: int = 2, seed: int = 0):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.model = build_model(cfg)
        self.data = SyntheticLMData(cfg, global_batch, seq_len, seed=seed)
        self.depth = prefetch_depth
        self.lr = lr
        self.ckpt = (CheckpointManager(ckpt_dir, interval=ckpt_interval)
                     if ckpt_dir else None)
        self.train_step = jax.jit(make_train_step(self.model, lr=lr),
                                  donate_argnums=(0, 1))

    # -- state ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        return {"params": params, "opt": adamw_init(params)}

    def restore_or_init(self):
        """Checkpoints are taken AFTER step t completes, so a restore from
        step t resumes at t+1."""
        if self.ckpt is not None and self.ckpt.latest is not None:
            step, state = self.ckpt.restore_or_init(lambda: self.init_state())
            return step + 1, state
        return 0, self.init_state()

    # -- the IDAG-orchestrated run ------------------------------------------------
    def run(self, num_steps: int, *, start_step: Optional[int] = None,
            state=None, metrics: Optional[TrainMetrics] = None,
            fail_at: Optional[int] = None) -> tuple[int, dict, TrainMetrics]:
        metrics = metrics or TrainMetrics()
        if state is None:
            start_step, state = self.restore_or_init()
        assert start_step is not None
        holder = {"state": state}
        results: "_queue.SimpleQueue" = _queue.SimpleQueue()

        try:
            self._run_body(num_steps, start_step, holder, results, fail_at)
        finally:
            # drain metrics and finish in-flight checkpoint I/O even on the
            # failure path — a committed step must be restorable immediately
            while True:
                try:
                    t, loss = results.get_nowait()
                    metrics.log(t, loss)
                except _queue.Empty:
                    break
            if self.ckpt is not None:
                self.ckpt.wait()
        return start_step + num_steps, holder["state"], metrics

    def _run_body(self, num_steps, start_step, holder, results, fail_at):
        with Runtime(num_nodes=1, devices_per_node=1, trace=True) as rt:
            B = self.global_batch
            stage = rt.buffer((self.depth, B, self.seq_len), dtype=np.int32,
                              name="stage",
                              init=np.zeros((self.depth, B, self.seq_len),
                                            np.int32))
            token = rt.buffer((1,), name="ckpt_token", init=np.zeros(1))

            def slot_region(t):
                return Box((t % self.depth, 0, 0),
                           (t % self.depth + 1, B, self.seq_len))

            for t in range(start_step, start_step + num_steps):
                def prefetch(chunk, v, t=t):
                    batch = self.data.local_batch(t)
                    v.set(slot_region(t), batch["tokens"][None])

                rt.submit(f"prefetch{t}", (1,),
                          [write(stage, fixed(slot_region(t)))],
                          prefetch, ttype=TaskType.HOST)

                def step_fn(chunk, v, tok, t=t):
                    toks = np.asarray(v.get(slot_region(t))[0])
                    if fail_at is not None and t == fail_at:
                        raise RuntimeError(f"injected failure at step {t}")
                    batch = {"tokens": toks, "labels": toks}
                    s = holder["state"]
                    p, o, m = self.train_step(s["params"], s["opt"], batch)
                    holder["state"] = {"params": p, "opt": o}
                    results.put((t, float(m["loss"])))
                    tok[0] = float(t)

                rt.submit(f"step{t}", (1,),
                          [read(stage, fixed(slot_region(t))),
                           read_write(token, one_to_one())],
                          step_fn, ttype=TaskType.HOST)

                if self.ckpt is not None and self.ckpt.should_save(t):
                    def ckpt_fn(chunk, tok, t=t):
                        self.ckpt.save(t, holder["state"])

                    rt.submit(f"ckpt{t}", (1,),
                              [read(token, one_to_one())],
                              ckpt_fn, ttype=TaskType.HOST)
            rt.sync(timeout=600)
            self.overlap = (rt.tracer.overlap_fraction("N0.host", "N0.host")
                            if rt.tracer else 0.0)


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          ckpt_dir=None, **kw) -> TrainMetrics:
    loop = TrainLoop(cfg, global_batch=global_batch, seq_len=seq_len,
                     ckpt_dir=ckpt_dir, **kw)
    _, _, metrics = loop.run(steps)
    return metrics
