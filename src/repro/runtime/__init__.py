from .train_loop import TrainLoop, train
from .serve_loop import ServeLoop
from .elastic import ElasticTrainer, rebalance_weights

__all__ = ["TrainLoop", "train", "ServeLoop", "ElasticTrainer",
           "rebalance_weights"]
