"""Instruction graph (IDAG) generation — the paper's core contribution (§3).

Compiles each node's command stream into micro-operations: ``alloc / copy /
free / send / receive / split-receive / await-receive / device-kernel /
host-task / horizon / epoch``.  Key mechanisms implemented faithfully:

* hierarchical work assignment — the command chunk is split a second time
  over the node's local devices (§3.1);
* virtualized buffers with multiple disjoint backing allocations per
  (buffer, memory); every accessor must be backed by one *contiguous*
  allocation, triggering alloc→copy→free resize chains when access patterns
  grow (§3.2, fig. 3);
* local coherence with producer- and consumer-split copies (§3.3);
* outbound transfers: producer-split sends + pilot messages; inbound:
  receive vs split-receive/await-receive under the union-only constraint of
  await-push commands (§3.4);
* horizon/epoch instructions for pruning and synchronization (§3.5);
* allocation widening driven by the scheduler lookahead (§4.3).
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

from .allocation import (Allocation, PINNED_HOST, USER_HOST, device_memory,
                         is_device_memory)
from .buffer import Accessor, VirtualBuffer
from .command_graph import Command, CommandType
from .reduction import Reduction
from .region import Box, Region, RegionMap, split_box
from .task_graph import DepKind, TaskType


class InstructionType(enum.Enum):
    ALLOC = "alloc"
    COPY = "copy"
    FREE = "free"
    SEND = "send"
    RECEIVE = "receive"
    SPLIT_RECEIVE = "split_receive"
    AWAIT_RECEIVE = "await_receive"
    # reduction pipeline (§2.2): identity-fill device scratch, combine device
    # partials per node, gather peer partials (multi-peer, pilot-driven,
    # fixed-stride slots) and fold them in canonical node order
    FILL_IDENTITY = "fill_identity"
    LOCAL_REDUCE = "local_reduce"
    GATHER_RECEIVE = "gather_receive"
    GLOBAL_REDUCE = "global_reduce"
    DEVICE_KERNEL = "device_kernel"
    HOST_TASK = "host_task"
    HORIZON = "horizon"
    EPOCH = "epoch"


_instr_ids = itertools.count()


@dataclass
class AccessorBinding:
    """Executor-facing: which allocation backs an accessor for one kernel."""
    accessor: Accessor
    allocation: Allocation
    region: Region                # buffer-space region the kernel may touch


@dataclass
class ReductionBinding:
    """Executor-facing: the identity-filled scratch a kernel reduces into."""
    reduction: Reduction
    allocation: Allocation        # per-device accumulator scratch


@dataclass
class Pilot:
    """Pilot message: announces an inbound transfer to the receiver (§3.4).

    ``transfer_id`` is ``(task id, buffer id)`` for push traffic and
    ``(task id, buffer id, 1)`` for reduction-gather traffic, so the two
    protocols never alias; the arbiter routes by transfer id and lands
    gather payloads at the fixed-stride slot of their *source* rank rather
    than at a buffer-space offset.  ``gather`` is wire metadata only (a
    real MPI transport would select the superaccumulator datatype from
    it); the in-process arbiter treats pilots as accounting.
    """
    source: int
    target: int
    transfer_id: tuple
    box: Box                      # buffer-space box being sent
    msg_id: int
    gather: bool = False          # reduction-gather transfer (metadata)


@dataclass
class Instruction:
    itype: InstructionType
    node: int
    # queue affinity: ("device", d) | ("host",) | ("comm",) — executor routing
    queue: tuple = ("host",)
    # ALLOC / FREE
    allocation: Optional[Allocation] = None
    # COPY
    src_alloc: Optional[Allocation] = None
    dst_alloc: Optional[Allocation] = None
    copy_box: Optional[Box] = None           # buffer-space box to copy
    # SEND
    dest: Optional[int] = None
    msg_id: Optional[int] = None
    send_box: Optional[Box] = None
    # RECEIVE / SPLIT_RECEIVE / AWAIT_RECEIVE / GATHER_RECEIVE
    transfer_id: Optional[tuple] = None
    recv_region: Optional[Region] = None
    recv_alloc: Optional[Allocation] = None
    split_parent: Optional["Instruction"] = None
    # reductions: FILL_IDENTITY fills ``allocation``; LOCAL_REDUCE folds
    # ``reduce_srcs`` into ``dst_alloc``; GATHER_RECEIVE expects one partial
    # per rank in ``gather_sources`` landed at slot=rank in ``recv_alloc``;
    # GLOBAL_REDUCE folds slots of ``src_alloc`` (+ own partial in
    # ``reduce_srcs``) over ``participants`` in node order into ``dst_alloc``
    reduction: Optional[Reduction] = None
    reduce_srcs: tuple[Allocation, ...] = ()
    gather_sources: tuple[int, ...] = ()
    participants: tuple[int, ...] = ()
    include_current: bool = False
    # DEVICE_KERNEL / HOST_TASK
    kernel_fn: Optional[Callable] = None
    chunk: Optional[Box] = None
    bindings: tuple[AccessorBinding, ...] = ()
    red_bindings: tuple[ReductionBinding, ...] = ()
    device: Optional[int] = None
    name: str = ""
    command: Optional[Command] = None
    iid: int = field(default_factory=lambda: next(_instr_ids))
    dependencies: list[tuple["Instruction", DepKind]] = field(default_factory=list)
    dependents: list["Instruction"] = field(default_factory=list)
    # set by the executor:
    state: str = "pending"

    def add_dependency(self, dep: "Instruction", kind: DepKind) -> None:
        if dep is self:
            return
        for d, _ in self.dependencies:
            if d is dep:
                return
        self.dependencies.append((dep, kind))
        dep.dependents.append(self)

    def __hash__(self) -> int:
        return self.iid

    def __repr__(self) -> str:
        extra = ""
        if self.itype == InstructionType.DEVICE_KERNEL:
            extra = f":{self.name}@D{self.device}"
        elif self.itype in (InstructionType.ALLOC, InstructionType.FREE):
            extra = f":{self.allocation}"
        elif self.itype == InstructionType.COPY:
            extra = f":{self.src_alloc and self.src_alloc.aid}->{self.dst_alloc and self.dst_alloc.aid}"
        return f"I{self.iid}<{self.itype.value}{extra}>"


@dataclass
class _MemState:
    """Per (buffer, memory) instruction-level tracking."""
    producers: RegionMap          # region -> original producer Instruction
    readers: list[tuple[Region, Instruction]] = field(default_factory=list)


class IdagGenerator:
    """Per-node instruction graph generator."""

    def __init__(self, node: int, num_devices: int, *, d2d: bool = True,
                 alloc_hints: Optional[dict] = None, retire: bool = False):
        self.node = node
        self.num_devices = num_devices
        self.d2d = d2d
        # ``retire=True`` (used by the runtime) trims ``instructions`` down to
        # the window since the last horizon/epoch, so generator memory stays
        # bounded on long runs; ``emitted_count`` keeps the lifetime total.
        self.retire = retire
        self.instructions: list[Instruction] = []
        self.emitted_count = 0
        self.alloc_count = 0
        self._batch: list[Instruction] = []
        self._frontier_pos = 0          # index of the last sync instruction
        self.pilots: list[Pilot] = []
        self.warnings: list[str] = []
        self._allocs: dict[tuple[int, int], list[Allocation]] = {}
        self._coherence: dict[int, RegionMap] = {}      # region -> frozenset(mids)
        self._mem: dict[tuple[int, int], _MemState] = {}
        # in-flight reduction state, keyed by reduction transfer id:
        # device partial scratches (+ producing kernels), the node partial
        # (+ its LOCAL_REDUCE) and the partial-broadcast sends
        self._red_state: dict[tuple, dict] = {}
        self._buffers: dict[int, VirtualBuffer] = {}
        self._msg_ids = itertools.count(node * 1_000_000)
        self._last_horizon: Optional[Instruction] = None
        self._last_epoch: Optional[Instruction] = None
        # lookahead-provided widening requirements: (bid, mid) -> Region
        self.alloc_hints: dict[tuple[int, int], Region] = alloc_hints or {}
        self._init_epoch = self._emit(Instruction(
            InstructionType.EPOCH, node=node, queue=("host",), name="init"))
        self._last_epoch = self._init_epoch

    # -- small helpers ---------------------------------------------------
    def _emit(self, instr: Instruction) -> Instruction:
        self.instructions.append(instr)
        self.emitted_count += 1
        if instr.itype == InstructionType.ALLOC:
            self.alloc_count += 1
        self._batch.append(instr)
        return instr

    def _register(self, buf: VirtualBuffer) -> None:
        if buf.bid not in self._buffers:
            self._buffers[buf.bid] = buf
            if buf.initial_value is not None:
                # data present in user host memory M0, produced by init epoch
                a = Allocation(mid=USER_HOST, bid=buf.bid, box=buf.full_box,
                               dtype=buf.dtype)
                a.initial_data = buf.initial_value  # type: ignore[attr-defined]
                self._allocs[(buf.bid, USER_HOST)] = [a]
                self._coherence[buf.bid] = RegionMap(buf.full_box,
                                                     default=frozenset([USER_HOST]))
                ms = self._memstate(buf.bid, USER_HOST)
                ms.producers.update(buf.full_region, self._init_epoch)
            else:
                self._coherence[buf.bid] = RegionMap(buf.full_box, default=frozenset())

    def _memstate(self, bid: int, mid: int) -> _MemState:
        ms = self._mem.get((bid, mid))
        if ms is None:
            buf = self._buffers[bid]
            ms = _MemState(producers=RegionMap(buf.full_box, default=self._init_epoch))
            self._mem[(bid, mid)] = ms
        return ms

    def _queue_for_mem(self, mid: int) -> tuple:
        if is_device_memory(mid):
            return ("device", mid - 2)
        return ("host",)

    # -- allocation management (§3.2) -------------------------------------
    def would_allocate_box(self, bid: int, mid: int, box: Box) -> bool:
        for a in self._allocs.get((bid, mid), []):
            if a.live and a.box.contains(box):
                return False
        return True

    def ensure_allocation(self, buf: VirtualBuffer, mid: int, box: Box) -> Allocation:
        """Return a live allocation whose box contains ``box``; emit
        alloc/copy/free resize chains if needed (fig. 3)."""
        self._register(buf)
        allocs = self._allocs.setdefault((buf.bid, mid), [])
        for a in allocs:
            if a.live and a.box.contains(box):
                return a
        # need a new allocation: merge with all overlapping live allocations
        # AND with lookahead widening hints, to a fixpoint — widening may
        # newly overlap allocations that the original request did not
        # (found by hypothesis, tests/test_lookahead_property.py)
        hint = self.alloc_hints.get((buf.bid, mid))
        new_box = box
        while True:
            overlapping = [a for a in allocs
                           if a.live and a.box.overlaps(new_box)]
            grown = new_box
            for a in overlapping:
                grown = grown.union_bbox(a.box)
            if hint is not None and not hint.is_empty():
                for hb in hint.boxes:
                    if hb.overlaps(grown) or any(a.box.overlaps(hb)
                                                 for a in overlapping):
                        grown = grown.union_bbox(hb)
                hint_bb = hint.bounding_box()
                if hint_bb.overlaps(grown):
                    grown = grown.union_bbox(hint_bb)
            if grown == new_box:
                break
            new_box = grown
        new_alloc = Allocation(mid=mid, bid=buf.bid, box=new_box, dtype=buf.dtype)
        alloc_instr = self._emit(Instruction(
            InstructionType.ALLOC, node=self.node, queue=self._queue_for_mem(mid),
            allocation=new_alloc, name=f"alloc {buf.name} M{mid} {new_box}"))
        if self._last_horizon is not None:
            alloc_instr.add_dependency(self._last_horizon, DepKind.SYNC)
        elif self._last_epoch is not None:
            alloc_instr.add_dependency(self._last_epoch, DepKind.SYNC)
        new_alloc.alloc_instr = alloc_instr  # type: ignore[attr-defined]
        ms = self._memstate(buf.bid, mid)
        # migrate live data from the old allocations into the new one
        coherent_here = self._region_coherent_in(buf.bid, mid)
        for old in overlapping:
            live_region = coherent_here.intersect_box(old.box)
            for sub, producer in ms.producers.query(live_region):
                for b in sub.boxes:
                    cp = self._emit_copy(buf, old, new_alloc, b, producer)
            free_instr = self._emit(Instruction(
                InstructionType.FREE, node=self.node, queue=self._queue_for_mem(mid),
                allocation=old, name=f"free {old}"))
            # free only after all users of the old allocation are done
            for r, reader in ms.readers:
                if r.overlaps(Region.from_box(old.box)):
                    free_instr.add_dependency(reader, DepKind.ANTI)
            for sub, producer in ms.producers.query(Region.from_box(old.box)):
                free_instr.add_dependency(producer, DepKind.ANTI)
            old.live = False
        self._allocs[(buf.bid, mid)] = [a for a in allocs if a.live] + [new_alloc]
        # producers of migrated regions are now the copies — but since the
        # copies carry the same data, we keep the original producer mapping;
        # dependency-wise, subsequent readers in this memory must depend on
        # the migration copies, which we ensure by updating producers to them.
        return new_alloc

    def _live_allocation(self, bid: int, mid: int, box: Box) -> Allocation:
        """The live allocation containing ``box`` (must exist)."""
        for a in self._allocs.get((bid, mid), []):
            if a.live and a.box.contains(box):
                return a
        raise AssertionError(f"no live allocation covers B{bid} M{mid} {box}")

    def _emit_copy(self, buf: VirtualBuffer, src: Allocation, dst: Allocation,
                   box: Box, producer: Instruction) -> Instruction:
        # copies between device memories run on the (src) device queue;
        # host<->device copies run on the device queue; host-host on host.
        q = self._queue_for_mem(dst.mid if is_device_memory(dst.mid) else src.mid)
        cp = self._emit(Instruction(
            InstructionType.COPY, node=self.node, queue=q,
            src_alloc=src, dst_alloc=dst, copy_box=box,
            name=f"copy {buf.name} {box} M{src.mid}->M{dst.mid}"))
        cp.add_dependency(producer, DepKind.TRUE)
        for a in (src, dst):
            ai = getattr(a, "alloc_instr", None)
            if ai is not None:
                cp.add_dependency(ai, DepKind.TRUE)
        # WAR/WAW against the destination region in dst memory
        dms = self._memstate(buf.bid, dst.mid)
        breg = Region.from_box(box)
        for r, reader in dms.readers:
            if r.overlaps(breg):
                cp.add_dependency(reader, DepKind.ANTI)
        for sub, w in dms.producers.query(breg):
            cp.add_dependency(w, DepKind.OUTPUT)
        dms.producers.update(breg, cp)
        # reading the source region
        sms = self._memstate(buf.bid, src.mid)
        sms.readers.append((breg, cp))
        return cp

    def _region_coherent_in(self, bid: int, mid: int) -> Region:
        out = Region.empty()
        for r, mids in self._coherence[bid].entries:
            if mids and mid in mids:
                out = out.union(r)
        return out

    # -- coherence (§3.3) --------------------------------------------------
    def make_coherent(self, buf: VirtualBuffer, mid: int, region: Region) -> list[Instruction]:
        """Emit producer-split copies so ``region`` is up-to-date in ``mid``."""
        self._register(buf)
        copies: list[Instruction] = []
        coh = self._coherence[buf.bid]
        stale = Region.empty()
        for sub, mids in coh.query(region):
            if not mids or mid in mids:
                continue
            stale = stale.union(sub)
        if stale.is_empty():
            return copies
        dst = self.ensure_allocation(buf, mid, region.bounding_box())
        for sub, mids in coh.query(stale):
            if not mids:
                continue
            src_mid = self._pick_source(mids, mid)
            if (is_device_memory(src_mid) and is_device_memory(mid)
                    and not self.d2d):
                # no P2P: stage through pinned host memory (§3.3)
                copies += self.make_coherent(buf, PINNED_HOST, sub)
                src_mid = PINNED_HOST
            src_ms = self._memstate(buf.bid, src_mid)
            for src_alloc in self._allocs.get((buf.bid, src_mid), []):
                if not src_alloc.live:
                    continue
                part = sub.intersect_box(src_alloc.box)
                # producer split: one copy per original-producer entry
                for psub, producer in src_ms.producers.query(part):
                    for b in psub.boxes:
                        copies.append(self._emit_copy(buf, src_alloc, dst, b, producer))
            coh.update(sub, (frozenset(mids) | {mid}))
        return copies

    def _pick_source(self, mids: frozenset, target: int) -> int:
        """Prefer same-kind memory, then pinned host, then user host."""
        mids = set(mids)
        if is_device_memory(target):
            dev = [m for m in mids if is_device_memory(m)]
            if dev and self.d2d:
                return min(dev)
            if PINNED_HOST in mids:
                return PINNED_HOST
            if USER_HOST in mids:
                return USER_HOST
            return min(mids)
        for pref in (PINNED_HOST, USER_HOST):
            if pref in mids:
                return pref
        return min(mids)

    # -- command compilation ------------------------------------------------
    def compile(self, cmd: Command) -> list[Instruction]:
        self._batch = []
        if cmd.ctype == CommandType.EXECUTION:
            self._compile_execution(cmd)
        elif cmd.ctype == CommandType.PUSH:
            self._compile_push(cmd)
        elif cmd.ctype == CommandType.AWAIT_PUSH:
            self._compile_await_push(cmd)
        elif cmd.ctype == CommandType.REDUCE_PARTIAL:
            self._compile_reduce_partial(cmd)
        elif cmd.ctype == CommandType.REDUCE_GLOBAL:
            self._compile_reduce_global(cmd)
        elif cmd.ctype == CommandType.HORIZON:
            self._compile_sync(cmd, InstructionType.HORIZON)
        elif cmd.ctype == CommandType.EPOCH:
            self._compile_sync(cmd, InstructionType.EPOCH)
        out, self._batch = self._batch, []
        return out

    def would_allocate(self, cmd: Command) -> bool:
        """Cheap query used by the lookahead scheduler (§4.3)."""
        reqs = self.allocation_requirements(cmd)
        return any(self.would_allocate_box(bid, mid, box)
                   for (bid, mid), region in reqs.items()
                   for box in [region.bounding_box()])

    def allocation_requirements(self, cmd: Command) -> dict[tuple[int, int], Region]:
        """(bid, mid) -> contiguous requirement regions for this command."""
        reqs: dict[tuple[int, int], Region] = {}

        def add(bid: int, mid: int, box: Box) -> None:
            key = (bid, mid)
            reqs[key] = reqs.get(key, Region.empty()).union(Region.from_box(box))

        if cmd.ctype == CommandType.EXECUTION and cmd.task is not None:
            is_host = cmd.task.ttype == TaskType.HOST
            chunks = ([cmd.chunk] if is_host else
                      split_box(cmd.chunk, self.num_devices,
                                dims=cmd.task.split_dims,
                                granularity=cmd.task.granularity))
            for d, ch in enumerate(chunks):
                mid = PINNED_HOST if is_host else device_memory(d)
                for acc in cmd.task.accessors:
                    reg = acc.mapped_region(ch)
                    if not reg.is_empty():
                        add(acc.buffer.bid, mid, reg.bounding_box())
        elif cmd.ctype == CommandType.PUSH:
            add(cmd.buffer.bid, PINNED_HOST, cmd.region.bounding_box())
        elif cmd.ctype == CommandType.AWAIT_PUSH:
            add(cmd.buffer.bid, PINNED_HOST, cmd.region.bounding_box())
        elif cmd.ctype == CommandType.REDUCE_GLOBAL:
            # the combined result lands in the buffer's host backing; the
            # partial/gather scratches are unhinted one-shot allocations
            add(cmd.buffer.bid, PINNED_HOST, cmd.buffer.full_box)
        return reqs

    # -- execution commands (§3.1, §3.3) -------------------------------------
    def _compile_execution(self, cmd: Command) -> None:
        task = cmd.task
        is_host = task.ttype == TaskType.HOST
        chunks = ([cmd.chunk] if is_host else
                  split_box(cmd.chunk, self.num_devices,
                            dims=task.split_dims, granularity=task.granularity))
        # overlapping-write detection between local devices (paper §4.4)
        if len(chunks) > 1:
            for acc in task.accessors:
                if not acc.mode.is_producer:
                    continue
                for i in range(len(chunks)):
                    for j in range(i + 1, len(chunks)):
                        ri = acc.mapped_region(chunks[i])
                        rj = acc.mapped_region(chunks[j])
                        if ri.overlaps(rj):
                            self.warnings.append(
                                f"overlapping write to {acc.buffer.name} by "
                                f"devices D{i} and D{j} in task {task.name}")
        for d, ch in enumerate(chunks):
            mid = PINNED_HOST if is_host else device_memory(d)
            bindings: list[AccessorBinding] = []
            deps: list[Instruction] = []
            # phase 1: settle ALL allocations first — a later accessor's
            # resize may free the allocation an earlier accessor would have
            # bound to (found by hypothesis, tests/test_lookahead_property)
            for acc in task.accessors:
                self._register(acc.buffer)
                reg = acc.mapped_region(ch)
                if not reg.is_empty():
                    self.ensure_allocation(acc.buffer, mid, reg.bounding_box())
            # phase 2: coherence + bindings against the settled allocations
            for acc in task.accessors:
                buf = acc.buffer
                reg = acc.mapped_region(ch)
                if reg.is_empty():
                    continue
                alloc = self._live_allocation(buf.bid, mid, reg.bounding_box())
                if acc.mode.is_consumer:
                    deps.extend(self.make_coherent(buf, mid, reg))
                bindings.append(AccessorBinding(acc, alloc, reg))
            # reduction outputs: one identity-filled accumulator scratch per
            # (device chunk, reduction) — never the buffer's own allocation,
            # since every chunk "writes" the same full-buffer region
            red_bindings: list[ReductionBinding] = []
            fills: list[Instruction] = []
            for red in task.reductions:
                buf = red.buffer
                self._register(buf)
                scratch, fill = self._emit_reduction_scratch(red, mid)
                red_bindings.append(ReductionBinding(red, scratch))
                fills.append(fill)
            itype = InstructionType.HOST_TASK if is_host else InstructionType.DEVICE_KERNEL
            qd = ("host",) if is_host else ("device", d)
            instr = Instruction(
                itype, node=self.node, queue=qd, kernel_fn=task.kernel_fn,
                chunk=ch, bindings=tuple(bindings),
                red_bindings=tuple(red_bindings),
                device=None if is_host else d, name=task.name, command=cmd)
            for f in fills:
                instr.add_dependency(f, DepKind.TRUE)
            for b in bindings:
                ai = getattr(b.allocation, "alloc_instr", None)
                if ai is not None:
                    instr.add_dependency(ai, DepKind.TRUE)
                ms = self._memstate(b.accessor.buffer.bid, mid)
                if b.accessor.mode.is_consumer:
                    for sub, producer in ms.producers.query(b.region):
                        instr.add_dependency(producer, DepKind.TRUE)
                    ms.readers.append((b.region, instr))
                if b.accessor.mode.is_producer:
                    for r, reader in ms.readers:
                        if reader is not instr and r.overlaps(b.region):
                            instr.add_dependency(reader, DepKind.ANTI)
                    for sub, w in ms.producers.query(b.region):
                        instr.add_dependency(w, DepKind.OUTPUT)
            if self._last_horizon is not None:
                instr.add_dependency(self._last_horizon, DepKind.SYNC)
            elif not instr.dependencies and self._last_epoch is not None:
                instr.add_dependency(self._last_epoch, DepKind.SYNC)
            self._emit(instr)
            for rb in red_bindings:
                rtid = (task.tid, rb.reduction.buffer.bid, 1)
                st = self._red_state.setdefault(
                    rtid, {"device": [], "partial": None, "sends": []})
                st["device"].append((rb.allocation, instr))
            # post-emit state updates: writes establish new producers/coherence
            for b in bindings:
                if b.accessor.mode.is_producer:
                    bid = b.accessor.buffer.bid
                    ms = self._memstate(bid, mid)
                    ms.producers.update(b.region, instr)
                    ms.readers = [(r, t) for r, t in ms.readers
                                  if t is instr or not r.difference(b.region).is_empty()]
                    self._coherence[bid].update(b.region, frozenset([mid]))

    # -- outbound transfers (§3.4) -------------------------------------------
    def _compile_push(self, cmd: Command) -> None:
        buf = cmd.buffer
        self._register(buf)
        # stage into pinned host memory, then one send per producer-rect
        self.make_coherent(buf, PINNED_HOST, cmd.region)
        ms = self._memstate(buf.bid, PINNED_HOST)
        for alloc in self._allocs.get((buf.bid, PINNED_HOST), []):
            if not alloc.live:
                continue
            part = cmd.region.intersect_box(alloc.box)
            for psub, producer in ms.producers.query(part):
                for b in psub.boxes:  # producer split
                    msg_id = next(self._msg_ids)
                    send = Instruction(
                        InstructionType.SEND, node=self.node, queue=("comm",),
                        dest=cmd.target, msg_id=msg_id, send_box=b,
                        recv_alloc=alloc, transfer_id=cmd.transfer_id,
                        name=f"send {buf.name} {b} ->N{cmd.target}", command=cmd)
                    send.add_dependency(producer, DepKind.TRUE)
                    ai = getattr(alloc, "alloc_instr", None)
                    if ai is not None:
                        send.add_dependency(ai, DepKind.TRUE)
                    if self._last_horizon is not None:
                        send.add_dependency(self._last_horizon, DepKind.SYNC)
                    self._emit(send)
                    ms.readers.append((Region.from_box(b), send))
                    self.pilots.append(Pilot(source=self.node, target=cmd.target,
                                             transfer_id=cmd.transfer_id, box=b,
                                             msg_id=msg_id))

    # -- inbound transfers (§3.4) ----------------------------------------------
    def _compile_await_push(self, cmd: Command) -> None:
        buf = cmd.buffer
        self._register(buf)
        # must be able to receive the whole union contiguously (case b)
        alloc = self.ensure_allocation(buf, PINNED_HOST, cmd.region.bounding_box())
        ms = self._memstate(buf.bid, PINNED_HOST)

        consumer_regions = self._consumer_split_regions(cmd)
        anti_deps: list[Instruction] = []
        for r, reader in ms.readers:
            if r.overlaps(cmd.region):
                anti_deps.append(reader)
        for sub, w in ms.producers.query(cmd.region):
            anti_deps.append(w)

        def wire(instr: Instruction) -> Instruction:
            ai = getattr(alloc, "alloc_instr", None)
            if ai is not None:
                instr.add_dependency(ai, DepKind.TRUE)
            for a in anti_deps:
                instr.add_dependency(a, DepKind.ANTI)
            if self._last_horizon is not None:
                instr.add_dependency(self._last_horizon, DepKind.SYNC)
            return self._emit(instr)

        if len(consumer_regions) <= 1:
            recv = wire(Instruction(
                InstructionType.RECEIVE, node=self.node, queue=("comm",),
                transfer_id=cmd.transfer_id, recv_region=cmd.region,
                recv_alloc=alloc, name=f"recv {buf.name} {cmd.region}", command=cmd))
            ms.producers.update(cmd.region, recv)
        else:
            split = wire(Instruction(
                InstructionType.SPLIT_RECEIVE, node=self.node, queue=("comm",),
                transfer_id=cmd.transfer_id, recv_region=cmd.region,
                recv_alloc=alloc, name=f"split-recv {buf.name} {cmd.region}",
                command=cmd))
            for creg in consumer_regions:
                aw = self._emit(Instruction(
                    InstructionType.AWAIT_RECEIVE, node=self.node, queue=("comm",),
                    transfer_id=cmd.transfer_id, recv_region=creg,
                    recv_alloc=alloc, split_parent=split,
                    name=f"await-recv {buf.name} {creg}", command=cmd))
                aw.add_dependency(split, DepKind.TRUE)
                ms.producers.update(creg, aw)
        self._coherence[buf.bid].update(cmd.region, frozenset([PINNED_HOST]))

    def _consumer_split_regions(self, cmd: Command) -> list[Region]:
        """Subregions per local consumer (device chunk) of an await-push."""
        regions: list[Region] = []
        for dep in cmd.dependents:
            if dep.ctype != CommandType.EXECUTION or dep.task is None:
                continue
            chunks = split_box(dep.chunk, self.num_devices,
                               dims=dep.task.split_dims,
                               granularity=dep.task.granularity)
            for ch in chunks:
                for acc in dep.task.accessors:
                    if acc.buffer.bid != cmd.buffer.bid or not acc.mode.is_consumer:
                        continue
                    part = acc.mapped_region(ch).intersect(cmd.region)
                    if not part.is_empty():
                        regions.append(part)
        # dedupe; if all consumers want the whole region, no split (§3.4)
        uniq: list[Region] = []
        for r in regions:
            if not any(r == u for u in uniq):
                uniq.append(r)
        if len(uniq) <= 1 or all(u.contains(cmd.region) for u in uniq):
            return uniq[:1]
        return uniq

    # -- reductions -----------------------------------------------------------
    def _emit_scratch_alloc(self, mid: int, box: Box, dtype,
                            name: str) -> Allocation:
        """Emit a one-shot scratch ALLOC (outside the resize machinery),
        sync-anchored like every other allocation."""
        scratch = Allocation(mid=mid, bid=None, box=box, dtype=dtype)
        alloc_instr = self._emit(Instruction(
            InstructionType.ALLOC, node=self.node,
            queue=self._queue_for_mem(mid), allocation=scratch, name=name))
        if self._last_horizon is not None:
            alloc_instr.add_dependency(self._last_horizon, DepKind.SYNC)
        elif self._last_epoch is not None:
            alloc_instr.add_dependency(self._last_epoch, DepKind.SYNC)
        scratch.alloc_instr = alloc_instr  # type: ignore[attr-defined]
        return scratch

    def _emit_reduction_scratch(self, red: Reduction,
                                mid: int) -> tuple[Allocation, Instruction]:
        """Allocate + identity-fill one accumulator scratch in ``mid``."""
        buf = red.buffer
        scratch = self._emit_scratch_alloc(
            mid, buf.full_box, red.op.acc_dtype(buf.dtype),
            f"alloc red-partial {buf.name} M{mid}")
        fill = self._emit(Instruction(
            InstructionType.FILL_IDENTITY, node=self.node,
            queue=self._queue_for_mem(mid), allocation=scratch, reduction=red,
            name=f"fill-identity {buf.name} ({red.op.name}) M{mid}"))
        fill.add_dependency(scratch.alloc_instr, DepKind.TRUE)
        return scratch, fill

    def _free_scratch(self, alloc: Allocation,
                      anti: list[Instruction]) -> Instruction:
        """Free a one-shot scratch once all ``anti`` users completed."""
        fr = self._emit(Instruction(
            InstructionType.FREE, node=self.node,
            queue=self._queue_for_mem(alloc.mid), allocation=alloc,
            name=f"free {alloc}"))
        for a in anti:
            fr.add_dependency(a, DepKind.ANTI)
        alloc.live = False
        return fr

    def _compile_reduce_partial(self, cmd: Command) -> None:
        """Fold device partials into one node partial, broadcast it (§2.2)."""
        red, buf = cmd.reduction, cmd.buffer
        st = self._red_state[cmd.transfer_id]
        device_parts: list[tuple[Allocation, Instruction]] = st["device"]
        partial = self._emit_scratch_alloc(
            PINNED_HOST, buf.full_box, red.op.acc_dtype(buf.dtype),
            f"alloc red-node-partial {buf.name}")
        lr = Instruction(
            InstructionType.LOCAL_REDUCE, node=self.node, queue=("host",),
            reduction=red, reduce_srcs=tuple(a for a, _ in device_parts),
            dst_alloc=partial, command=cmd,
            name=f"local-reduce {buf.name} ({red.op.name})")
        lr.add_dependency(partial.alloc_instr, DepKind.TRUE)
        for alloc, producer in device_parts:
            lr.add_dependency(producer, DepKind.TRUE)
            ai = getattr(alloc, "alloc_instr", None)
            if ai is not None:
                lr.add_dependency(ai, DepKind.TRUE)
        self._emit(lr)
        st["partial"] = (partial, lr)
        for alloc, _ in device_parts:
            self._free_scratch(alloc, [lr])
        # broadcast the node partial to every other rank; the receiver's
        # GATHER_RECEIVE matches this traffic by its 3-tuple transfer id
        # and lands each payload at its SOURCE rank's slot
        for target in cmd.targets:
            msg_id = next(self._msg_ids)
            send = Instruction(
                InstructionType.SEND, node=self.node, queue=("comm",),
                dest=target, msg_id=msg_id, send_box=buf.full_box,
                recv_alloc=partial, transfer_id=cmd.transfer_id, command=cmd,
                name=f"send red-partial {buf.name} ->N{target}")
            send.add_dependency(lr, DepKind.TRUE)
            if self._last_horizon is not None:
                send.add_dependency(self._last_horizon, DepKind.SYNC)
            self._emit(send)
            st["sends"].append(send)
            self.pilots.append(Pilot(source=self.node, target=target,
                                     transfer_id=cmd.transfer_id,
                                     box=buf.full_box, msg_id=msg_id,
                                     gather=True))

    def _compile_reduce_global(self, cmd: Command) -> None:
        """Gather peer partials and fold them in canonical node order."""
        red, buf = cmd.reduction, cmd.buffer
        self._register(buf)
        st = self._red_state.pop(cmd.transfer_id,
                                 {"device": [], "partial": None, "sends": []})
        own_partial = st["partial"]           # (alloc, LOCAL_REDUCE) | None
        peers = tuple(s for s in cmd.participants if s != self.node)

        gather_alloc = None
        gather_instr = None
        if peers:
            # fixed-stride gather staging: slot s holds rank s's partial
            slots = max(peers) + 1
            gbox = Box((0,) * (buf.full_box.rank + 1), (slots,) + buf.shape)
            gather_alloc = self._emit_scratch_alloc(
                PINNED_HOST, gbox, red.op.acc_dtype(buf.dtype),
                f"alloc red-gather {buf.name}")
            gather_instr = Instruction(
                InstructionType.GATHER_RECEIVE, node=self.node,
                queue=("comm",), transfer_id=cmd.transfer_id,
                recv_region=buf.full_region, recv_alloc=gather_alloc,
                gather_sources=peers, reduction=red, command=cmd,
                name=f"gather-recv {buf.name} <-{{{','.join(map(str, peers))}}}")
            gather_instr.add_dependency(gather_alloc.alloc_instr, DepKind.TRUE)
            if self._last_horizon is not None:
                gather_instr.add_dependency(self._last_horizon, DepKind.SYNC)
            self._emit(gather_instr)

        # the combined value lands in the buffer's host backing allocation
        dst = self.ensure_allocation(buf, PINNED_HOST, buf.full_box)
        full = buf.full_region
        if red.include_current_value:
            # previous contents enter the fold exactly once — every node
            # holds the same replicated value, so this stays deterministic
            self.make_coherent(buf, PINNED_HOST, full)
        ms = self._memstate(buf.bid, PINNED_HOST)
        gi = Instruction(
            InstructionType.GLOBAL_REDUCE, node=self.node, queue=("host",),
            reduction=red, src_alloc=gather_alloc,
            reduce_srcs=(own_partial[0],) if own_partial else (),
            dst_alloc=dst, participants=cmd.participants,
            include_current=red.include_current_value, command=cmd,
            name=f"global-reduce {buf.name} ({red.op.name})")
        ai = getattr(dst, "alloc_instr", None)
        if ai is not None:
            gi.add_dependency(ai, DepKind.TRUE)
        if gather_instr is not None:
            gi.add_dependency(gather_instr, DepKind.TRUE)
        if own_partial is not None:
            gi.add_dependency(own_partial[1], DepKind.TRUE)
        kind = DepKind.TRUE if red.include_current_value else DepKind.OUTPUT
        for sub, producer in ms.producers.query(full):
            gi.add_dependency(producer, kind)
        for r, reader in ms.readers:
            if r.overlaps(full):
                gi.add_dependency(reader, DepKind.ANTI)
        if self._last_horizon is not None:
            gi.add_dependency(self._last_horizon, DepKind.SYNC)
        self._emit(gi)
        ms.producers.update(full, gi)
        ms.readers = [(r, t) for r, t in ms.readers
                      if not r.difference(full).is_empty()]
        self._coherence[buf.bid].update(full, frozenset([PINNED_HOST]))
        # scratch lifetimes: the gather staging dies with the fold; the node
        # partial must also outlive every outbound broadcast send
        if gather_alloc is not None:
            self._free_scratch(gather_alloc, [gi])
        if own_partial is not None:
            self._free_scratch(own_partial[0], [gi] + st["sends"])

    # -- synchronization (§3.5) ---------------------------------------------
    def _compile_sync(self, cmd: Command, itype: InstructionType) -> None:
        instr = Instruction(itype, node=self.node, queue=("host",),
                            name=itype.value, command=cmd)
        # every instruction before the previous sync already has a dependent
        # (that sync), so only the tail can contribute to the frontier
        for i in self.instructions[self._frontier_pos:]:
            if not i.dependents:
                instr.add_dependency(i, DepKind.SYNC)
        self._emit(instr)
        if itype == InstructionType.HORIZON:
            self._last_horizon = instr
        else:
            self._last_epoch = instr
            self._last_horizon = None
        # horizon compaction: prior producers collapse onto the sync point
        for ms in self._mem.values():
            ms.producers.update(ms.producers.covered(), instr)
            ms.producers.coalesce()
            ms.readers = []
        if self.retire:
            # everything before this sync is transitively dominated by it;
            # the generator only ever wires new deps against the sync point
            del self.instructions[:-1]
            self._frontier_pos = 0
        else:
            self._frontier_pos = len(self.instructions) - 1

    # -- shutdown -------------------------------------------------------------
    def free_all(self) -> list[Instruction]:
        """Emit frees for all live allocations (buffer destruction, §3.2)."""
        out = []
        for (bid, mid), allocs in self._allocs.items():
            for a in allocs:
                if not a.live or mid == USER_HOST:
                    continue
                fr = self._emit(Instruction(
                    InstructionType.FREE, node=self.node,
                    queue=self._queue_for_mem(mid), allocation=a,
                    name=f"free {a}"))
                ms = self._memstate(bid, mid)
                for r, reader in ms.readers:
                    fr.add_dependency(reader, DepKind.ANTI)
                for sub, w in ms.producers.query(Region.from_box(a.box)):
                    fr.add_dependency(w, DepKind.ANTI)
                a.live = False
                out.append(fr)
        return out
