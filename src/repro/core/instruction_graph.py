"""Instruction graph (IDAG) generation — the paper's core contribution (§3).

Compiles each node's command stream into micro-operations: ``alloc / copy /
free / spill / reload / send / receive / split-receive / await-receive /
device-kernel / host-task / horizon / epoch``.  Key mechanisms implemented
faithfully:

* hierarchical work assignment — the command chunk is split a second time
  over the node's local devices (§3.1);
* virtualized buffers with multiple disjoint backing allocations per
  (buffer, memory); every accessor must be backed by one *contiguous*
  allocation, triggering alloc→copy→free resize chains when access patterns
  grow (§3.2, fig. 3);
* local coherence with producer- and consumer-split copies (§3.3);
* outbound transfers: producer-split sends + pilot messages; inbound:
  receive vs split-receive/await-receive under the union-only constraint of
  await-push commands (§3.4);
* horizon/epoch instructions for pruning and synchronization (§3.5);
* allocation widening driven by the scheduler lookahead (§4.3).

The allocation *lifecycle* — backing allocations, coherence, widening,
byte budgets and spill/reload under pressure — lives in
:class:`repro.core.memory.MemoryManager` (DESIGN.md §8); this generator is
a pure consumer that requests regions and receives placements.
"""

from __future__ import annotations

import itertools
from contextlib import contextmanager
from typing import Optional

from .allocation import (PINNED_HOST, USER_HOST, device_memory,  # noqa: F401
                         is_device_memory, queue_for_mem)
from .buffer import AccessMode, VirtualBuffer
from .collective import (allgather_schedule, reduce_scatter_schedule,
                         schedule_for, shard_bounds)
from .command_graph import Command, CommandType
from .instructions import (AccessorBinding, CollFragment,  # noqa: F401
                           EpochAbort, Instruction, InstructionType, Pilot,
                           ReductionBinding)
from .memory import MemoryManager
from .region import Box, Region, split_box
from .task_graph import DepKind, TaskType


class IdagGenerator:
    """Per-node instruction graph generator."""

    def __init__(self, node: int, num_devices: int, *, d2d: bool = True,
                 alloc_hints: Optional[dict] = None, retire: bool = False,
                 budgets: Optional[dict[int, int]] = None, metrics=None,
                 namespace: Optional[str] = None,
                 buffer_owner: Optional[dict[int, str]] = None,
                 renaming: bool = False):
        self.node = node
        self.num_devices = num_devices
        # ``retire=True`` (used by the runtime) trims ``instructions`` down to
        # the window since the last horizon/epoch, so generator memory stays
        # bounded on long runs; ``emitted_count`` keeps the lifetime total.
        self.retire = retire
        self.instructions: list[Instruction] = []
        self.emitted_count = 0
        self.alloc_count = 0
        self._batch: list[Instruction] = []
        self._frontier_pos = 0          # index of the last sync instruction
        self.pilots: list[Pilot] = []
        self.warnings: list[str] = []
        # in-flight reduction state, keyed by reduction transfer id:
        # device partial scratches (+ producing kernels), the node partial
        # (+ its LOCAL_REDUCE) and the partial-broadcast sends
        self._red_state: dict[tuple, dict] = {}
        # collective-mode reduction state (DESIGN.md §9), keyed by rtid:
        # the per-member staging (slot s = rank s's partial), the member's
        # LOCAL_REDUCE and the fusion group's shared exchange instructions
        self._coll_red: dict[tuple, dict] = {}
        self._msg_ids = itertools.count(node * 1_000_000)
        self._last_horizon: Optional[Instruction] = None
        self._last_epoch: Optional[Instruction] = None
        # the memory layer: allocation lifecycle, coherence, budgets,
        # spill/reload (DESIGN.md §8); widening hints double as reservations
        self.mem = MemoryManager(self, d2d=d2d, budgets=budgets,
                                 hints=alloc_hints, metrics=metrics,
                                 namespace=namespace,
                                 buffer_owner=buffer_owner,
                                 renaming=renaming)
        self._init_epoch = self._emit(Instruction(
            InstructionType.EPOCH, node=node, queue=("host",), name="init"))
        self._last_epoch = self._init_epoch
        self.mem.init_anchor = self._init_epoch
        # the bootstrap epoch is consumed via ``instructions`` by the
        # runtime; leave no open batch behind (capture_batch relies on it)
        self._batch = []

    # -- small helpers ---------------------------------------------------
    @contextmanager
    def capture_batch(self, out: list):
        """Collect EVERY instruction emitted inside the scope into ``out``.

        For callers outside :meth:`compile` (e.g. the memory layer's reload
        prefetch) that must schedule side-effect emissions — allocs, frees,
        cascade spills — not just the instructions a helper returns.  Must
        not be entered while a ``compile`` batch is open.
        """
        assert not self._batch, "capture_batch inside an open compile batch"
        self._batch = []
        try:
            yield
        finally:
            out.extend(self._batch)
            self._batch = []

    def _emit(self, instr: Instruction) -> Instruction:
        self.instructions.append(instr)
        self.emitted_count += 1
        if instr.itype == InstructionType.ALLOC:
            self.alloc_count += 1
        self._batch.append(instr)
        return instr

    def _register(self, buf: VirtualBuffer) -> None:
        self.mem.register_buffer(buf)

    # -- memory-layer pass-throughs (compat + convenience) -----------------
    @property
    def _allocs(self) -> dict:
        """Live-allocation map — owned by the MemoryManager; read-only
        compatibility view for tests and diagnostics."""
        return self.mem.allocations

    @property
    def _mem(self) -> dict:
        """Per-(buffer, memory) producer/reader state — owned by the
        MemoryManager; read-only compatibility view."""
        return self.mem.mem

    @property
    def alloc_hints(self) -> dict:
        return self.mem.hints

    @alloc_hints.setter
    def alloc_hints(self, hints: dict) -> None:
        self.mem.reserve(hints)

    def would_allocate_box(self, bid: int, mid: int, box: Box) -> bool:
        return self.mem.would_allocate_box(bid, mid, box)

    def ensure_allocation(self, buf: VirtualBuffer, mid: int, box: Box):
        """Placement request — delegates to the memory layer (§3.2)."""
        return self.mem.ensure(buf, mid, box)

    def make_coherent(self, buf: VirtualBuffer, mid: int,
                      region: Region) -> list[Instruction]:
        """Residency request — delegates to the memory layer (§3.3)."""
        return self.mem.make_coherent(buf, mid, region)

    # -- command compilation ------------------------------------------------
    def compile(self, cmd: Command) -> list[Instruction]:
        self._batch = []
        # pin scope: every allocation this command touches stays resident
        # until the command is fully lowered (eviction must never drop the
        # working set out from under a half-compiled kernel)
        with self.mem.pin_scope():
            if cmd.ctype == CommandType.EXECUTION:
                self._compile_execution(cmd)
            elif cmd.ctype == CommandType.PUSH:
                self._compile_push(cmd)
            elif cmd.ctype == CommandType.AWAIT_PUSH:
                self._compile_await_push(cmd)
            elif cmd.ctype == CommandType.REDUCE_PARTIAL:
                self._compile_reduce_partial(cmd)
            elif cmd.ctype == CommandType.REDUCE_GLOBAL:
                self._compile_reduce_global(cmd)
            elif cmd.ctype == CommandType.COLL_ALLREDUCE:
                self._compile_allreduce(cmd)
            elif cmd.ctype in (CommandType.COLL_ALLGATHER,
                               CommandType.COLL_BROADCAST,
                               CommandType.COLL_SCATTER):
                if cmd.reduction is not None:
                    self._compile_reduce_exchange(cmd)
                else:
                    self._compile_collective(cmd)
            elif cmd.ctype == CommandType.HORIZON:
                self._compile_sync(cmd, InstructionType.HORIZON)
            elif cmd.ctype == CommandType.EPOCH:
                self._compile_sync(cmd, InstructionType.EPOCH)
        out, self._batch = self._batch, []
        return out

    def would_allocate(self, cmd: Command) -> bool:
        """Cheap query used by the lookahead scheduler (§4.3)."""
        reqs = self.allocation_requirements(cmd)
        return any(self.mem.would_allocate_box(bid, mid, box)
                   for (bid, mid), region in reqs.items()
                   for box in [region.bounding_box()])

    def allocation_requirements(self, cmd: Command) -> dict[tuple[int, int], Region]:
        """(bid, mid) -> contiguous requirement regions for this command."""
        reqs: dict[tuple[int, int], Region] = {}

        def add(bid: int, mid: int, box: Box) -> None:
            key = (bid, mid)
            reqs[key] = reqs.get(key, Region.empty()).union(Region.from_box(box))

        if cmd.ctype == CommandType.EXECUTION and cmd.task is not None:
            is_host = cmd.task.ttype == TaskType.HOST
            chunks = ([cmd.chunk] if is_host else
                      split_box(cmd.chunk, self.num_devices,
                                dims=cmd.task.split_dims,
                                granularity=cmd.task.granularity))
            for d, ch in enumerate(chunks):
                mid = PINNED_HOST if is_host else device_memory(d)
                for acc in cmd.task.accessors:
                    reg = acc.mapped_region(ch)
                    if not reg.is_empty():
                        add(acc.buffer.bid, mid, reg.bounding_box())
        elif cmd.ctype == CommandType.PUSH:
            add(cmd.buffer.bid, PINNED_HOST, cmd.region.bounding_box())
        elif cmd.ctype == CommandType.AWAIT_PUSH:
            add(cmd.buffer.bid, PINNED_HOST, cmd.region.bounding_box())
        elif cmd.ctype == CommandType.REDUCE_GLOBAL:
            # the combined result lands in the buffer's host backing; the
            # partial/gather scratches are unhinted one-shot allocations
            add(cmd.buffer.bid, PINNED_HOST, cmd.buffer.full_box)
        elif cmd.ctype in (CommandType.COLL_ALLGATHER,
                           CommandType.COLL_BROADCAST,
                           CommandType.COLL_SCATTER,
                           CommandType.COLL_ALLREDUCE):
            # region collectives stage through the buffer's pinned-host
            # backing; reduction exchanges use unhinted one-shot staging
            if cmd.reduction is None and cmd.region is not None \
                    and not cmd.region.is_empty():
                add(cmd.buffer.bid, PINNED_HOST, cmd.region.bounding_box())
        return reqs

    # -- execution commands (§3.1, §3.3) -------------------------------------
    def _compile_execution(self, cmd: Command) -> None:
        task = cmd.task
        is_host = task.ttype == TaskType.HOST
        chunks = ([cmd.chunk] if is_host else
                  split_box(cmd.chunk, self.num_devices,
                            dims=task.split_dims, granularity=task.granularity))
        # overlapping-write detection between local devices (paper §4.4)
        if len(chunks) > 1:
            for acc in task.accessors:
                if not acc.mode.is_producer:
                    continue
                for i in range(len(chunks)):
                    for j in range(i + 1, len(chunks)):
                        ri = acc.mapped_region(chunks[i])
                        rj = acc.mapped_region(chunks[j])
                        if ri.overlaps(rj):
                            self.warnings.append(
                                f"overlapping write to {acc.buffer.name} by "
                                f"devices D{i} and D{j} in task {task.name}")
        for d, ch in enumerate(chunks):
            mid = PINNED_HOST if is_host else device_memory(d)
            bindings: list[AccessorBinding] = []
            deps: list[Instruction] = []
            # phase 1: settle ALL allocations first — a later accessor's
            # resize may free the allocation an earlier accessor would have
            # bound to (found by hypothesis, tests/test_lookahead_property)
            for acc in task.accessors:
                self._register(acc.buffer)
                reg = acc.mapped_region(ch)
                if not reg.is_empty():
                    self.mem.ensure(acc.buffer, mid, reg.bounding_box())
            # phase 2: coherence + bindings against the settled allocations
            for acc in task.accessors:
                buf = acc.buffer
                reg = acc.mapped_region(ch)
                if reg.is_empty():
                    continue
                # renaming (DESIGN.md §13): a pure overwrite — discard-write
                # accessor, and no accessor of the same buffer reads in this
                # task — rebinds the version to a fresh physical so the
                # write carries no WAR/WAW edges against prior readers
                if (acc.mode == AccessMode.WRITE
                        and not any(a2 is not acc
                                    and a2.buffer.bid == buf.bid
                                    and a2.mode.is_consumer
                                    for a2 in task.accessors)):
                    self.mem.rename_for_write(buf, mid, reg)
                alloc = self.mem.live(buf.bid, mid, reg.bounding_box())
                if acc.mode.is_consumer:
                    deps.extend(self.mem.make_coherent(buf, mid, reg))
                bindings.append(AccessorBinding(acc, alloc, reg))
            # reduction outputs: one identity-filled accumulator scratch per
            # (device chunk, reduction) — never the buffer's own allocation,
            # since every chunk "writes" the same full-buffer region
            red_bindings: list[ReductionBinding] = []
            fills: list[Instruction] = []
            for red in task.reductions:
                buf = red.buffer
                self._register(buf)
                scratch, fill = self._emit_reduction_scratch(red, mid)
                red_bindings.append(ReductionBinding(red, scratch))
                fills.append(fill)
            itype = InstructionType.HOST_TASK if is_host else InstructionType.DEVICE_KERNEL
            qd = ("host",) if is_host else ("device", d)
            instr = Instruction(
                itype, node=self.node, queue=qd, kernel_fn=task.kernel_fn,
                chunk=ch, bindings=tuple(bindings),
                red_bindings=tuple(red_bindings),
                device=None if is_host else d, name=task.name, command=cmd)
            for f in fills:
                instr.add_dependency(f, DepKind.TRUE)
            for b in bindings:
                ai = b.allocation.alloc_instr
                if ai is not None:
                    instr.add_dependency(ai, DepKind.TRUE)
                ms = self.mem.state(b.accessor.buffer.bid, mid)
                if b.accessor.mode.is_consumer:
                    for sub, producer in ms.producers.query(b.region):
                        instr.add_dependency(producer, DepKind.TRUE)
                    ms.readers.append((b.region, instr))
                if b.accessor.mode.is_producer:
                    for r, reader in ms.readers:
                        if reader is not instr and r.overlaps(b.region):
                            instr.add_dependency(reader, DepKind.ANTI)
                    for sub, w in ms.producers.query(b.region):
                        instr.add_dependency(w, DepKind.OUTPUT)
                    # first writer of a recycled physical: order behind the
                    # retired version's outstanding users (DESIGN.md §13)
                    for h in self.mem.take_hazards(b.allocation):
                        instr.add_dependency(h, DepKind.ANTI)
            if self._last_horizon is not None:
                instr.add_dependency(self._last_horizon, DepKind.SYNC)
            elif not instr.dependencies and self._last_epoch is not None:
                instr.add_dependency(self._last_epoch, DepKind.SYNC)
            self._emit(instr)
            for rb in red_bindings:
                rtid = (task.tid, rb.reduction.buffer.bid, 1)
                st = self._red_state.setdefault(
                    rtid, {"device": [], "partial": None, "sends": []})
                st["device"].append((rb.allocation, instr))
            # post-emit state updates: writes establish new producers/coherence
            for b in bindings:
                if b.accessor.mode.is_producer:
                    bid = b.accessor.buffer.bid
                    ms = self.mem.state(bid, mid)
                    ms.producers.update(b.region, instr)
                    ms.readers = [(r, t) for r, t in ms.readers
                                  if t is instr or not r.difference(b.region).is_empty()]
                    self.mem.coherence[bid].update(b.region, frozenset([mid]))
                    self.mem.note_write(bid, b.region)

    # -- outbound transfers (§3.4) -------------------------------------------
    def _compile_push(self, cmd: Command) -> None:
        buf = cmd.buffer
        self._register(buf)
        # stage into pinned host memory, then one send per producer-rect
        self.mem.make_coherent(buf, PINNED_HOST, cmd.region)
        ms = self.mem.state(buf.bid, PINNED_HOST)
        for alloc in self.mem.allocations.get((buf.bid, PINNED_HOST), []):
            if not alloc.live:
                continue
            part = cmd.region.intersect_box(alloc.box)
            for psub, producer in ms.producers.query(part):
                for b in psub.boxes:  # producer split
                    msg_id = next(self._msg_ids)
                    send = Instruction(
                        InstructionType.SEND, node=self.node, queue=("comm",),
                        dest=cmd.target, msg_id=msg_id, send_box=b,
                        recv_alloc=alloc, transfer_id=cmd.transfer_id,
                        name=f"send {buf.name} {b} ->N{cmd.target}", command=cmd)
                    send.add_dependency(producer, DepKind.TRUE)
                    ai = alloc.alloc_instr
                    if ai is not None:
                        send.add_dependency(ai, DepKind.TRUE)
                    if self._last_horizon is not None:
                        send.add_dependency(self._last_horizon, DepKind.SYNC)
                    self._emit(send)
                    ms.readers.append((Region.from_box(b), send))
                    self.pilots.append(Pilot(source=self.node, target=cmd.target,
                                             transfer_id=cmd.transfer_id, box=b,
                                             msg_id=msg_id))

    # -- inbound transfers (§3.4) ----------------------------------------------
    def _compile_await_push(self, cmd: Command) -> None:
        buf = cmd.buffer
        self._register(buf)
        # must be able to receive the whole union contiguously (case b)
        alloc = self.mem.ensure(buf, PINNED_HOST, cmd.region.bounding_box())
        ms = self.mem.state(buf.bid, PINNED_HOST)

        consumer_regions = self._consumer_split_regions(cmd)
        anti_deps: list[Instruction] = []
        for r, reader in ms.readers:
            if r.overlaps(cmd.region):
                anti_deps.append(reader)
        for sub, w in ms.producers.query(cmd.region):
            anti_deps.append(w)

        def wire(instr: Instruction) -> Instruction:
            ai = alloc.alloc_instr
            if ai is not None:
                instr.add_dependency(ai, DepKind.TRUE)
            for a in anti_deps:
                instr.add_dependency(a, DepKind.ANTI)
            if self._last_horizon is not None:
                instr.add_dependency(self._last_horizon, DepKind.SYNC)
            return self._emit(instr)

        if len(consumer_regions) <= 1:
            recv = wire(Instruction(
                InstructionType.RECEIVE, node=self.node, queue=("comm",),
                transfer_id=cmd.transfer_id, recv_region=cmd.region,
                recv_alloc=alloc, name=f"recv {buf.name} {cmd.region}", command=cmd))
            ms.producers.update(cmd.region, recv)
        else:
            split = wire(Instruction(
                InstructionType.SPLIT_RECEIVE, node=self.node, queue=("comm",),
                transfer_id=cmd.transfer_id, recv_region=cmd.region,
                recv_alloc=alloc, name=f"split-recv {buf.name} {cmd.region}",
                command=cmd))
            for creg in consumer_regions:
                aw = self._emit(Instruction(
                    InstructionType.AWAIT_RECEIVE, node=self.node, queue=("comm",),
                    transfer_id=cmd.transfer_id, recv_region=creg,
                    recv_alloc=alloc, split_parent=split,
                    name=f"await-recv {buf.name} {creg}", command=cmd))
                aw.add_dependency(split, DepKind.TRUE)
                ms.producers.update(creg, aw)
        self.mem.coherence[buf.bid].update(cmd.region, frozenset([PINNED_HOST]))
        # fresh remote data supersedes anything spilled from this region
        self.mem.note_write(buf.bid, cmd.region)

    def _consumer_split_regions(self, cmd: Command) -> list[Region]:
        """Subregions per local consumer (device chunk) of an await-push."""
        regions: list[Region] = []
        for dep in cmd.dependents:
            if dep.ctype != CommandType.EXECUTION or dep.task is None:
                continue
            chunks = split_box(dep.chunk, self.num_devices,
                               dims=dep.task.split_dims,
                               granularity=dep.task.granularity)
            for ch in chunks:
                for acc in dep.task.accessors:
                    if acc.buffer.bid != cmd.buffer.bid or not acc.mode.is_consumer:
                        continue
                    part = acc.mapped_region(ch).intersect(cmd.region)
                    if not part.is_empty():
                        regions.append(part)
        # dedupe; if all consumers want the whole region, no split (§3.4)
        uniq: list[Region] = []
        for r in regions:
            if not any(r == u for u in uniq):
                uniq.append(r)
        if len(uniq) <= 1 or all(u.contains(cmd.region) for u in uniq):
            return uniq[:1]
        return uniq

    # -- reductions -----------------------------------------------------------
    def _emit_reduction_scratch(self, red,
                                mid: int) -> tuple:
        """Allocate + identity-fill one accumulator scratch in ``mid``."""
        buf = red.buffer
        scratch = self.mem.scratch(
            mid, buf.full_box, red.op.acc_dtype(buf.dtype),
            f"alloc red-partial {buf.name} M{mid}")
        fill = self._emit(Instruction(
            InstructionType.FILL_IDENTITY, node=self.node,
            queue=queue_for_mem(mid), allocation=scratch, reduction=red,
            name=f"fill-identity {buf.name} ({red.op.name}) M{mid}"))
        fill.add_dependency(scratch.alloc_instr, DepKind.TRUE)
        return scratch, fill

    def _red_staging(self, rtid: tuple, red, group_size: int) -> dict:
        """Collective-mode staging for one reduction component: slot ``s``
        holds rank ``s``'s partial (own slot written by LOCAL_REDUCE, peer
        slots landed by the exchange rounds)."""
        cst = self._coll_red.setdefault(rtid, {})
        if "staging" not in cst:
            buf = red.buffer
            gbox = Box((0,) * (buf.full_box.rank + 1),
                       (group_size,) + buf.shape)
            cst["staging"] = self.mem.scratch(
                PINNED_HOST, gbox, red.op.acc_dtype(buf.dtype),
                f"alloc red-staging {buf.name}")
        return cst

    def _red_staging_flat(self, rtid: tuple, red) -> dict:
        """Allreduce-mode staging: ONE flat accumulator over the member's
        slot space (flattened buffer elements).  LOCAL_REDUCE writes the
        whole node partial into it; reduce-scatter rounds fold incoming
        slot-range fragments in place; allgather rounds land the final
        folded shards of the other owners (DESIGN.md §9)."""
        cst = self._coll_red.setdefault(rtid, {})
        if "staging" not in cst:
            buf = red.buffer
            cst["staging"] = self.mem.scratch(
                PINNED_HOST, Box((0,), (buf.full_box.volume(),)),
                red.op.acc_dtype(buf.dtype), f"alloc red-acc {buf.name}")
            cst["mode"] = "allreduce"
            cst["tail"] = None          # fold chain: LOCAL_REDUCE, rs folds
        return cst

    def _compile_reduce_partial(self, cmd: Command) -> None:
        """Fold device partials into one node partial, broadcast it (§2.2).

        Collective mode (DESIGN.md §9): the node partial is written straight
        into this rank's slot of the staging allocation — the exchange
        rounds (emitted by the fused COLL_ALLGATHER) read it from there, so
        there is no separate partial scratch and no per-peer broadcast.
        """
        if cmd.collective:
            red, buf = cmd.reduction, cmd.buffer
            st = self._red_state[cmd.transfer_id]
            device_parts = st["device"]
            if cmd.allreduce:
                # flat slot-space accumulator: the whole node partial lands
                # in it, reduce-scatter folds happen in place
                cst = self._red_staging_flat(cmd.transfer_id, red)
                staging = cst["staging"]
                dst_slot = None
                tag = "->acc"
            else:
                cst = self._red_staging(cmd.transfer_id, red,
                                        max(cmd.coll_group) + 1)
                staging = cst["staging"]
                dst_slot = self.node
                tag = f"->slot{self.node}"
            lr = Instruction(
                InstructionType.LOCAL_REDUCE, node=self.node, queue=("host",),
                reduction=red, reduce_srcs=tuple(a for a, _ in device_parts),
                dst_alloc=staging, dst_slot=dst_slot, command=cmd,
                name=f"local-reduce {buf.name} ({red.op.name}) {tag}")
            lr.add_dependency(staging.alloc_instr, DepKind.TRUE)
            for alloc, producer in device_parts:
                lr.add_dependency(producer, DepKind.TRUE)
                if alloc.alloc_instr is not None:
                    lr.add_dependency(alloc.alloc_instr, DepKind.TRUE)
            self._emit(lr)
            cst["local"] = lr
            if cmd.allreduce:
                cst["tail"] = lr
            for alloc, _ in device_parts:
                self.mem.free_scratch(alloc, [lr])
            return
        red, buf = cmd.reduction, cmd.buffer
        st = self._red_state[cmd.transfer_id]
        device_parts: list[tuple] = st["device"]
        partial = self.mem.scratch(
            PINNED_HOST, buf.full_box, red.op.acc_dtype(buf.dtype),
            f"alloc red-node-partial {buf.name}")
        lr = Instruction(
            InstructionType.LOCAL_REDUCE, node=self.node, queue=("host",),
            reduction=red, reduce_srcs=tuple(a for a, _ in device_parts),
            dst_alloc=partial, command=cmd,
            name=f"local-reduce {buf.name} ({red.op.name})")
        lr.add_dependency(partial.alloc_instr, DepKind.TRUE)
        for alloc, producer in device_parts:
            lr.add_dependency(producer, DepKind.TRUE)
            if alloc.alloc_instr is not None:
                lr.add_dependency(alloc.alloc_instr, DepKind.TRUE)
        self._emit(lr)
        st["partial"] = (partial, lr)
        for alloc, _ in device_parts:
            self.mem.free_scratch(alloc, [lr])
        # broadcast the node partial to every other rank; the receiver's
        # GATHER_RECEIVE matches this traffic by its 3-tuple transfer id
        # and lands each payload at its SOURCE rank's slot
        for target in cmd.targets:
            msg_id = next(self._msg_ids)
            send = Instruction(
                InstructionType.SEND, node=self.node, queue=("comm",),
                dest=target, msg_id=msg_id, send_box=buf.full_box,
                recv_alloc=partial, transfer_id=cmd.transfer_id, command=cmd,
                name=f"send red-partial {buf.name} ->N{target}")
            send.add_dependency(lr, DepKind.TRUE)
            if self._last_horizon is not None:
                send.add_dependency(self._last_horizon, DepKind.SYNC)
            self._emit(send)
            st["sends"].append(send)
            self.pilots.append(Pilot(source=self.node, target=target,
                                     transfer_id=cmd.transfer_id,
                                     box=buf.full_box, msg_id=msg_id,
                                     gather=True))

    def _compile_reduce_exchange(self, cmd: Command) -> None:
        """Lower the (fused) reduction allgather into O(log N) rounds.

        One COLL_SEND per (round, message) carries one *packed* payload:
        for every member component of the fusion group, the partial slots
        named by the dissemination schedule.  Each round is independently
        schedulable (a round-k send depends only on the previous rounds'
        landings of the slots it forwards), so rounds of different
        collectives interleave in the out-of-order engine.
        """
        members = cmd.coll_members                 # ((rtid, Reduction), ...)
        group = cmd.coll_group
        gsize = max(group) + 1
        stagings = []
        for rtid, red in members:
            cst = self._red_staging(rtid, red, gsize)
            stagings.append(cst["staging"])
        rounds = schedule_for("allgather", group,
                              contributors=cmd.participants)
        lane = f"N{self.node}.coll.t{cmd.transfer_id[0]}b{cmd.transfer_id[1]}"
        slot_src: dict[int, Instruction] = {}      # slot rank -> landing recv
        recvs: list[Instruction] = []
        sends: list[Instruction] = []
        for k, msgs in enumerate(rounds):
            rtid_k = cmd.transfer_id + (k,)
            for m in msgs:
                if m.dst == self.node:
                    expect = tuple((mi, b) for mi in range(len(members))
                                   for b in m.blocks)
                    rc = Instruction(
                        InstructionType.COLL_RECV, node=self.node,
                        queue=("comm",), transfer_id=rtid_k,
                        coll_source=m.src, coll_allocs=tuple(stagings),
                        coll_expect=expect, command=cmd, trace_lane=lane,
                        name=f"coll-recv r{k} {cmd.buffer.name} <-N{m.src}")
                    for a in stagings:
                        rc.add_dependency(a.alloc_instr, DepKind.TRUE)
                    if self._last_horizon is not None:
                        rc.add_dependency(self._last_horizon, DepKind.SYNC)
                    self._emit(rc)
                    recvs.append(rc)
                    for b in m.blocks:
                        slot_src[b] = rc
                if m.src == self.node:
                    frags = tuple(CollFragment(key=(mi, b),
                                               alloc=stagings[mi], slot=b)
                                  for mi in range(len(members))
                                  for b in m.blocks)
                    msg_id = next(self._msg_ids)
                    sd = Instruction(
                        InstructionType.COLL_SEND, node=self.node,
                        queue=("comm",), dest=m.dst, msg_id=msg_id,
                        transfer_id=rtid_k, coll_frags=frags, command=cmd,
                        trace_lane=lane,
                        name=f"coll-send r{k} {cmd.buffer.name} ->N{m.dst}")
                    for a in stagings:
                        sd.add_dependency(a.alloc_instr, DepKind.TRUE)
                    for b in m.blocks:
                        if b == self.node:
                            for rtid, _ in members:
                                lr = self._coll_red[rtid].get("local")
                                if lr is not None:
                                    sd.add_dependency(lr, DepKind.TRUE)
                        else:
                            rc = slot_src.get(b)
                            if rc is not None:
                                sd.add_dependency(rc, DepKind.TRUE)
                    if self._last_horizon is not None:
                        sd.add_dependency(self._last_horizon, DepKind.SYNC)
                    self._emit(sd)
                    sends.append(sd)
                    self.pilots.append(Pilot(
                        source=self.node, target=m.dst, transfer_id=rtid_k,
                        box=cmd.buffer.full_box, msg_id=msg_id, gather=True))
        shared = dict(recvs=recvs, sends=sends)
        for rtid, _ in members:
            self._coll_red[rtid]["shared"] = shared

    def _compile_allreduce(self, cmd: Command) -> None:
        """Lower the (fused) reduction exchange as reduce-scatter + shard
        allgather (DESIGN.md §9) — ~2/N of the full-partial bytes.

        Phase 1 (recursive halving over the participants): each message
        ships, per fused member, the partial sums of one *slot range* out
        of the flat accumulator; the receiver lands them in a one-shot
        scratch and a LOCAL_REDUCE folds them into the half it keeps
        (fold-on-receive) — communication and fold work interleave inside
        the schedule.  Phase 2 (dissemination allgather over ALL nodes):
        the final folded shards travel as overwrite fragments, landing
        straight into every rank's accumulator.  Both phases share the
        round-tagged transfer-id space of the exchange (allgather rounds
        are offset by the reduce-scatter round count), so rounds remain
        independently schedulable and interleave with other collectives.
        """
        members = cmd.coll_members                 # ((rtid, Reduction), ...)
        group = cmd.coll_group                     # all nodes
        rs_rounds, owner, m = reduce_scatter_schedule(cmd.participants)
        # per fused member: staging accumulator + slot-space shard bounds
        info = []
        for rtid, red in members:
            cst = self._red_staging_flat(rtid, red)
            bounds = shard_bounds(cst["staging"].box.shape[0], m)
            info.append((cst, cst["staging"], red, bounds))
        lane = f"N{self.node}.coll.t{cmd.transfer_id[0]}b{cmd.transfer_id[1]}"
        all_sends: list[Instruction] = []
        ag_recvs: list[Instruction] = []

        def sync_dep(instr: Instruction) -> None:
            if self._last_horizon is not None:
                instr.add_dependency(self._last_horizon, DepKind.SYNC)

        # -- phase 1: reduce-scatter (fold-on-receive) --------------------
        for k, msgs in enumerate(rs_rounds):
            rtid_k = cmd.transfer_id + (k,)
            for msg in msgs:
                s_lo, s_hi = msg.shards
                spans = [(mi, b[s_lo], b[s_hi])
                         for mi, (_, _, _, b) in enumerate(info)
                         if b[s_lo] < b[s_hi]]
                if not spans:
                    continue               # every member's range is empty
                if msg.dst == self.node:
                    scr = {}
                    for mi, lo, hi in spans:
                        cst, _, red, _ = info[mi]
                        scr[mi] = self.mem.scratch(
                            PINNED_HOST, Box((0,), (hi - lo,)),
                            red.op.acc_dtype(red.buffer.dtype),
                            f"alloc rs-recv {red.buffer.name} r{k}")
                    land = tuple(CollFragment(key=(mi, lo, hi),
                                              alloc=scr[mi],
                                              srange=(0, hi - lo))
                                 for mi, lo, hi in spans)
                    rc = Instruction(
                        InstructionType.COLL_RECV, node=self.node,
                        queue=("comm",), transfer_id=rtid_k,
                        coll_source=msg.src,
                        coll_allocs=tuple(scr[mi] for mi, _, _ in spans),
                        coll_expect=tuple(f.key for f in land),
                        coll_land=land, command=cmd, trace_lane=lane,
                        name=f"rs-recv r{k} {cmd.buffer.name} <-N{msg.src}")
                    for a in rc.coll_allocs:
                        rc.add_dependency(a.alloc_instr, DepKind.TRUE)
                    sync_dep(rc)
                    self._emit(rc)
                    for mi, lo, hi in spans:
                        cst, staging, red, _ = info[mi]
                        fold = Instruction(
                            InstructionType.LOCAL_REDUCE, node=self.node,
                            queue=("host",), reduction=red,
                            reduce_srcs=(scr[mi],), dst_alloc=staging,
                            slot_range=(lo, hi), accumulate=True,
                            command=cmd, trace_lane=lane,
                            name=(f"fold r{k} {red.buffer.name} "
                                  f"[{lo}:{hi})"))
                        fold.add_dependency(rc, DepKind.TRUE)
                        fold.add_dependency(staging.alloc_instr, DepKind.TRUE)
                        fold.add_dependency(scr[mi].alloc_instr, DepKind.TRUE)
                        if cst["tail"] is not None:
                            fold.add_dependency(cst["tail"], DepKind.TRUE)
                        self._emit(fold)
                        cst["tail"] = fold
                        self.mem.free_scratch(scr[mi], [fold])
                if msg.src == self.node:
                    frags = tuple(CollFragment(key=(mi, lo, hi),
                                               alloc=info[mi][1],
                                               srange=(lo, hi))
                                  for mi, lo, hi in spans)
                    msg_id = next(self._msg_ids)
                    sd = Instruction(
                        InstructionType.COLL_SEND, node=self.node,
                        queue=("comm",), dest=msg.dst, msg_id=msg_id,
                        transfer_id=rtid_k, coll_frags=frags, command=cmd,
                        trace_lane=lane,
                        name=f"rs-send r{k} {cmd.buffer.name} ->N{msg.dst}")
                    for mi, lo, hi in spans:
                        cst, staging, _, _ = info[mi]
                        sd.add_dependency(staging.alloc_instr, DepKind.TRUE)
                        if cst["tail"] is not None:
                            sd.add_dependency(cst["tail"], DepKind.TRUE)
                    sync_dep(sd)
                    self._emit(sd)
                    all_sends.append(sd)
                    self.pilots.append(Pilot(
                        source=self.node, target=msg.dst, transfer_id=rtid_k,
                        box=cmd.buffer.full_box, msg_id=msg_id, gather=True))

        # -- phase 2: allgather of the folded shards ----------------------
        # a rank contributes iff its shard is non-empty for ANY member;
        # per-member empty fragments are skipped inside each message
        contributors = tuple(sorted(
            r for r, s in owner.items()
            if any(b[s] < b[s + 1] for _, _, _, b in info)))
        ag_rounds = allgather_schedule(group, contributors)
        off = len(rs_rounds)
        shard_src: dict[int, Instruction] = {}     # owner rank -> landing rc

        def shard_frags(blocks):
            """Per-member fragments of the given owners' shards — the SAME
            construction on both sides of a message, so sender keys and
            receiver expected keys never diverge."""
            return tuple(
                CollFragment(key=(mi, b), alloc=staging,
                             srange=(bounds[owner[b]], bounds[owner[b] + 1]))
                for b in blocks
                for mi, (_, staging, _, bounds) in enumerate(info)
                if bounds[owner[b]] < bounds[owner[b] + 1])

        for k, msgs in enumerate(ag_rounds):
            rtid_k = cmd.transfer_id + (off + k,)
            for msg in msgs:
                if msg.dst == self.node:
                    land = shard_frags(msg.blocks)
                    rc = Instruction(
                        InstructionType.COLL_RECV, node=self.node,
                        queue=("comm",), transfer_id=rtid_k,
                        coll_source=msg.src,
                        coll_allocs=tuple(st for _, st, _, _ in info),
                        coll_expect=tuple(f.key for f in land),
                        coll_land=tuple(land), command=cmd, trace_lane=lane,
                        name=f"ag-recv r{k} {cmd.buffer.name} <-N{msg.src}")
                    for _, staging, _, _ in info:
                        rc.add_dependency(staging.alloc_instr, DepKind.TRUE)
                    # landing overwrites partially folded ranges: after the
                    # fold chain and every reduce-scatter send that read them
                    for cst, _, _, _ in info:
                        if cst["tail"] is not None:
                            rc.add_dependency(cst["tail"], DepKind.ANTI)
                    for sd in all_sends:
                        rc.add_dependency(sd, DepKind.ANTI)
                    sync_dep(rc)
                    self._emit(rc)
                    ag_recvs.append(rc)
                    for b in msg.blocks:
                        shard_src[b] = rc
                if msg.src == self.node:
                    msg_id = next(self._msg_ids)
                    sd = Instruction(
                        InstructionType.COLL_SEND, node=self.node,
                        queue=("comm",), dest=msg.dst, msg_id=msg_id,
                        transfer_id=rtid_k, coll_frags=shard_frags(msg.blocks),
                        command=cmd, trace_lane=lane,
                        name=f"ag-send r{k} {cmd.buffer.name} ->N{msg.dst}")
                    for cst, staging, _, _ in info:
                        sd.add_dependency(staging.alloc_instr, DepKind.TRUE)
                    for b in msg.blocks:
                        rc = shard_src.get(b)
                        if rc is not None:
                            sd.add_dependency(rc, DepKind.TRUE)
                        else:          # own fully folded shard
                            for cst, _, _, _ in info:
                                if cst["tail"] is not None:
                                    sd.add_dependency(cst["tail"],
                                                      DepKind.TRUE)
                    sync_dep(sd)
                    self._emit(sd)
                    all_sends.append(sd)
                    self.pilots.append(Pilot(
                        source=self.node, target=msg.dst, transfer_id=rtid_k,
                        box=cmd.buffer.full_box, msg_id=msg_id, gather=True))
        shared = dict(recvs=ag_recvs, sends=all_sends)
        for rtid, _ in members:
            self._coll_red[rtid]["shared"] = shared

    def _compile_reduce_global(self, cmd: Command) -> None:
        """Gather peer partials and fold them in canonical node order."""
        if cmd.collective:
            self._compile_reduce_global_collective(cmd)
            return
        red, buf = cmd.reduction, cmd.buffer
        self._register(buf)
        st = self._red_state.pop(cmd.transfer_id,
                                 {"device": [], "partial": None, "sends": []})
        own_partial = st["partial"]           # (alloc, LOCAL_REDUCE) | None
        peers = tuple(s for s in cmd.participants if s != self.node)

        gather_alloc = None
        gather_instr = None
        if peers:
            # fixed-stride gather staging: slot s holds rank s's partial
            slots = max(peers) + 1
            gbox = Box((0,) * (buf.full_box.rank + 1), (slots,) + buf.shape)
            gather_alloc = self.mem.scratch(
                PINNED_HOST, gbox, red.op.acc_dtype(buf.dtype),
                f"alloc red-gather {buf.name}")
            gather_instr = Instruction(
                InstructionType.GATHER_RECEIVE, node=self.node,
                queue=("comm",), transfer_id=cmd.transfer_id,
                recv_region=buf.full_region, recv_alloc=gather_alloc,
                gather_sources=peers, reduction=red, command=cmd,
                name=f"gather-recv {buf.name} <-{{{','.join(map(str, peers))}}}")
            gather_instr.add_dependency(gather_alloc.alloc_instr, DepKind.TRUE)
            if self._last_horizon is not None:
                gather_instr.add_dependency(self._last_horizon, DepKind.SYNC)
            self._emit(gather_instr)

        # the combined value lands in the buffer's host backing allocation
        dst = self.mem.ensure(buf, PINNED_HOST, buf.full_box)
        full = buf.full_region
        if red.include_current_value:
            # previous contents enter the fold exactly once — every node
            # holds the same replicated value, so this stays deterministic
            self.mem.make_coherent(buf, PINNED_HOST, full)
        ms = self.mem.state(buf.bid, PINNED_HOST)
        gi = Instruction(
            InstructionType.GLOBAL_REDUCE, node=self.node, queue=("host",),
            reduction=red, src_alloc=gather_alloc,
            reduce_srcs=(own_partial[0],) if own_partial else (),
            dst_alloc=dst, participants=cmd.participants,
            include_current=red.include_current_value, command=cmd,
            name=f"global-reduce {buf.name} ({red.op.name})")
        if dst.alloc_instr is not None:
            gi.add_dependency(dst.alloc_instr, DepKind.TRUE)
        if gather_instr is not None:
            gi.add_dependency(gather_instr, DepKind.TRUE)
        if own_partial is not None:
            gi.add_dependency(own_partial[1], DepKind.TRUE)
        kind = DepKind.TRUE if red.include_current_value else DepKind.OUTPUT
        for sub, producer in ms.producers.query(full):
            gi.add_dependency(producer, kind)
        for r, reader in ms.readers:
            if r.overlaps(full):
                gi.add_dependency(reader, DepKind.ANTI)
        if self._last_horizon is not None:
            gi.add_dependency(self._last_horizon, DepKind.SYNC)
        self._emit(gi)
        ms.producers.update(full, gi)
        ms.readers = [(r, t) for r, t in ms.readers
                      if not r.difference(full).is_empty()]
        self.mem.coherence[buf.bid].update(full, frozenset([PINNED_HOST]))
        self.mem.note_write(buf.bid, full)
        # scratch lifetimes: the gather staging dies with the fold; the node
        # partial must also outlive every outbound broadcast send
        if gather_alloc is not None:
            self.mem.free_scratch(gather_alloc, [gi])
        if own_partial is not None:
            self.mem.free_scratch(own_partial[0], [gi] + st["sends"])

    def _compile_reduce_global_collective(self, cmd: Command) -> None:
        """Collective-mode fold: every participant slot (own included) is in
        the staging allocation, so the fold reads ``staging[s]`` for all
        ``s`` in canonical order (``slot_all``) — bitexactness per fused
        component is untouched, only the transport changed."""
        red, buf = cmd.reduction, cmd.buffer
        self._register(buf)
        self._red_state.pop(cmd.transfer_id, None)
        cst = self._coll_red.pop(cmd.transfer_id)
        staging = cst["staging"]
        shared = cst.get("shared", {})
        allreduce = cst.get("mode") == "allreduce"
        dst = self.mem.ensure(buf, PINNED_HOST, buf.full_box)
        full = buf.full_region
        if red.include_current_value:
            self.mem.make_coherent(buf, PINNED_HOST, full)
        ms = self.mem.state(buf.bid, PINNED_HOST)
        gi = Instruction(
            InstructionType.GLOBAL_REDUCE, node=self.node, queue=("host",),
            reduction=red, src_alloc=staging, dst_alloc=dst,
            slot_all=not allreduce, prefolded=allreduce,
            participants=cmd.participants,
            include_current=red.include_current_value, command=cmd,
            name=f"global-reduce {buf.name} ({red.op.name})")
        gi.add_dependency(staging.alloc_instr, DepKind.TRUE)
        if dst.alloc_instr is not None:
            gi.add_dependency(dst.alloc_instr, DepKind.TRUE)
        lr = cst.get("tail") if allreduce else cst.get("local")
        if lr is not None:
            gi.add_dependency(lr, DepKind.TRUE)
        for rc in shared.get("recvs", ()):
            gi.add_dependency(rc, DepKind.TRUE)
        kind = DepKind.TRUE if red.include_current_value else DepKind.OUTPUT
        for sub, producer in ms.producers.query(full):
            gi.add_dependency(producer, kind)
        for r, reader in ms.readers:
            if r.overlaps(full):
                gi.add_dependency(reader, DepKind.ANTI)
        if self._last_horizon is not None:
            gi.add_dependency(self._last_horizon, DepKind.SYNC)
        self._emit(gi)
        ms.producers.update(full, gi)
        ms.readers = [(r, t) for r, t in ms.readers
                      if not r.difference(full).is_empty()]
        self.mem.coherence[buf.bid].update(full, frozenset([PINNED_HOST]))
        self.mem.note_write(buf.bid, full)
        # the member staging dies with its fold, but must outlive every
        # packed exchange send of the whole fusion group
        self.mem.free_scratch(staging, [gi] + list(shared.get("sends", ())))

    # -- region collectives (DESIGN.md §9) ------------------------------------
    def _compile_collective(self, cmd: Command) -> None:
        """Lower a region collective into O(log N) rounds of COLL_SEND /
        COLL_RECV against the buffer's pinned-host backing allocation."""
        buf = cmd.buffer
        self._register(buf)
        kind = {CommandType.COLL_ALLGATHER: "allgather",
                CommandType.COLL_BROADCAST: "broadcast",
                CommandType.COLL_SCATTER: "scatter"}[cmd.ctype]
        group, blocks, root = cmd.coll_group, cmd.coll_blocks, cmd.coll_root
        rounds = schedule_for(kind, group, contributors=tuple(sorted(blocks)),
                              root=root)
        if kind == "allgather":
            own_region = blocks.get(self.node, Region.empty())
        else:
            own_region = Region.empty()
            if self.node == root:
                for r in blocks.values():
                    own_region = own_region.union(r)
        recv_region = Region.empty()
        for msgs in rounds:
            for m in msgs:
                if m.dst == self.node:
                    for b in m.blocks:
                        recv_region = recv_region.union(blocks[b])
        touched = own_region.union(recv_region)
        if touched.is_empty():
            return
        alloc = self.mem.ensure(buf, PINNED_HOST, touched.bounding_box())
        if not own_region.is_empty():
            self.mem.make_coherent(buf, PINNED_HOST, own_region)
        ms = self.mem.state(buf.bid, PINNED_HOST)
        anti_deps: list[Instruction] = []
        if not recv_region.is_empty():
            for r, reader in ms.readers:
                if r.overlaps(recv_region):
                    anti_deps.append(reader)
            for sub, w in ms.producers.query(recv_region):
                anti_deps.append(w)
        lane = f"N{self.node}.coll.t{cmd.transfer_id[0]}b{cmd.transfer_id[1]}"
        block_src: dict[int, Instruction] = {}     # block id -> landing recv
        for k, msgs in enumerate(rounds):
            rtid_k = cmd.transfer_id + (k,)
            for m in msgs:
                if m.dst == self.node:
                    landed = Region.empty()
                    for b in m.blocks:
                        landed = landed.union(blocks[b])
                    expect = tuple(bx for b in m.blocks
                                   for bx in blocks[b].boxes)
                    rc = Instruction(
                        InstructionType.COLL_RECV, node=self.node,
                        queue=("comm",), transfer_id=rtid_k,
                        coll_source=m.src, coll_allocs=(alloc,),
                        coll_expect=expect, recv_region=landed,
                        recv_alloc=alloc, command=cmd, trace_lane=lane,
                        name=f"coll-recv r{k} {buf.name} <-N{m.src}")
                    rc.add_dependency(alloc.alloc_instr, DepKind.TRUE)
                    for a in anti_deps:
                        rc.add_dependency(a, DepKind.ANTI)
                    if self._last_horizon is not None:
                        rc.add_dependency(self._last_horizon, DepKind.SYNC)
                    self._emit(rc)
                    ms.producers.update(landed, rc)
                    for b in m.blocks:
                        block_src[b] = rc
                if m.src == self.node:
                    frags = tuple(CollFragment(key=bx, alloc=alloc, box=bx)
                                  for b in m.blocks
                                  for bx in blocks[b].boxes)
                    sent = Region.empty()
                    for b in m.blocks:
                        sent = sent.union(blocks[b])
                    msg_id = next(self._msg_ids)
                    sd = Instruction(
                        InstructionType.COLL_SEND, node=self.node,
                        queue=("comm",), dest=m.dst, msg_id=msg_id,
                        transfer_id=rtid_k, coll_frags=frags, command=cmd,
                        trace_lane=lane,
                        name=f"coll-send r{k} {buf.name} ->N{m.dst}")
                    sd.add_dependency(alloc.alloc_instr, DepKind.TRUE)
                    for b in m.blocks:
                        rc = block_src.get(b)
                        if rc is not None:
                            sd.add_dependency(rc, DepKind.TRUE)
                        else:   # own data: depend on its producers
                            for psub, producer in ms.producers.query(blocks[b]):
                                sd.add_dependency(producer, DepKind.TRUE)
                    if self._last_horizon is not None:
                        sd.add_dependency(self._last_horizon, DepKind.SYNC)
                    self._emit(sd)
                    ms.readers.append((sent, sd))
                    self.pilots.append(Pilot(
                        source=self.node, target=m.dst, transfer_id=rtid_k,
                        box=sent.bounding_box(), msg_id=msg_id))
        if not recv_region.is_empty():
            # fresh remote data supersedes stale local replicas + spills
            self.mem.coherence[buf.bid].update(recv_region,
                                               frozenset([PINNED_HOST]))
            self.mem.note_write(buf.bid, recv_region)

    # -- synchronization (§3.5) ---------------------------------------------
    def _compile_sync(self, cmd: Command, itype: InstructionType) -> None:
        instr = Instruction(itype, node=self.node, queue=("host",),
                            name=itype.value, command=cmd)
        # every instruction before the previous sync already has a dependent
        # (that sync), so only the tail can contribute to the frontier
        for i in self.instructions[self._frontier_pos:]:
            if not i.dependents:
                instr.add_dependency(i, DepKind.SYNC)
        self._emit(instr)
        if itype == InstructionType.HORIZON:
            self._last_horizon = instr
        else:
            self._last_epoch = instr
            self._last_horizon = None
        # horizon compaction: prior producers collapse onto the sync point
        self.mem.compact_at_sync(instr)
        if self.retire:
            # everything before this sync is transitively dominated by it;
            # the generator only ever wires new deps against the sync point
            del self.instructions[:-1]
            self._frontier_pos = 0
        else:
            self._frontier_pos = len(self.instructions) - 1

    # -- shutdown -------------------------------------------------------------
    def free_all(self) -> list[Instruction]:
        """Emit frees for all live allocations (buffer destruction, §3.2)."""
        return self.mem.free_all()
