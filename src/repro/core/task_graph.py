"""Task graph (TDAG) generation — paper §2.3/§2.4, horizons per §3.5.

Each task represents a cluster-collective operation (usually a kernel).  The
TDAG is generated identically on all nodes; dependencies are computed at
buffer-element granularity as if the program executed on a single device.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from .buffer import Accessor, AccessMode, VirtualBuffer
from .reduction import Reduction
from .region import Box, Region, RegionMap


class TaskType(enum.Enum):
    KERNEL = "kernel"          # device kernel (data-parallel over index space)
    HOST = "host"              # host task (runs in a host thread)
    EPOCH = "epoch"            # graph-based synchronization with main thread
    HORIZON = "horizon"        # tracking-complexity bound / pruning point


class DepKind(enum.Enum):
    TRUE = "true"        # read-after-write (dataflow)
    ANTI = "anti"        # write-after-read
    OUTPUT = "output"    # write-after-write
    SYNC = "sync"        # epoch/horizon graph-synchronization


_task_ids = itertools.count()


@dataclass
class Task:
    ttype: TaskType
    name: str = ""
    index_space: Optional[Box] = None            # kernel tasks only
    accessors: tuple[Accessor, ...] = ()
    reductions: tuple[Reduction, ...] = ()        # reduction outputs (§2.2)
    kernel_fn: Optional[Callable] = None          # (arrays..., chunk) -> outputs
    split_dims: tuple[int, ...] = (0,)            # user hint: split axes
    granularity: tuple[int, ...] = (1,)           # split alignment hint
    tid: int = field(default_factory=lambda: next(_task_ids))
    dependencies: list[tuple["Task", DepKind]] = field(default_factory=list)
    dependents: list["Task"] = field(default_factory=list)
    critical_path: int = 0
    # reduction-fusion chain marker (DESIGN.md §9): stamped by the TDAG on
    # the MAIN thread, so the decision is replicated by construction — the
    # CDAG may merge this task's reduction exchange with the immediately
    # preceding reduction task's exchange (same horizon window, no
    # dependency path between them).
    fuse_with_prev: bool = False

    def add_dependency(self, dep: "Task", kind: DepKind) -> None:
        if dep is self:
            return
        for d, _ in self.dependencies:
            if d is dep:
                return
        self.dependencies.append((dep, kind))
        dep.dependents.append(self)
        self.critical_path = max(self.critical_path, dep.critical_path + 1)

    def __hash__(self) -> int:
        return self.tid

    def __repr__(self) -> str:
        return f"T{self.tid}<{self.ttype.value}:{self.name}>"


@dataclass
class _BufferState:
    """Per-buffer tracking for TDAG dependency generation."""
    last_writers: RegionMap                     # Region -> Task
    last_readers: list[tuple[Region, Task]] = field(default_factory=list)
    initialized: Region = field(default_factory=Region.empty)
    # replicated-pending: the last write was a reduction whose (replicated)
    # result every node will hold once the producing task executes — readers
    # take a TRUE dep on it but the CDAG will never generate pushes for it
    pending_reduction: Optional[Task] = None


class TaskGraph:
    """Generates the TDAG from a stream of submissions.

    Horizon tasks are emitted when the maximum critical-path length grows by
    ``horizon_step`` since the last horizon (Thoman et al. [23]); the horizon
    then *replaces* all previous writers/readers as the dependency frontier,
    bounding tracking structures.
    """

    def __init__(self, horizon_step: int = 4, max_front_width: int = 16,
                 fuse_reductions: bool = True):
        self.tasks: list[Task] = []
        # reduction fusion scope (DESIGN.md §9): the task whose reduction
        # exchange is still "open" for fusion; any non-reduction kernel,
        # horizon/epoch, or dependency path breaks the chain
        self.fuse_reductions = fuse_reductions
        self._red_chain: list[Task] = []
        # prefix retirement (runtime mode): ``tasks[0]`` is lifetime index
        # ``_base``; ``retire_to`` drops broadcast prefixes at sync points so
        # TDAG memory is O(window) on long programs (DESIGN.md §3)
        self._base = 0
        self.horizon_step = horizon_step
        self.max_front_width = max_front_width
        self._buffers: dict[int, _BufferState] = {}
        self._buffer_objs: dict[int, VirtualBuffer] = {}
        self._last_horizon: Optional[Task] = None
        self._prev_horizon: Optional[Task] = None
        self._last_epoch: Optional[Task] = None
        self._cp_at_last_horizon = 0
        self._frontier_pos = 0          # index of the last sync task
        self.warnings: list[str] = []
        # initial epoch — everything hangs off it
        self._last_epoch = self._append(Task(TaskType.EPOCH, name="init"))

    # ------------------------------------------------------------------
    def _append(self, task: Task) -> Task:
        self.tasks.append(task)
        return task

    def _state(self, buf: VirtualBuffer) -> _BufferState:
        st = self._buffers.get(buf.bid)
        if st is None:
            st = _BufferState(last_writers=RegionMap(buf.full_box, default=self._last_epoch))
            if buf.initial_value is not None:
                st.initialized = buf.full_region
            self._buffers[buf.bid] = st
            self._buffer_objs[buf.bid] = buf
        return st

    # ------------------------------------------------------------------
    def submit(self, name: str, index_space: Box | Sequence[int],
               accessors: Sequence[Accessor], kernel_fn: Callable | None = None,
               ttype: TaskType = TaskType.KERNEL,
               split_dims: Sequence[int] = (0,),
               granularity: Sequence[int] = (1,)) -> Task:
        """Submit a command group; returns the created task.

        ``accessors`` may mix :class:`Accessor` and :class:`Reduction`
        descriptors — kernels bind reduction outputs exactly like accessors.
        """
        if not isinstance(index_space, Box):
            index_space = Box.full(tuple(index_space))
        plain = tuple(a for a in accessors if isinstance(a, Accessor))
        reds = tuple(r for r in accessors if isinstance(r, Reduction))
        if len({r.buffer.bid for r in reds}) != len(reds):
            # would collide on the (task, buffer) reduction transfer id
            raise ValueError(f"task {name!r} binds multiple reductions to "
                             f"the same buffer")
        task = Task(ttype, name=name, index_space=index_space,
                    accessors=plain, reductions=reds, kernel_fn=kernel_fn,
                    split_dims=tuple(split_dims), granularity=tuple(granularity))

        for acc in task.accessors:
            st = self._state(acc.buffer)
            region = acc.mapped_region(index_space)
            if acc.mode.is_consumer:
                # uninitialized-read detection (paper §4.4)
                produced = Region.empty()
                for r, _ in st.last_writers.entries:
                    produced = produced.union(r)
                known = st.initialized.union(self._written_region(st))
                missing = region.difference(known)
                if not missing.is_empty():
                    self.warnings.append(
                        f"uninitialized read of {acc.buffer.name} region {missing} in task {name}")
                # true dependencies on last writers
                for sub, writer in st.last_writers.query(region):
                    task.add_dependency(writer, DepKind.TRUE)
                st.last_readers.append((region, task))
            if acc.mode.is_producer:
                # anti-deps on readers of the overwritten region
                for rregion, reader in st.last_readers:
                    if rregion.overlaps(region):
                        task.add_dependency(reader, DepKind.ANTI)
                # output deps on previous writers
                for sub, writer in st.last_writers.query(region):
                    task.add_dependency(writer, DepKind.OUTPUT)
                st.last_writers.update(region, task)
                st.last_readers = [(r, t) for r, t in st.last_readers
                                   if not r.difference(region).is_empty()]
                # any overwrite breaks the pure replicated-pending state
                st.pending_reduction = None

        # reduction outputs: a true-dependency write of the WHOLE buffer on
        # every node at once (N partial producers -> 1 replicated value);
        # with include_current_value the previous contents are consumed too
        for red in task.reductions:
            st = self._state(red.buffer)
            full = red.buffer.full_region
            if red.include_current_value:
                known = st.initialized.union(self._written_region(st))
                missing = full.difference(known)
                if not missing.is_empty():
                    self.warnings.append(
                        f"uninitialized read of {red.buffer.name} region "
                        f"{missing} in reduction of task {name}")
            for rregion, reader in st.last_readers:
                task.add_dependency(reader, DepKind.ANTI)
            for sub, writer in st.last_writers.query(full):
                task.add_dependency(writer,
                                    DepKind.TRUE if red.include_current_value
                                    else DepKind.OUTPUT)
            st.last_writers.update(full, task)
            st.last_readers = []
            st.initialized = full
            st.pending_reduction = task

        if not task.dependencies and self._last_epoch is not None:
            task.add_dependency(self._last_epoch, DepKind.SYNC)
        if self._last_horizon is not None:
            task.add_dependency(self._last_horizon, DepKind.SYNC)

        # reduction-fusion chain (DESIGN.md §9): decided HERE, on the main
        # thread, from replicated TDAG state only — every node scheduler
        # sees the same ``fuse_with_prev`` stamps, so the fused exchange
        # topology is identical everywhere.  A task extends the chain iff it
        # has reductions and no dependency path to any open chain member
        # (a path would make the fused exchange cyclic: the earlier member's
        # result would wait on a partial that waits on the result).
        if reds and self.fuse_reductions:
            if self._red_chain and not self._reaches_any(task, self._red_chain):
                task.fuse_with_prev = True
                self._red_chain.append(task)
            else:
                self._red_chain = [task]
        elif ttype in (TaskType.KERNEL, TaskType.HOST):
            self._red_chain = []          # adjacency broken

        self._append(task)
        self._maybe_emit_horizon(task)
        return task

    def _reaches_any(self, task: Task, targets: list[Task]) -> bool:
        """Transitive dependency check bounded to the open-chain window."""
        lo = targets[0].tid
        target_ids = {t.tid for t in targets}
        stack = [task]
        seen: set[int] = set()
        while stack:
            for dep, _ in stack.pop().dependencies:
                if dep.tid in target_ids:
                    return True
                if dep.tid >= lo and dep.tid not in seen:
                    seen.add(dep.tid)
                    stack.append(dep)
        return False

    def _written_region(self, st: _BufferState) -> Region:
        out = Region.empty()
        for r, v in st.last_writers.entries:
            if isinstance(v, Task) and v.ttype in (TaskType.KERNEL, TaskType.HOST,
                                                   TaskType.HORIZON, TaskType.EPOCH):
                if v.ttype in (TaskType.KERNEL, TaskType.HOST) or v.name != "init":
                    out = out.union(r)
        return out

    # ------------------------------------------------------------------
    def _maybe_emit_horizon(self, task: Task) -> None:
        front = [t for t in self.tasks[-(self.max_front_width * 4):]
                 if not t.dependents and t.ttype == TaskType.KERNEL]
        if (task.critical_path - self._cp_at_last_horizon >= self.horizon_step
                or len(front) >= self.max_front_width):
            self.emit_horizon()

    def emit_horizon(self) -> Task:
        horizon = Task(TaskType.HORIZON, name=f"H@cp{self.tasks[-1].critical_path}")
        # horizon depends on the current execution front; tasks before the
        # previous sync already have a dependent (that sync), so scan the tail
        for t in self.tasks[self._frontier_pos:]:
            if not t.dependents and t is not horizon:
                horizon.add_dependency(t, DepKind.SYNC)
        self._append(horizon)
        self._frontier_pos = len(self.tasks) - 1
        # horizon becomes the new frontier: substitute it for all prior
        # writers/readers so tracking structures stay bounded [23]
        for st in self._buffers.values():
            st.last_writers.update(st.last_writers.covered(), horizon)
            st.last_writers.coalesce()
            st.last_readers = [(r, t) for r, t in st.last_readers
                               if t.critical_path >= horizon.critical_path - self.horizon_step]
        self._prev_horizon, self._last_horizon = self._last_horizon, horizon
        self._cp_at_last_horizon = horizon.critical_path
        self._red_chain = []              # fusion scope ends at the horizon
        return horizon

    def emit_epoch(self, name: str = "epoch") -> Task:
        epoch = Task(TaskType.EPOCH, name=name)
        for t in self.tasks[self._frontier_pos:]:
            if not t.dependents and t is not epoch:
                epoch.add_dependency(t, DepKind.SYNC)
        self._append(epoch)
        self._frontier_pos = len(self.tasks) - 1
        for st in self._buffers.values():
            st.last_writers.update(st.last_writers.covered(), epoch)
            st.last_writers.coalesce()
            st.last_readers = []
        self._last_epoch = epoch
        self._last_horizon = None
        # the epoch compacted every tracking structure — it is a pruning
        # point at least as strong as a horizon, so the horizon cadence
        # restarts here (otherwise a horizon can fire one task after the
        # epoch, and horizon placement depends on cross-epoch phase)
        self._cp_at_last_horizon = epoch.critical_path
        self._red_chain = []              # fusion scope ends at the epoch
        return epoch

    # ------------------------------------------------------------------
    @property
    def task_count(self) -> int:
        """Lifetime number of tasks ever submitted (incl. retired ones)."""
        return self._base + len(self.tasks)

    def retire_to(self, lifetime_idx: int) -> int:
        """Drop the task-list prefix below ``lifetime_idx``, bounded by the
        last sync point (everything before it is transitively dominated by
        that sync and all internal tracking maps were compacted onto it).

        Retired tasks get their dependency lists cleared, breaking the
        reference chain that would otherwise keep the whole task history
        alive through horizon edges.  Callers must only pass indices of
        tasks that every consumer (node scheduler) has already received —
        the CDAG never reads task graph edges, so clearing is safe even if
        a scheduler has not *processed* the task yet.  Returns the number
        of tasks dropped.
        """
        cut = min(lifetime_idx - self._base, self._frontier_pos)
        if cut <= 0:
            return 0
        for t in self.tasks[:cut]:
            t.dependencies.clear()
            t.dependents.clear()
        del self.tasks[:cut]
        self._base += cut
        self._frontier_pos -= cut
        return cut

    # ------------------------------------------------------------------
    def kernel_tasks(self) -> list[Task]:
        return [t for t in self.tasks if t.ttype in (TaskType.KERNEL, TaskType.HOST)]

    def pending_reductions(self) -> dict[int, Task]:
        """Buffers whose last write is a replicated-pending reduction."""
        return {bid: st.pending_reduction for bid, st in self._buffers.items()
                if st.pending_reduction is not None}
