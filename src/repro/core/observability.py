"""Flight recorder: unified metrics + critical-path / wait-state attribution.

The paper's claim is architectural — instruction-graph scheduling moves the
analysis work *off* the latency-critical path — but a claim about a critical
path is only testable with a critical-path analyzer.  This module provides
the measurement substrate the rest of the runtime hooks into:

* :class:`MetricsRegistry` — thread-safe counters, gauges and fixed-bucket
  histograms (p50/p95/p99) behind one namespace, unifying the previously
  scattered stat dicts (``comm_stats``, ``memory_report``,
  ``instant_counts``) into a single ``Runtime.metrics()`` snapshot.
* **Wait-state taxonomy** (:func:`classify_wait`) — every executed
  instruction's issue latency decomposes into *dep-wait* (last-arriving
  predecessor), *budget-wait* (blocked behind eviction/FREE anchors),
  *transport-wait* (pilot/retransmit/ack stalls) and *queue-wait* (lane
  contention).  The decomposition is exact by construction:
  ``pending + queue == t_start - t_reg`` per instruction.
* :func:`critical_path` — walks the completed-instruction records backwards
  along last-arriving-predecessor ("blame") links, crossing into the
  scheduler (cdag/idag) and main-thread (task) spans at the chain head, and
  reports the longest cost-weighted chain with per-layer and per-wait-class
  totals — a machine-readable answer to "is scheduling on the critical
  path, and if not, what is".

Metric naming scheme (DESIGN.md §11): ``layer.node.name``, e.g.
``executor.N0.issue_us``, ``sched.N1.horizon_lag``, ``memory.N0.spills``.
"""

from __future__ import annotations

import threading
from bisect import bisect_right
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

from .instructions import InstructionType

# -- wait-state taxonomy (DESIGN.md §11.2) ----------------------------------

WAIT_DEP = "dep"              # last-arriving predecessor was compute/copy
WAIT_BUDGET = "budget"        # blocked behind FREE/SPILL/RELOAD (eviction)
WAIT_TRANSPORT = "transport"  # blocked behind send/receive completion
WAIT_QUEUE = "queue"          # ready but waiting for a backend lane

WAIT_CLASSES = (WAIT_DEP, WAIT_BUDGET, WAIT_TRANSPORT, WAIT_QUEUE)

_BUDGET_TYPES = frozenset((InstructionType.FREE, InstructionType.SPILL,
                           InstructionType.RELOAD))
_TRANSPORT_TYPES = frozenset((
    InstructionType.SEND, InstructionType.COLL_SEND, InstructionType.RECEIVE,
    InstructionType.SPLIT_RECEIVE, InstructionType.AWAIT_RECEIVE,
    InstructionType.GATHER_RECEIVE, InstructionType.COLL_RECV))


def classify_wait(blame_itype: Optional[InstructionType]) -> str:
    """Wait class of a pending interval, from its last-arriving predecessor.

    ``None`` (no blamed predecessor — e.g. eager issue, or ready at
    registration) defaults to dep-wait: the wait, if any, was for an
    ordinary dependency whose identity the executor did not capture.
    """
    if blame_itype is None:
        return WAIT_DEP
    if blame_itype in _BUDGET_TYPES:
        return WAIT_BUDGET
    if blame_itype in _TRANSPORT_TYPES:
        return WAIT_TRANSPORT
    return WAIT_DEP


# precomputed lookup for the executor completion path (dict.get beats two
# frozenset probes per instruction)
WAIT_OF = {it: classify_wait(it) for it in InstructionType}


# -- histograms --------------------------------------------------------------

_NBUCKETS = 28    # log2 buckets over microseconds: covers ns .. ~2 minutes


class Histogram:
    """Fixed-bucket log2 histogram of microsecond values.

    Bucket ``i`` holds values ``v`` with ``int(v).bit_length() == i``, i.e.
    ``[2^(i-1), 2^i)`` microseconds (bucket 0: ``[0, 1)``).  ``observe`` is
    deliberately branch-light — it sits on the executor issue path.  A
    histogram is single-writer by convention (names embed the node id);
    readers take a point-in-time copy under the registry lock.
    """

    __slots__ = ("counts", "n", "total", "vmax")

    def __init__(self) -> None:
        self.counts = [0] * _NBUCKETS
        self.n = 0
        self.total = 0.0
        self.vmax = 0.0

    def observe(self, us: float) -> None:
        self.n += 1
        self.total += us
        if us > self.vmax:
            self.vmax = us
        i = int(us).bit_length()
        self.counts[i if i < _NBUCKETS else _NBUCKETS - 1] += 1

    def percentile(self, p: float) -> float:
        """Linear-interpolated percentile estimate (exact to bucket width)."""
        if self.n == 0:
            return 0.0
        rank = (p / 100.0) * self.n
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = 0.0 if i == 0 else float(1 << (i - 1))
                hi = float(1 << i)
                est = lo + (hi - lo) * max(0.0, rank - cum) / c
                return min(est, self.vmax) if self.vmax > 0 else est
            cum += c
        return self.vmax

    def snapshot(self) -> dict:
        return dict(count=self.n, sum_us=self.total, max_us=self.vmax,
                    p50=self.percentile(50), p95=self.percentile(95),
                    p99=self.percentile(99))


class MetricsRegistry:
    """Thread-safe metric namespace: counters, gauges, histograms.

    Counters accumulate (monotone), gauges hold the last sampled value, and
    histograms aggregate latency-style observations.  ``histogram()``
    returns the live object so hot paths can cache it and observe without
    touching the registry lock (single-writer per name, see
    :class:`Histogram`).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, Histogram] = {}

    def counter(self, name: str, delta: float = 1.0) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + delta

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            return h

    def observe(self, name: str, us: float) -> None:
        self.histogram(name).observe(us)

    def snapshot(self) -> dict:
        with self._lock:
            return dict(counters=dict(self._counters),
                        gauges=dict(self._gauges),
                        histograms={k: h.snapshot()
                                    for k, h in self._hists.items()})

    def export_counters(self, tracer) -> None:
        """Write final counter/gauge values as Perfetto counter samples."""
        with self._lock:
            items = list(self._counters.items()) + list(self._gauges.items())
        for name, value in items:
            tracer.counter(name, float(value))


# -- per-instruction execution records ---------------------------------------


@dataclass
class InstrRecord:
    """One executed instruction's full timing breakdown (tracer-epoch secs).

    ``t_reg <= t_ready <= t_start <= t_done``: registration at the executor,
    last dependency arrival, backend-lane dequeue, completion.  The issue
    latency ``t_start - t_reg`` decomposes exactly into the pending wait
    (``t_ready - t_reg``, classified by ``wait_cls``) plus the queue wait
    (``t_start - t_ready``).  ``blame_iid`` names the last-arriving
    predecessor (same-node iid) — the critical-path walk follows it.
    """

    __slots__ = ("node", "iid", "kind", "lane", "name", "t_reg", "t_ready",
                 "t_start", "t_done", "wait_cls", "blame_iid", "tid", "cid")

    node: int
    iid: int
    kind: str
    lane: str
    name: str
    t_reg: float
    t_ready: float
    t_start: float
    t_done: float
    wait_cls: str
    blame_iid: Optional[int]
    tid: Optional[int]
    cid: Optional[int]


# -- lane utilization ---------------------------------------------------------


def lane_utilization(records) -> dict:
    """Per-lane busy/idle occupancy from completed :class:`InstrRecord`s.

    Busy time is the union of ``[t_start, t_done]`` execution intervals per
    ``(node, lane)`` (overlaps merged, so concurrent sub-intervals are not
    double-counted); the observation window is the global first-start to
    last-done span.  Returns ``{"N<node>.<lane>": {busy_us, idle_us,
    busy_frac, raw_busy_us, instructions}, ..., "span_us": ...,
    "occupancy": ..., "device_occupancy": ...}``.

    ``occupancy`` is the mean merged busy fraction over all lanes;
    ``raw_busy_us`` is the unmerged per-lane sum of instruction durations.
    A device lane runs one instruction per hardware queue, and the lane key
    merges the queues — so when the issue window keeps several kernels in
    flight, merged busy shrinks while raw busy is conserved.
    ``device_occupancy`` = total raw device-lane busy / (span x device
    lanes) is therefore the pipelining-depth headline: serialized issue
    caps it at the single-queue fraction, overlap raises it (>1 means more
    than one kernel in flight per device on average).
    """
    by_lane: dict[tuple[int, str], list[tuple[float, float]]] = \
        defaultdict(list)
    t0, t1 = float("inf"), float("-inf")
    for r in records:
        if r.t_done <= r.t_start:
            continue
        by_lane[(r.node, r.lane)].append((r.t_start, r.t_done))
        t0 = min(t0, r.t_start)
        t1 = max(t1, r.t_done)
    if not by_lane:
        return dict(span_us=0.0, occupancy=0.0, lanes={})
    span = t1 - t0
    lanes: dict[str, dict] = {}
    fracs: list[float] = []
    dev_raw, dev_lanes = 0.0, 0
    for (node, lane), ivals in sorted(by_lane.items()):
        ivals.sort()
        raw = sum(b - a for a, b in ivals)
        busy = 0.0
        cur_a, cur_b = ivals[0]
        for a, b in ivals[1:]:
            if a > cur_b:
                busy += cur_b - cur_a
                cur_a, cur_b = a, b
            else:
                cur_b = max(cur_b, b)
        busy += cur_b - cur_a
        frac = busy / span if span > 0 else 0.0
        fracs.append(frac)
        if "device" in lane:
            dev_raw += raw
            dev_lanes += 1
        lanes[f"N{node}.{lane}"] = dict(
            busy_us=busy * 1e6, idle_us=max(0.0, span - busy) * 1e6,
            busy_frac=frac, raw_busy_us=raw * 1e6, instructions=len(ivals))
    dev_occ = (dev_raw / (span * dev_lanes)
               if span > 0 and dev_lanes else 0.0)
    return dict(span_us=span * 1e6,
                occupancy=sum(fracs) / len(fracs),
                device_occupancy=dev_occ, lanes=lanes)


# -- critical-path analysis --------------------------------------------------

# instruction kind -> pipeline layer, for the per-layer totals
_LAYER_OF = {
    "device_kernel": "kernel", "host_task": "kernel",
    "alloc": "memory", "free": "memory", "copy": "memory",
    "spill": "memory", "reload": "memory",
    "send": "comm", "coll_send": "comm", "receive": "comm",
    "split_receive": "comm", "await_receive": "comm",
    "gather_receive": "comm", "coll_recv": "comm",
    "fill_identity": "reduce", "local_reduce": "reduce",
    "global_reduce": "reduce",
    "horizon": "sync", "epoch": "sync",
}

_LAYER_ORDER = ("kernel", "comm", "reduce", "memory", "sync", "other",
                "scheduler", "main")


@dataclass
class CriticalPathReport:
    """Longest cost-weighted chain through the completed execution."""

    total_us: float                      # chain start -> final completion
    by_layer: dict[str, float] = field(default_factory=dict)      # us
    by_wait: dict[str, float] = field(default_factory=dict)       # us, on-path
    aggregate_wait_us: dict[str, float] = field(default_factory=dict)
    unattributed_us: float = 0.0
    chain_len: int = 0
    n_instructions: int = 0
    steps: list = field(default_factory=list)     # InstrRecords, end-first

    @property
    def scheduler_fraction(self) -> float:
        """Share of the critical path spent in scheduler lanes (cdag+idag).

        The paper's off-critical-path claim, quantified: this should stay
        well under 1 for execution-bound programs.
        """
        if self.total_us <= 0:
            return 0.0
        return self.by_layer.get("scheduler", 0.0) / self.total_us

    def as_dict(self) -> dict:
        return dict(total_us=self.total_us, by_layer=dict(self.by_layer),
                    by_wait=dict(self.by_wait),
                    aggregate_wait_us=dict(self.aggregate_wait_us),
                    unattributed_us=self.unattributed_us,
                    chain_len=self.chain_len,
                    n_instructions=self.n_instructions,
                    scheduler_fraction=self.scheduler_fraction)

    def render(self) -> str:
        lines = [f"critical path: {self.total_us / 1e3:.2f} ms end-to-end, "
                 f"{self.chain_len} chain steps of "
                 f"{self.n_instructions} traced instructions"]
        lines.append("  on-path time by layer:")
        for layer in _LAYER_ORDER:
            us = self.by_layer.get(layer)
            if us is None:
                continue
            pct = 100.0 * us / self.total_us if self.total_us else 0.0
            note = "   <- scheduling lanes" if layer == "scheduler" else ""
            lines.append(f"    {layer:<10} {us / 1e3:10.3f} ms "
                         f"{pct:5.1f}%{note}")
        if self.unattributed_us > 0:
            pct = 100.0 * self.unattributed_us / self.total_us \
                if self.total_us else 0.0
            lines.append(f"    {'(gaps)':<10} "
                         f"{self.unattributed_us / 1e3:10.3f} ms {pct:5.1f}%")
        if self.by_wait:
            lines.append("  on-path waits: " + "  ".join(
                f"{k}={v / 1e3:.3f}ms" for k, v in
                sorted(self.by_wait.items())))
        if self.aggregate_wait_us:
            lines.append("  aggregate waits (all instructions): " + "  ".join(
                f"{k}={v / 1e3:.3f}ms" for k, v in
                sorted(self.aggregate_wait_us.items())))
        lines.append(f"  scheduler share of critical path: "
                     f"{100.0 * self.scheduler_fraction:.2f}%")
        return "\n".join(lines)


def critical_path(tracer) -> CriticalPathReport:
    """Walk the completed-span DAG backwards along blame links.

    Starting from the last instruction to complete, each step accounts the
    instruction's execution interval to its layer and its queue wait to the
    wait totals, then follows ``blame_iid`` to the predecessor whose
    completion made it ready (monotonically decreasing ``t_done``, so the
    walk terminates).  At the chain head — an instruction that was ready
    the moment it was registered — the walk climbs into the scheduler's
    idag/cdag spans and the main-thread task span via the propagated task
    id, attributing lowering time to the ``scheduler`` and ``main`` layers.
    """
    with tracer._lock:
        recs_list = list(tracer.records)
        spans = list(tracer.spans)
    recs = {(r.node, r.iid): r for r in recs_list}
    if not recs:
        return CriticalPathReport(total_us=0.0)

    # scheduler / main spans indexed by the propagated task id
    sched_spans: dict[tuple[int, int, str], object] = {}
    task_spans: dict[int, object] = {}
    for s in spans:
        meta = s.meta
        if not meta:
            continue
        tid = meta.get("tid")
        if tid is None:
            continue
        if s.kind == "task":
            task_spans[tid] = s
        elif s.kind in ("cdag", "idag") and s.lane.startswith("sched-N"):
            node = int(s.lane[len("sched-N"):])
            sched_spans[(node, tid, s.kind)] = s

    by_layer: dict[str, float] = defaultdict(float)
    by_wait: dict[str, float] = defaultdict(float)
    agg_wait: dict[str, float] = defaultdict(float)
    for r in recs_list:
        agg_wait[r.wait_cls] += max(0.0, r.t_ready - r.t_reg) * 1e6
        agg_wait[WAIT_QUEUE] += max(0.0, r.t_start - r.t_ready) * 1e6

    # unified activity timeline for temporal-predecessor jumps: when the
    # causal (blame) chain dries up at an instruction that was ready the
    # moment it was registered, the run before that point was bounded by
    # whatever finished last — another instruction, a scheduler lowering
    # span, or a main-thread submission span — so all three are walkable.
    acts: list[tuple[float, str, object]] = \
        [(r.t_done, "rec", r) for r in recs_list]
    for s in sched_spans.values():
        acts.append((s.t1, "scheduler", s))
    for s in task_spans.values():
        acts.append((s.t1, "main", s))
    acts.sort(key=lambda a: a[0])
    ends = [a[0] for a in acts]
    eps = 1e-6

    cur = max(recs_list, key=lambda r: r.t_done)
    end = cur.t_done
    # earliest instant already accounted: every interval is clipped against
    # it before being added, so the walk's decomposition is DISJOINT — the
    # layer + wait totals can never exceed the end-to-end time, and the
    # remainder is reported honestly as unattributed gaps
    frontier = end
    steps: list[InstrRecord] = []
    visited: set[tuple[int, int]] = set()
    span_seen: set[int] = set()

    def account(dst: dict, key: str, a: float, b: float) -> None:
        nonlocal frontier
        b = min(b, frontier)
        if b <= a:
            return
        dst[key] += (b - a) * 1e6
        frontier = a

    while cur is not None:
        visited.add((cur.node, cur.iid))
        steps.append(cur)
        account(by_layer, _LAYER_OF.get(cur.kind, "other"),
                cur.t_start, cur.t_done)
        nxt = recs.get((cur.node, cur.blame_iid)) \
            if cur.blame_iid is not None else None
        if nxt is not None and nxt.t_done < cur.t_done \
                and (nxt.node, nxt.iid) not in visited:
            # the predecessor's own execution explains the pending interval
            # (and, for eager issue, part of the in-queue interval too);
            # only the slack after its completion counts as a wait
            account(by_wait, WAIT_QUEUE,
                    max(cur.t_ready, nxt.t_done), cur.t_start)
            account(by_wait, cur.wait_cls,
                    max(cur.t_reg, nxt.t_done), cur.t_ready)
            cur = nxt
            continue
        account(by_wait, WAIT_QUEUE, cur.t_ready, cur.t_start)
        # chain head: no recorded predecessor — the pending interval is a
        # genuine unexplained wait, and lowering time becomes visible
        account(by_wait, cur.wait_cls, cur.t_reg, cur.t_ready)
        if cur.tid is not None:
            for kind in ("idag", "cdag"):
                s = sched_spans.get((cur.node, cur.tid, kind))
                if s is not None and id(s) not in span_seen:
                    span_seen.add(id(s))
                    account(by_layer, "scheduler", s.t0, s.t1)
            ts = task_spans.get(cur.tid)
            if ts is not None and id(ts) not in span_seen:
                span_seen.add(id(ts))
                account(by_layer, "main", ts.t0, ts.t1)
        # temporal predecessor: the last unvisited activity before the
        # accounted frontier (any remaining gap stays unattributed);
        # scheduler/main spans encountered here are accounted in place and
        # the scan continues until the next instruction record is found
        cur = None
        i = bisect_right(ends, frontier + eps) - 1
        while i >= 0 and cur is None:
            t1, akind, obj = acts[i]
            i -= 1
            if akind == "rec":
                if (obj.node, obj.iid) not in visited:
                    cur = obj
            elif id(obj) not in span_seen:
                span_seen.add(id(obj))
                account(by_layer, akind, obj.t0, obj.t1)
                i = bisect_right(ends, frontier + eps) - 1

    total_us = max(0.0, end - frontier) * 1e6
    accounted = sum(by_layer.values()) + sum(by_wait.values())
    return CriticalPathReport(
        total_us=total_us, by_layer=dict(by_layer), by_wait=dict(by_wait),
        aggregate_wait_us=dict(agg_wait),
        unattributed_us=max(0.0, total_us - accounted),
        chain_len=len(steps), n_instructions=len(recs_list), steps=steps)
