"""Allocation and memory-id model (paper §3.2) plus residency state.

Memory ids: ``M0`` = user-controlled host memory, ``M1`` = DMA-capable
(page-locked) host memory, ``M2+d`` = dedicated memory of device ``d``.
Concrete addresses only exist at execution time; the graph refers to
allocations by numeric *allocation ids*.

Residency/lifetime fields (``last_use``, ``evictable``) are maintained by
:class:`repro.core.memory.MemoryManager`, which owns the allocation
lifecycle: per-memory byte budgets, LRU eviction order and spill-to-host
chains under budget pressure.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

from .region import Box

USER_HOST = 0    # M0
PINNED_HOST = 1  # M1


def device_memory(device: int) -> int:
    return 2 + device


def is_device_memory(mid: int) -> bool:
    return mid >= 2


def queue_for_mem(mid: int) -> tuple:
    """Executor queue affinity of memory operations in ``mid``."""
    if is_device_memory(mid):
        return ("device", mid - 2)
    return ("host",)


_alloc_ids = itertools.count(1)


@dataclass(eq=False)
class Allocation:
    """A backing allocation for a buffer subregion in one memory.

    Identity semantics (``eq=False``): every allocation has a unique ``aid``;
    comparing field-wise would recurse through ``alloc_instr``/``initial_data``.
    """

    mid: int
    bid: Optional[int]            # buffer id; None for scratch
    box: Box                      # buffer-space box this allocation backs
    dtype: object = "float64"     # numpy dtype of the backing array
    aid: int = field(default_factory=lambda: next(_alloc_ids))
    live: bool = True
    # residency state, owned by the MemoryManager:
    last_use: int = 0             # logical LRU clock of the last touch
    evictable: bool = True        # one-shot scratches opt out of eviction
    # the ALLOC instruction that materializes this allocation (wired by the
    # memory manager; dependencies of every user point at it)
    alloc_instr: Optional[object] = None
    # M0 allocations seeded from user data carry it for lazy materialization
    initial_data: Optional[object] = None
    # renaming (DESIGN.md §13): when this physical is retired to the free
    # pool, the readers/producers of its last buffer version are snapshotted
    # here; the next writer of the recycled physical anti-depends on them
    hazards: list = field(default_factory=list)

    def nbytes(self) -> int:
        import numpy as np
        return self.box.volume() * np.dtype(self.dtype).itemsize

    def offset_of(self, b: Box) -> tuple[int, ...]:
        """Offset of buffer-space box ``b`` inside this allocation."""
        if not self.box.contains(b):
            raise ValueError(f"{b} not contained in allocation {self.box}")
        return tuple(x - o for x, o in zip(b.min, self.box.min))

    def __repr__(self) -> str:
        return f"A{self.aid}<M{self.mid},B{self.bid},{self.box}>"
