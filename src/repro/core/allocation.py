"""Allocation and memory-id model (paper §3.2).

Memory ids: ``M0`` = user-controlled host memory, ``M1`` = DMA-capable
(page-locked) host memory, ``M2+d`` = dedicated memory of device ``d``.
Concrete addresses only exist at execution time; the graph refers to
allocations by numeric *allocation ids*.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

from .region import Box

USER_HOST = 0    # M0
PINNED_HOST = 1  # M1


def device_memory(device: int) -> int:
    return 2 + device


def is_device_memory(mid: int) -> bool:
    return mid >= 2


_alloc_ids = itertools.count(1)


@dataclass
class Allocation:
    """A backing allocation for a buffer subregion in one memory."""

    mid: int
    bid: Optional[int]            # buffer id; None for scratch
    box: Box                      # buffer-space box this allocation backs
    dtype: object = "float64"     # numpy dtype of the backing array
    aid: int = field(default_factory=lambda: next(_alloc_ids))
    live: bool = True

    def nbytes(self) -> int:
        import numpy as np
        return self.box.volume() * np.dtype(self.dtype).itemsize

    def offset_of(self, b: Box) -> tuple[int, ...]:
        """Offset of buffer-space box ``b`` inside this allocation."""
        if not self.box.contains(b):
            raise ValueError(f"{b} not contained in allocation {self.box}")
        return tuple(x - o for x, o in zip(b.min, self.box.min))

    def __repr__(self) -> str:
        return f"A{self.aid}<M{self.mid},B{self.bid},{self.box}>"
