"""Collective exchange topologies (DESIGN.md §9).

Pure, replicated-deterministic schedule functions shared by the CDAG
(collective detection + dependency wiring) and the IDAG (lowering into
per-round ``COLL_SEND`` / ``COLL_RECV`` instructions).  A schedule is a
list of *rounds*; each round is a list of :class:`CollMsg` — one point-to-
point message carrying a set of *blocks* (identified by absolute rank).

* **Allgather** uses the dissemination (Bruck-style) generalization of
  recursive doubling: at round ``k`` every rank receives from the rank
  ``2^k`` below it (mod P) everything that peer holds and it does not.
  Works for ANY group size in ``ceil(log2 P)`` rounds with at most one
  message per rank per round — total message count ``<= P * ceil(log2 P)``
  versus ``P * (P - 1)`` for the all-pairs exchange.  Ranks without an own
  contribution (e.g. non-participant nodes of a reduction) simply start
  with an empty held set and forward what they receive.
* **Broadcast / scatter** use a binomial tree rooted at the data owner:
  ``ceil(log2 P)`` rounds, ``P - 1`` messages total, the root sends only
  ``ceil(log2 P)`` of them.  Scatter messages carry exactly the blocks of
  the receiver's subtree, so payloads halve per hop.
* **Reduce-scatter** (the first phase of the allreduce, DESIGN.md §9)
  uses recursive halving: each round a rank folds the incoming slot-range
  fragment into the half of its accumulator it keeps and sends the other
  half, so after ``log2 m`` rounds each of the ``m`` active ranks owns one
  fully folded shard of the slot space.  Non-power-of-two groups use the
  standard pre-fold: the ``P - m`` excess ranks ship their whole partial
  to a neighbour and drop out of the halving.  The schedule works in
  *shard index* space (``m`` shards), so fused reduction members of
  different sizes share one message structure and map shard ranges to
  their own slot ranges via :func:`shard_bounds`.

Every round is independently schedulable: a round-``k`` send depends only
on the previous rounds' receives of the blocks it forwards, so rounds of
different collectives interleave freely in the out-of-order executor.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence


@dataclass(frozen=True)
class CollMsg:
    """One message of one round: ``src`` sends ``blocks`` to ``dst``.

    Ranks are absolute node ids; block ids are absolute ranks too (the
    contributor whose piece/partial the block carries).
    """

    src: int
    dst: int
    blocks: tuple[int, ...]


def num_rounds(p: int) -> int:
    """``ceil(log2 p)`` — rounds needed to span a group of ``p`` ranks."""
    r = 0
    while (1 << r) < p:
        r += 1
    return r


def allgather_schedule(group: Sequence[int],
                       contributors: Sequence[int]) -> list[list[CollMsg]]:
    """Dissemination allgather over ``group``; any size, any contributor set.

    After round ``k`` rank ``j`` holds the initial blocks of ranks
    ``j, j-1, ..., j-(2^(k+1)-1)`` (mod P), so ``ceil(log2 P)`` rounds
    deliver every contribution everywhere.  Messages whose block set would
    be empty are skipped, keeping the total ``<= P * ceil(log2 P)``.
    """
    ranks = list(group)
    p = len(ranks)
    pos = {r: i for i, r in enumerate(ranks)}
    held: list[set[int]] = [set() for _ in range(p)]
    for c in contributors:
        held[pos[c]].add(c)
    rounds: list[list[CollMsg]] = []
    for k in range(num_rounds(p)):
        d = 1 << k
        snapshot = [set(h) for h in held]
        msgs: list[CollMsg] = []
        for j in range(p):
            i = (j - d) % p               # j receives from i
            blocks = snapshot[i] - snapshot[j]
            if blocks:
                msgs.append(CollMsg(ranks[i], ranks[j], tuple(sorted(blocks))))
                held[j] |= blocks
        rounds.append(msgs)
    return rounds


def tree_schedule(group: Sequence[int], root: int, *,
                  scatter: bool = False) -> list[list[CollMsg]]:
    """Binomial-tree broadcast (or scatter) rounds rooted at ``root``.

    Relative rank 0 is the root; at the round with distance ``d`` every
    holder ``r`` (``r % 2d == 0``) sends to ``r + d``.  For a broadcast the
    payload is always the root's full block; for a scatter the message
    carries exactly the blocks of the receiver's subtree
    (relative ranks ``[r+d, r+2d)``), so no rank ever receives data it
    neither consumes nor forwards.
    """
    rel = [root] + sorted(x for x in group if x != root)
    p = len(rel)
    rounds: list[list[CollMsg]] = []
    for k in reversed(range(num_rounds(p))):
        d = 1 << k
        msgs: list[CollMsg] = []
        for r in range(0, p, 2 * d):
            if r + d < p:
                blocks = (tuple(rel[r + d:min(r + 2 * d, p)]) if scatter
                          else (root,))
                msgs.append(CollMsg(rel[r], rel[r + d], blocks))
        rounds.append(msgs)
    return rounds


@dataclass(frozen=True)
class RsMsg:
    """One reduce-scatter message: ``src`` sends the partial sums of the
    shard index range ``shards = (lo, hi)`` to ``dst``, which folds them
    into its own accumulator (fold-on-receive)."""

    src: int
    dst: int
    shards: tuple[int, int]


def shard_bounds(num_slots: int, num_shards: int) -> list[int]:
    """Slot-space boundaries of an even partition into ``num_shards``.

    ``bounds[s] = s * num_slots // num_shards``; shard ``s`` covers slots
    ``[bounds[s], bounds[s+1])``.  Degenerate shards (fewer slots than
    shards) are empty ranges — their messages are simply skipped, which
    every rank derives identically from the replicated schedule.
    """
    return [s * num_slots // num_shards for s in range(num_shards + 1)]


def reduce_scatter_schedule(
        group: Sequence[int]) -> tuple[list[list[RsMsg]], dict[int, int], int]:
    """Recursive-halving reduce-scatter over ``group``, in shard space.

    Returns ``(rounds, owner, m)`` where ``m`` is the largest power of two
    ``<= len(group)``, ``owner`` maps each of the ``m`` *active* ranks to
    the single shard index it ends up owning fully folded, and ``rounds``
    is the message schedule:

    * **pre-fold round** (non-power-of-two only): rank ``2i+1`` of the
      first ``2(P - m)`` ranks sends its whole partial (all ``m`` shards)
      to rank ``2i`` and drops out of the halving;
    * **halving rounds**: at distance ``d = m/2, m/4, ..., 1`` active
      ranks pair up (``i`` with ``i ^ d`` in active-index space); the pair
      holds an identical shard range, the lower index keeps the lower
      half and receives+folds it, the upper index keeps the upper half.

    Each active rank sends and receives at most one message per round, so
    fold-on-receive is a simple per-rank chain.  Total slot traffic is
    ``~(P-1)/P`` of the slot space per rank versus the full slot space
    ``P-1`` times over for the full-partial allgather — combined with the
    shard allgather the allreduce ships ``~2/P`` of the bytes.
    """
    ranks = list(group)
    p = len(ranks)
    m = 1
    while m * 2 <= p:
        m *= 2
    r = p - m
    rounds: list[list[RsMsg]] = []
    if r:
        rounds.append([RsMsg(src=ranks[2 * i + 1], dst=ranks[2 * i],
                             shards=(0, m)) for i in range(r)])
    active = [ranks[2 * i] for i in range(r)] + ranks[2 * r:]
    span: list[tuple[int, int]] = [(0, m)] * m
    d = m // 2
    while d >= 1:
        msgs: list[RsMsg] = []
        for i in range(m):
            j = i ^ d
            if j < i:
                continue
            lo, hi = span[i]                  # == span[j] by construction
            mid = (lo + hi) // 2
            # i (bit clear) keeps the lower half, j the upper half
            msgs.append(RsMsg(active[i], active[j], (mid, hi)))
            msgs.append(RsMsg(active[j], active[i], (lo, mid)))
            span[i] = (lo, mid)
            span[j] = (mid, hi)
        rounds.append(msgs)
        d //= 2
    owner = {active[i]: span[i][0] for i in range(m)}
    return rounds, owner, m


def allreduce_message_count(participants: Sequence[int],
                            group: Sequence[int], num_slots: int) -> int:
    """Wire messages of one reduction exchange under the default policy
    (used by tests/examples as the oracle): the reduce-scatter + shard
    allgather at >= 3 nodes, the full-partial slot allgather below (where
    the decomposition cannot reduce bytes — see CommandGraphGenerator).

    ``num_slots`` models ONE member size; for fused groups it is exact
    only when every member has that size (a message is skipped only when
    EVERY member's slot range is empty, so mixed-size groups ship the
    union of the per-member message sets and this count is a floor).
    """
    if len(group) < 3:
        return message_count(allgather_schedule(group, participants))
    rs_rounds, owner, m = reduce_scatter_schedule(participants)
    bounds = shard_bounds(num_slots, m)
    n = sum(1 for msgs in rs_rounds for msg in msgs
            if bounds[msg.shards[0]] < bounds[msg.shards[1]])
    contributors = tuple(sorted(a for a, s in owner.items()
                                if bounds[s] < bounds[s + 1]))
    n += message_count(allgather_schedule(group, contributors))
    return n


def schedule_for(kind: str, group: Sequence[int], *,
                 contributors: Sequence[int] = (),
                 root: int | None = None) -> list[list[CollMsg]]:
    """Uniform entry point used by CDAG and IDAG (must agree bit-for-bit)."""
    if kind == "allgather":
        return allgather_schedule(group, contributors)
    if kind == "broadcast":
        return tree_schedule(group, root, scatter=False)
    if kind == "scatter":
        return tree_schedule(group, root, scatter=True)
    raise ValueError(f"unknown collective kind {kind!r}")


def message_count(rounds: list[list[CollMsg]]) -> int:
    return sum(len(msgs) for msgs in rounds)
