"""Collective exchange topologies (DESIGN.md §9).

Pure, replicated-deterministic schedule functions shared by the CDAG
(collective detection + dependency wiring) and the IDAG (lowering into
per-round ``COLL_SEND`` / ``COLL_RECV`` instructions).  A schedule is a
list of *rounds*; each round is a list of :class:`CollMsg` — one point-to-
point message carrying a set of *blocks* (identified by absolute rank).

* **Allgather** uses the dissemination (Bruck-style) generalization of
  recursive doubling: at round ``k`` every rank receives from the rank
  ``2^k`` below it (mod P) everything that peer holds and it does not.
  Works for ANY group size in ``ceil(log2 P)`` rounds with at most one
  message per rank per round — total message count ``<= P * ceil(log2 P)``
  versus ``P * (P - 1)`` for the all-pairs exchange.  Ranks without an own
  contribution (e.g. non-participant nodes of a reduction) simply start
  with an empty held set and forward what they receive.
* **Broadcast / scatter** use a binomial tree rooted at the data owner:
  ``ceil(log2 P)`` rounds, ``P - 1`` messages total, the root sends only
  ``ceil(log2 P)`` of them.  Scatter messages carry exactly the blocks of
  the receiver's subtree, so payloads halve per hop.

Every round is independently schedulable: a round-``k`` send depends only
on the previous rounds' receives of the blocks it forwards, so rounds of
different collectives interleave freely in the out-of-order executor.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence


@dataclass(frozen=True)
class CollMsg:
    """One message of one round: ``src`` sends ``blocks`` to ``dst``.

    Ranks are absolute node ids; block ids are absolute ranks too (the
    contributor whose piece/partial the block carries).
    """

    src: int
    dst: int
    blocks: tuple[int, ...]


def num_rounds(p: int) -> int:
    """``ceil(log2 p)`` — rounds needed to span a group of ``p`` ranks."""
    r = 0
    while (1 << r) < p:
        r += 1
    return r


def allgather_schedule(group: Sequence[int],
                       contributors: Sequence[int]) -> list[list[CollMsg]]:
    """Dissemination allgather over ``group``; any size, any contributor set.

    After round ``k`` rank ``j`` holds the initial blocks of ranks
    ``j, j-1, ..., j-(2^(k+1)-1)`` (mod P), so ``ceil(log2 P)`` rounds
    deliver every contribution everywhere.  Messages whose block set would
    be empty are skipped, keeping the total ``<= P * ceil(log2 P)``.
    """
    ranks = list(group)
    p = len(ranks)
    pos = {r: i for i, r in enumerate(ranks)}
    held: list[set[int]] = [set() for _ in range(p)]
    for c in contributors:
        held[pos[c]].add(c)
    rounds: list[list[CollMsg]] = []
    for k in range(num_rounds(p)):
        d = 1 << k
        snapshot = [set(h) for h in held]
        msgs: list[CollMsg] = []
        for j in range(p):
            i = (j - d) % p               # j receives from i
            blocks = snapshot[i] - snapshot[j]
            if blocks:
                msgs.append(CollMsg(ranks[i], ranks[j], tuple(sorted(blocks))))
                held[j] |= blocks
        rounds.append(msgs)
    return rounds


def tree_schedule(group: Sequence[int], root: int, *,
                  scatter: bool = False) -> list[list[CollMsg]]:
    """Binomial-tree broadcast (or scatter) rounds rooted at ``root``.

    Relative rank 0 is the root; at the round with distance ``d`` every
    holder ``r`` (``r % 2d == 0``) sends to ``r + d``.  For a broadcast the
    payload is always the root's full block; for a scatter the message
    carries exactly the blocks of the receiver's subtree
    (relative ranks ``[r+d, r+2d)``), so no rank ever receives data it
    neither consumes nor forwards.
    """
    rel = [root] + sorted(x for x in group if x != root)
    p = len(rel)
    rounds: list[list[CollMsg]] = []
    for k in reversed(range(num_rounds(p))):
        d = 1 << k
        msgs: list[CollMsg] = []
        for r in range(0, p, 2 * d):
            if r + d < p:
                blocks = (tuple(rel[r + d:min(r + 2 * d, p)]) if scatter
                          else (root,))
                msgs.append(CollMsg(rel[r], rel[r + d], blocks))
        rounds.append(msgs)
    return rounds


def schedule_for(kind: str, group: Sequence[int], *,
                 contributors: Sequence[int] = (),
                 root: int | None = None) -> list[list[CollMsg]]:
    """Uniform entry point used by CDAG and IDAG (must agree bit-for-bit)."""
    if kind == "allgather":
        return allgather_schedule(group, contributors)
    if kind == "broadcast":
        return tree_schedule(group, root, scatter=False)
    if kind == "scatter":
        return tree_schedule(group, root, scatter=True)
    raise ValueError(f"unknown collective kind {kind!r}")


def message_count(rounds: list[list[CollMsg]]) -> int:
    return sum(len(msgs) for msgs in rounds)
