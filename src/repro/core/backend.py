"""Backend submission queues (paper §4, fig. 5).

The executor offloads actual work to *backend* lanes so submission latency
stays off its polling loop:

* ``InOrderQueue`` — models a SYCL in-order queue: one worker thread drains a
  FIFO.  The executor's *eager issue* rule (§4.1) relies on this FIFO
  guarantee: an instruction whose incomplete dependencies are all enqueued on
  the same in-order queue may be submitted immediately.
* ``HostPool`` — a pool of host worker threads for host tasks and host-side
  copies (no ordering guarantee; used only for *direct* issue).

Both report completions through a shared thread-safe completion list that the
executor drains in its polling loop, mirroring the event-polling approach the
paper adopts from [18]/[4].
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional


@dataclass
class WorkItem:
    """One unit of backend work: ``fn(tag)`` is invoked on the lane thread.

    Passing the tag (typically the Instruction) as the argument lets the
    executor submit bound methods directly instead of allocating a closure
    per instruction on the issue fast path.
    """
    fn: Callable[[object], None]
    tag: object = None                     # typically the Instruction


class CompletionSink:
    """Thread-safe sink of finished work items, drained by the executor."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._done: list[tuple[object, Optional[BaseException], float]] = []
        self.event = threading.Event()

    def push(self, tag: object, err: Optional[BaseException], latency: float) -> None:
        with self._lock:
            self._done.append((tag, err, latency))
        if not self.event.is_set():
            self.event.set()

    def drain(self) -> list[tuple[object, Optional[BaseException], float]]:
        # clear BEFORE swapping: a push racing with the swap leaves the event
        # set for the next loop iteration instead of being lost (the executor
        # blocks on this event, so a lost wake-up would stall a full timeout)
        self.event.clear()
        with self._lock:
            out, self._done = self._done, []
        return out


class InOrderQueue:
    """A FIFO worker thread — the analogue of a SYCL in-order queue."""

    def __init__(self, name: str, sink: CompletionSink):
        self.name = name
        self.sink = sink
        self._q: "queue.SimpleQueue[Optional[WorkItem]]" = queue.SimpleQueue()
        self._pending = 0                   # submitted, not yet completed
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._run, name=name, daemon=True)
        self._thread.start()

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending

    def submit(self, item: WorkItem) -> None:
        with self._lock:
            self._pending += 1
        self._q.put(item)

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            err: Optional[BaseException] = None
            t0 = time.perf_counter()
            try:
                item.fn(item.tag)
            except BaseException as e:  # noqa: BLE001 — reported to executor
                err = e
            with self._lock:
                self._pending -= 1
            self.sink.push(item.tag, err, time.perf_counter() - t0)

    def shutdown(self, join_timeout: float = 5.0) -> int:
        """Stop the worker; returns 1 if it failed to join (leaked)."""
        self._q.put(None)
        self._thread.join(timeout=join_timeout)
        return 1 if self._thread.is_alive() else 0


class HostPool:
    """N host worker threads sharing one FIFO (no per-item ordering)."""

    def __init__(self, name: str, num_threads: int, sink: CompletionSink):
        self.name = name
        self.sink = sink
        self._q: "queue.SimpleQueue[Optional[WorkItem]]" = queue.SimpleQueue()
        self._threads = [threading.Thread(target=self._run, name=f"{name}-{i}",
                                          daemon=True)
                         for i in range(num_threads)]
        for t in self._threads:
            t.start()

    def submit(self, item: WorkItem) -> None:
        self._q.put(item)

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.put(None)           # propagate shutdown to siblings
                return
            err: Optional[BaseException] = None
            t0 = time.perf_counter()
            try:
                item.fn(item.tag)
            except BaseException as e:  # noqa: BLE001
                err = e
            self.sink.push(item.tag, err, time.perf_counter() - t0)

    def shutdown(self, join_timeout: float = 5.0) -> int:
        """Stop all workers; returns how many failed to join (leaked)."""
        self._q.put(None)
        leaked = 0
        for t in self._threads:
            t.join(timeout=join_timeout)
            if t.is_alive():
                leaked += 1
        return leaked


class Backend:
    """All backend lanes of one node: per-device in-order queues + host pool.

    ``queues_per_device`` > 1 enables the paper's scheme of multiple in-order
    queues per device so independent copy/kernel instructions overlap (§4.1).
    A device instruction is routed round-robin unless eager issue pins it to
    the queue its dependencies are already on.
    """

    def __init__(self, num_devices: int, *, queues_per_device: int = 2,
                 host_threads: int = 4):
        self.sink = CompletionSink()
        self.num_devices = num_devices
        self.queues_per_device = queues_per_device
        self.device_queues: list[list[InOrderQueue]] = [
            [InOrderQueue(f"D{d}.q{i}", self.sink) for i in range(queues_per_device)]
            for d in range(num_devices)
        ]
        self.host_pool = HostPool("host", host_threads, self.sink)
        self._rr = [0] * num_devices

    def pick_device_queue(self, device: int,
                          preferred: Optional[InOrderQueue] = None) -> InOrderQueue:
        if preferred is not None:
            return preferred
        qs = self.device_queues[device]
        # prefer an idle queue, else round-robin
        for q in qs:
            if q.pending == 0:
                return q
        self._rr[device] = (self._rr[device] + 1) % len(qs)
        return qs[self._rr[device]]

    def shutdown(self, join_timeout: float = 5.0) -> int:
        """Stop every lane; returns the total leaked-thread count."""
        leaked = 0
        for qs in self.device_queues:
            for q in qs:
                leaked += q.shutdown(join_timeout)
        leaked += self.host_pool.shutdown(join_timeout)
        return leaked
