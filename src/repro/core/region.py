"""Box/region algebra for buffer subrange tracking.

Celerity tracks dataflow at the granularity of individual buffer elements by
operating on *regions*: finite unions of pairwise-disjoint, half-open,
axis-aligned N-dimensional boxes.  Every layer of the scheduler (task graph,
command graph, instruction graph) is built on this algebra, so it must be
exact — the hypothesis test-suite checks it against a brute-force bitmap
oracle.

Boxes are represented as ``(min, max)`` tuples of per-dimension integers with
half-open semantics ``min <= i < max``.  Empty boxes are normalized away.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Iterable, Iterator, Sequence


@dataclass(frozen=True)
class Box:
    """A half-open axis-aligned box ``[min, max)`` in N dimensions."""

    min: tuple[int, ...]
    max: tuple[int, ...]

    def __post_init__(self) -> None:
        if len(self.min) != len(self.max):
            raise ValueError(f"rank mismatch: {self.min} vs {self.max}")

    @staticmethod
    def make(min_: Sequence[int], max_: Sequence[int]) -> "Box":
        return Box(tuple(int(m) for m in min_), tuple(int(m) for m in max_))

    @staticmethod
    def full(shape: Sequence[int]) -> "Box":
        return Box((0,) * len(shape), tuple(int(s) for s in shape))

    @property
    def rank(self) -> int:
        return len(self.min)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(b - a for a, b in zip(self.min, self.max))

    def volume(self) -> int:
        v = 1
        for a, b in zip(self.min, self.max):
            if b <= a:
                return 0
            v *= b - a
        return v

    def empty(self) -> bool:
        return any(b <= a for a, b in zip(self.min, self.max))

    def contains(self, other: "Box") -> bool:
        if other.empty():
            return True
        return all(a <= oa and ob <= b for a, oa, ob, b in
                   zip(self.min, other.min, other.max, self.max))

    def contains_point(self, pt: Sequence[int]) -> bool:
        return all(a <= p < b for a, p, b in zip(self.min, pt, self.max))

    def intersect(self, other: "Box") -> "Box":
        lo = tuple(max(a, b) for a, b in zip(self.min, other.min))
        hi = tuple(min(a, b) for a, b in zip(self.max, other.max))
        hi = tuple(max(l, h) for l, h in zip(lo, hi))  # clamp to empty
        return Box(lo, hi)

    def overlaps(self, other: "Box") -> bool:
        return not self.intersect(other).empty()

    def union_bbox(self, other: "Box") -> "Box":
        if self.empty():
            return other
        if other.empty():
            return self
        return Box(tuple(min(a, b) for a, b in zip(self.min, other.min)),
                   tuple(max(a, b) for a, b in zip(self.max, other.max)))

    def translate(self, offset: Sequence[int]) -> "Box":
        return Box(tuple(a + o for a, o in zip(self.min, offset)),
                   tuple(b + o for b, o in zip(self.max, offset)))

    def clamp(self, bounds: "Box") -> "Box":
        return self.intersect(bounds)

    def difference(self, other: "Box") -> list["Box"]:
        """``self \\ other`` as a list of disjoint boxes (axis-sweep split)."""
        inter = self.intersect(other)
        if inter.empty():
            return [] if self.empty() else [self]
        if inter == self:
            return []
        out: list[Box] = []
        cur = self
        for d in range(self.rank):
            # slab below the intersection along dim d
            if cur.min[d] < inter.min[d]:
                lo, hi = list(cur.min), list(cur.max)
                hi[d] = inter.min[d]
                out.append(Box(tuple(lo), tuple(hi)))
            # slab above
            if inter.max[d] < cur.max[d]:
                lo, hi = list(cur.min), list(cur.max)
                lo[d] = inter.max[d]
                out.append(Box(tuple(lo), tuple(hi)))
            # narrow current to the intersection along dim d and continue
            lo, hi = list(cur.min), list(cur.max)
            lo[d], hi[d] = inter.min[d], inter.max[d]
            cur = Box(tuple(lo), tuple(hi))
        return [b for b in out if not b.empty()]

    def __str__(self) -> str:  # compact debug form: [0,4)x[2,8)
        return "x".join(f"[{a},{b})" for a, b in zip(self.min, self.max))


def _merge_adjacent(boxes: list[Box]) -> list[Box]:
    """Greedily merge boxes that differ in exactly one dimension and touch."""
    boxes = [b for b in boxes if not b.empty()]
    changed = True
    while changed:
        changed = False
        out: list[Box] = []
        used = [False] * len(boxes)
        for i, a in enumerate(boxes):
            if used[i]:
                continue
            acc = a
            for j in range(i + 1, len(boxes)):
                if used[j]:
                    continue
                b = boxes[j]
                m = _try_merge(acc, b)
                if m is not None:
                    acc = m
                    used[j] = True
                    changed = True
            out.append(acc)
        boxes = out
    return boxes


def _try_merge(a: Box, b: Box) -> Box | None:
    """Merge two boxes into one iff their union is exactly a box."""
    diff_dim = -1
    for d in range(a.rank):
        if a.min[d] == b.min[d] and a.max[d] == b.max[d]:
            continue
        if diff_dim >= 0:
            return None
        diff_dim = d
    if diff_dim < 0:
        return a  # identical
    d = diff_dim
    if a.max[d] == b.min[d]:
        return Box(a.min, tuple(list(a.max[:d]) + [b.max[d]] + list(a.max[d + 1:])))
    if b.max[d] == a.min[d]:
        return Box(tuple(list(a.min[:d]) + [b.min[d]] + list(a.min[d + 1:])), a.max)
    return None


class Region:
    """A finite union of pairwise-disjoint boxes. Immutable."""

    __slots__ = ("boxes", "_hash")

    def __init__(self, boxes: Iterable[Box] = ()):  # normalizes to disjoint
        disjoint: list[Box] = []
        for b in boxes:
            if b.empty():
                continue
            pending = [b]
            for existing in disjoint:
                nxt: list[Box] = []
                for p in pending:
                    nxt.extend(p.difference(existing))
                pending = nxt
                if not pending:
                    break
            disjoint.extend(pending)
        self.boxes: tuple[Box, ...] = tuple(_merge_adjacent(disjoint))
        self._hash: int | None = None

    # -- constructors -----------------------------------------------------
    @staticmethod
    def from_box(b: Box) -> "Region":
        return Region([b])

    @staticmethod
    def empty() -> "Region":
        return Region()

    # -- predicates --------------------------------------------------------
    def is_empty(self) -> bool:
        return not self.boxes

    def volume(self) -> int:
        return sum(b.volume() for b in self.boxes)

    @property
    def rank(self) -> int:
        return self.boxes[0].rank if self.boxes else 0

    def bounding_box(self) -> Box:
        if not self.boxes:
            raise ValueError("empty region has no bounding box")
        bb = self.boxes[0]
        for b in self.boxes[1:]:
            bb = bb.union_bbox(b)
        return bb

    def contains(self, other: "Region") -> bool:
        return other.difference(self).is_empty()

    def contains_box(self, b: Box) -> bool:
        return Region([b]).difference(self).is_empty()

    def overlaps(self, other: "Region") -> bool:
        return not self.intersect(other).is_empty()

    # -- algebra -----------------------------------------------------------
    def union(self, other: "Region") -> "Region":
        if self.is_empty():
            return other
        if other.is_empty():
            return self
        return Region(itertools.chain(self.boxes, other.boxes))

    def intersect(self, other: "Region") -> "Region":
        out = []
        for a in self.boxes:
            for b in other.boxes:
                i = a.intersect(b)
                if not i.empty():
                    out.append(i)
        return Region(out)

    def intersect_box(self, box: Box) -> "Region":
        return Region(a.intersect(box) for a in self.boxes)

    def difference(self, other: "Region") -> "Region":
        cur = list(self.boxes)
        for b in other.boxes:
            nxt: list[Box] = []
            for a in cur:
                nxt.extend(a.difference(b))
            cur = nxt
            if not cur:
                break
        return Region(cur)

    # -- dunder ------------------------------------------------------------
    def __iter__(self) -> Iterator[Box]:
        return iter(self.boxes)

    def __len__(self) -> int:
        return len(self.boxes)

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, Region):
            return NotImplemented
        return (self.difference(other).is_empty()
                and other.difference(self).is_empty())

    def __hash__(self) -> int:
        # canonical: hash of sorted box volume/bbox signature (cheap, collision-ok)
        if self._hash is None:
            self._hash = hash((self.volume(),
                               tuple(sorted((b.min, b.max) for b in self.boxes))))
        return self._hash

    def __str__(self) -> str:
        return "{" + ", ".join(str(b) for b in self.boxes) + "}"

    __repr__ = __str__


class RegionMap:
    """Maps every point of a bounded index space to a value.

    Implemented as a list of ``(Region, value)`` entries with disjoint
    regions.  ``update(region, value)`` overwrites previous values in that
    region — exactly the structure Celerity uses to track last writers,
    up-to-date memories, etc.
    """

    __slots__ = ("bounds", "entries", "default")

    def __init__(self, bounds: Box, default=None):
        self.bounds = bounds
        self.default = default
        self.entries: list[tuple[Region, object]] = []
        if default is not None:
            self.entries.append((Region.from_box(bounds), default))

    def update(self, region: Region, value) -> None:
        region = region.intersect_box(self.bounds)
        if region.is_empty():
            return
        new_entries: list[tuple[Region, object]] = []
        for r, v in self.entries:
            rem = r.difference(region)
            if not rem.is_empty():
                new_entries.append((rem, v))
        new_entries.append((region, value))
        self.entries = new_entries

    def query(self, region: Region) -> list[tuple[Region, object]]:
        """All (subregion, value) pairs intersecting ``region``."""
        out = []
        for r, v in self.entries:
            i = r.intersect(region)
            if not i.is_empty():
                out.append((i, v))
        return out

    def covered(self) -> Region:
        out = Region.empty()
        for r, _ in self.entries:
            out = out.union(r)
        return out

    def coalesce(self) -> None:
        """Merge entries that share the same value (bounds complexity)."""
        by_val: dict[int, tuple[object, Region]] = {}
        order: list[int] = []
        for r, v in self.entries:
            k = id(v) if not isinstance(v, (int, str, tuple, frozenset)) else hash((type(v).__name__, v))
            if k in by_val:
                by_val[k] = (v, by_val[k][1].union(r))
            else:
                by_val[k] = (v, r)
                order.append(k)
        self.entries = [(r, v) for k in order for v, r in [by_val[k]]]


def split_box(box: Box, num_chunks: int, dims: Sequence[int] = (0,),
              granularity: Sequence[int] | None = None) -> list[Box]:
    """Split ``box`` into at most ``num_chunks`` boxes along ``dims``.

    This is Celerity's static work-assignment split: chunks are as even as
    possible, aligned to ``granularity`` in each split dimension, and empty
    chunks are dropped (small index spaces yield fewer chunks than requested).
    Multi-dim splits factor ``num_chunks`` greedily over ``dims``.
    """
    if num_chunks <= 1 or box.empty():
        return [box] if not box.empty() else []
    if len(dims) == 1:
        d = dims[0]
        extent = box.max[d] - box.min[d]
        gran = (granularity[0] if granularity else 1) or 1
        units = (extent + gran - 1) // gran
        n = min(num_chunks, units)
        out = []
        base, rem = divmod(units, n)
        cursor = box.min[d]
        for i in range(n):
            take = (base + (1 if i < rem else 0)) * gran
            lo, hi = list(box.min), list(box.max)
            lo[d] = cursor
            hi[d] = min(cursor + take, box.max[d])
            cursor = hi[d]
            b = Box(tuple(lo), tuple(hi))
            if not b.empty():
                out.append(b)
        return out
    # 2-D split: factor num_chunks as close to square as possible
    d0, d1 = dims[0], dims[1]
    best = (num_chunks, 1)
    for f in range(1, int(num_chunks ** 0.5) + 1):
        if num_chunks % f == 0:
            best = (num_chunks // f, f)
    rows = split_box(box, best[0], (d0,), granularity)
    out = []
    for r in rows:
        out.extend(split_box(r, best[1], (d1,),
                             (granularity[1:] if granularity and len(granularity) > 1 else None)))
    return out
