"""Box/region algebra for buffer subrange tracking.

Celerity tracks dataflow at the granularity of individual buffer elements by
operating on *regions*: finite unions of pairwise-disjoint, half-open,
axis-aligned N-dimensional boxes.  Every layer of the scheduler (task graph,
command graph, instruction graph) is built on this algebra, so it must be
exact — the hypothesis test-suite checks it against a brute-force bitmap
oracle.

Boxes are represented as ``(min, max)`` tuples of per-dimension integers with
half-open semantics ``min <= i < max``.  Empty boxes are normalized away.

Performance notes (see DESIGN.md "Performance notes"):

* Regions produced by the algebra itself (``intersect``, ``difference``,
  ``union``, ``intersect_box``) are disjoint *by construction*, so internal
  call sites build results through the trusted :meth:`Region.from_disjoint`
  constructor and never pay the quadratic renormalization of the public
  ``Region(boxes)`` constructor.
* All pairwise loops are prefiltered by cached bounding boxes; the all-pairs
  work only happens for boxes whose bounding boxes actually overlap.
* Box-merging uses a sort-and-sweep (group by the N-1 invariant coordinates,
  merge touching intervals along the remaining axis), replacing the previous
  greedy O(n^3) loop.
"""

from __future__ import annotations

from typing import Iterable, Iterator, Sequence


class Box:
    """A half-open axis-aligned box ``[min, max)`` in N dimensions.

    Immutable by convention (do not assign to ``min``/``max``): a plain
    slotted class instead of a frozen dataclass because Box construction is
    the single hottest operation of the whole scheduler.
    """

    __slots__ = ("min", "max")

    def __init__(self, min: tuple[int, ...], max: tuple[int, ...]):  # noqa: A002
        if len(min) != len(max):
            raise ValueError(f"rank mismatch: {min} vs {max}")
        self.min = min
        self.max = max

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, Box):
            return NotImplemented
        return self.min == other.min and self.max == other.max

    def __hash__(self) -> int:
        return hash((self.min, self.max))

    def __repr__(self) -> str:
        return f"Box(min={self.min}, max={self.max})"

    @staticmethod
    def make(min_: Sequence[int], max_: Sequence[int]) -> "Box":
        return Box(tuple(int(m) for m in min_), tuple(int(m) for m in max_))

    @staticmethod
    def full(shape: Sequence[int]) -> "Box":
        return Box((0,) * len(shape), tuple(int(s) for s in shape))

    @property
    def rank(self) -> int:
        return len(self.min)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(b - a for a, b in zip(self.min, self.max))

    def volume(self) -> int:
        v = 1
        for a, b in zip(self.min, self.max):
            if b <= a:
                return 0
            v *= b - a
        return v

    def empty(self) -> bool:
        for a, b in zip(self.min, self.max):
            if b <= a:
                return True
        return False

    def contains(self, other: "Box") -> bool:
        if other.empty():
            return True
        return all(a <= oa and ob <= b for a, oa, ob, b in
                   zip(self.min, other.min, other.max, self.max))

    def contains_point(self, pt: Sequence[int]) -> bool:
        return all(a <= p < b for a, p, b in zip(self.min, pt, self.max))

    def intersect(self, other: "Box") -> "Box":
        lo = tuple(map(max, self.min, other.min))
        hi = tuple(map(max, lo, map(min, self.max, other.max)))  # clamp empty
        return Box(lo, hi)

    def overlaps(self, other: "Box") -> bool:
        return _boxes_overlap(self, other)

    def union_bbox(self, other: "Box") -> "Box":
        if self.empty():
            return other
        if other.empty():
            return self
        return Box(tuple(min(a, b) for a, b in zip(self.min, other.min)),
                   tuple(max(a, b) for a, b in zip(self.max, other.max)))

    def translate(self, offset: Sequence[int]) -> "Box":
        return Box(tuple(a + o for a, o in zip(self.min, offset)),
                   tuple(b + o for b, o in zip(self.max, offset)))

    def clamp(self, bounds: "Box") -> "Box":
        return self.intersect(bounds)

    def difference(self, other: "Box") -> list["Box"]:
        """``self \\ other`` as a list of disjoint boxes (axis-sweep split)."""
        inter = self.intersect(other)
        if inter.empty():
            return [] if self.empty() else [self]
        if inter == self:
            return []
        out: list[Box] = []
        cur = self
        for d in range(self.rank):
            # slab below the intersection along dim d
            if cur.min[d] < inter.min[d]:
                lo, hi = list(cur.min), list(cur.max)
                hi[d] = inter.min[d]
                out.append(Box(tuple(lo), tuple(hi)))
            # slab above
            if inter.max[d] < cur.max[d]:
                lo, hi = list(cur.min), list(cur.max)
                lo[d] = inter.max[d]
                out.append(Box(tuple(lo), tuple(hi)))
            # narrow current to the intersection along dim d and continue
            lo, hi = list(cur.min), list(cur.max)
            lo[d], hi[d] = inter.min[d], inter.max[d]
            cur = Box(tuple(lo), tuple(hi))
        return [b for b in out if not b.empty()]

    def __str__(self) -> str:  # compact debug form: [0,4)x[2,8)
        return "x".join(f"[{a},{b})" for a, b in zip(self.min, self.max))


def _boxes_overlap(a: Box, b: Box) -> bool:
    """Open-interval overlap test — no Box construction on the hot path."""
    for a0, a1, b0, b1 in zip(a.min, a.max, b.min, b.max):
        if a0 >= b1 or b0 >= a1 or a0 >= a1 or b0 >= b1:
            return False
    return True


def _subtract_boxes(pending: list[Box], boxes: Iterable[Box]) -> list[Box]:
    """Subtract each of ``boxes`` from every box in ``pending``.

    Bbox-prefiltered: only overlapping pairs pay for ``Box.difference``.
    Returns the (possibly empty) disjoint remainder; early-outs when it
    empties.  Shared kernel of normalization, ``contains_box`` and ``union``.
    """
    for x in boxes:
        nxt: list[Box] = []
        for p in pending:
            if _boxes_overlap(p, x):
                nxt.extend(p.difference(x))
            else:
                nxt.append(p)
        pending = nxt
        if not pending:
            break
    return pending


def _merge_adjacent(boxes: list[Box]) -> list[Box]:
    """Merge mergeable boxes in a *pairwise-disjoint* list (sort-and-sweep).

    For each axis, boxes sharing the same extent in every other dimension are
    grouped and their intervals along that axis merged where they touch.
    Axes are swept repeatedly until a fixpoint, since a merge along one axis
    can enable a merge along another; each sweep is O(n log n).
    """
    boxes = [b for b in boxes if not b.empty()]
    if len(boxes) <= 1:
        return boxes
    rank = boxes[0].rank
    changed = True
    while changed:
        changed = False
        for d in range(rank):
            if len(boxes) <= 1:
                break
            groups: dict[tuple, list[Box]] = {}
            for b in boxes:
                key = b.min[:d] + b.min[d + 1:] + b.max[:d] + b.max[d + 1:]
                groups.setdefault(key, []).append(b)
            out: list[Box] = []
            for bs in groups.values():
                if len(bs) == 1:
                    out.append(bs[0])
                    continue
                bs.sort(key=lambda x: x.min[d])
                cur = bs[0]
                for b in bs[1:]:
                    if b.min[d] == cur.max[d]:    # touching: merge intervals
                        cur = Box(cur.min, cur.max[:d] + (b.max[d],)
                                  + cur.max[d + 1:])
                        changed = True
                    else:
                        out.append(cur)
                        cur = b
                out.append(cur)
            boxes = out
    return boxes


class Region:
    """A finite union of pairwise-disjoint boxes. Immutable."""

    __slots__ = ("boxes", "_hash", "_bbox")

    def __init__(self, boxes: Iterable[Box] = ()):  # normalizes to disjoint
        disjoint: list[Box] = []
        for b in boxes:
            if not b.empty():
                disjoint.extend(_subtract_boxes([b], disjoint))
        self.boxes: tuple[Box, ...] = tuple(_merge_adjacent(disjoint))
        self._hash: int | None = None
        self._bbox: Box | None = None

    # -- constructors -----------------------------------------------------
    @classmethod
    def from_disjoint(cls, boxes: Iterable[Box]) -> "Region":
        """Trusted constructor: the caller guarantees ``boxes`` are already
        pairwise disjoint and non-empty; normalization is skipped entirely.

        Every internal algebra result (intersection of disjoint regions,
        difference remainders, ...) is disjoint by construction, which is
        what keeps renormalization off the scheduling fast path.
        """
        r = object.__new__(cls)
        r.boxes = tuple(boxes)
        r._hash = None
        r._bbox = None
        return r

    @staticmethod
    def from_box(b: Box) -> "Region":
        if b.empty():
            return _EMPTY
        return Region.from_disjoint((b,))

    @staticmethod
    def empty() -> "Region":
        return _EMPTY

    # -- predicates --------------------------------------------------------
    def is_empty(self) -> bool:
        return not self.boxes

    def volume(self) -> int:
        return sum(b.volume() for b in self.boxes)

    @property
    def rank(self) -> int:
        return self.boxes[0].rank if self.boxes else 0

    def bounding_box(self) -> Box:
        bb = self._bbox
        if bb is None:
            bs = self.boxes
            if not bs:
                raise ValueError("empty region has no bounding box")
            if len(bs) == 1:                   # single-box regions dominate
                bb = bs[0]
            else:
                lo, hi = bs[0].min, bs[0].max
                for b in bs[1:]:
                    lo = tuple(map(min, lo, b.min))
                    hi = tuple(map(max, hi, b.max))
                bb = Box(lo, hi)
            self._bbox = bb
        return bb

    def contains(self, other: "Region") -> bool:
        if not other.boxes:
            return True
        if not self.boxes:
            return False
        if not self.bounding_box().contains(other.bounding_box()):
            return False
        return all(self.contains_box(b) for b in other.boxes)

    def contains_box(self, b: Box) -> bool:
        if b.empty():
            return True
        if not self.boxes:
            return False
        for x in self.boxes:                       # single-box fast path
            if x.contains(b):
                return True
        if not self.bounding_box().contains(b):
            return False
        return not _subtract_boxes([b], self.boxes)

    def overlaps(self, other: "Region") -> bool:
        if not self.boxes or not other.boxes:
            return False
        if not _boxes_overlap(self.bounding_box(), other.bounding_box()):
            return False
        obb = other.bounding_box()
        for a in self.boxes:
            if not _boxes_overlap(a, obb):
                continue
            for b in other.boxes:
                if _boxes_overlap(a, b):
                    return True
        return False

    # -- algebra -----------------------------------------------------------
    def union(self, other: "Region") -> "Region":
        if not self.boxes:
            return other
        if not other.boxes:
            return self
        sbb = self.bounding_box()
        if not _boxes_overlap(sbb, other.bounding_box()):
            # disjoint bounding boxes: concatenation is already disjoint
            # (boxes may still be adjacent, so merge for compactness)
            return Region.from_disjoint(
                _merge_adjacent(list(self.boxes + other.boxes)))
        out = list(self.boxes)
        for b in other.boxes:
            if _boxes_overlap(b, sbb):
                out.extend(_subtract_boxes([b], self.boxes))
            else:
                out.append(b)
        return Region.from_disjoint(_merge_adjacent(out))

    def intersect(self, other: "Region") -> "Region":
        if not self.boxes or not other.boxes:
            return _EMPTY
        if len(self.boxes) == 1 and len(other.boxes) == 1:
            i = self.boxes[0].intersect(other.boxes[0])
            return Region.from_disjoint((i,)) if not i.empty() else _EMPTY
        obb = other.bounding_box()
        if not _boxes_overlap(self.bounding_box(), obb):
            return _EMPTY
        # intersections of two disjoint families are pairwise disjoint
        out: list[Box] = []
        for a in self.boxes:
            if not _boxes_overlap(a, obb):
                continue
            for b in other.boxes:
                if _boxes_overlap(a, b):
                    out.append(a.intersect(b))
        if not out:
            return _EMPTY
        if len(out) > 1:
            out = _merge_adjacent(out)
        return Region.from_disjoint(out)

    def intersect_box(self, box: Box) -> "Region":
        if not self.boxes or box.empty():
            return _EMPTY
        if len(self.boxes) == 1:
            i = self.boxes[0].intersect(box)
            return Region.from_disjoint((i,)) if not i.empty() else _EMPTY
        if not _boxes_overlap(self.bounding_box(), box):
            return _EMPTY
        out = [a.intersect(box) for a in self.boxes if _boxes_overlap(a, box)]
        if not out:
            return _EMPTY
        if len(out) > 1:
            out = _merge_adjacent(out)
        return Region.from_disjoint(out)

    def difference(self, other: "Region") -> "Region":
        if not self.boxes:
            return _EMPTY
        if not other.boxes:
            return self
        sbb = self.bounding_box()
        if not _boxes_overlap(sbb, other.bounding_box()):
            return self
        cur = list(self.boxes)
        changed = False
        for b in other.boxes:
            if not cur:
                break
            if not _boxes_overlap(sbb, b):
                continue
            nxt: list[Box] = []
            for a in cur:
                if _boxes_overlap(a, b):
                    nxt.extend(a.difference(b))
                    changed = True
                else:
                    nxt.append(a)
            cur = nxt
        if not changed:
            return self
        if not cur:
            return _EMPTY
        return Region.from_disjoint(_merge_adjacent(cur))

    # -- dunder ------------------------------------------------------------
    def __iter__(self) -> Iterator[Box]:
        return iter(self.boxes)

    def __len__(self) -> int:
        return len(self.boxes)

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, Region):
            return NotImplemented
        if self.boxes == other.boxes:
            return True
        if not self.boxes or not other.boxes:
            return False                        # exactly one side is empty
        if self.volume() != other.volume():
            return False
        if self.bounding_box() != other.bounding_box():
            return False
        # equal finite volumes: self ⊆ other already implies equality
        return self.difference(other).is_empty()

    def __hash__(self) -> int:
        # canonical for set-equal regions: any normalization of the same
        # point set shares volume and bounding box (collisions are fine)
        if self._hash is None:
            if not self.boxes:
                self._hash = hash(())
            else:
                bb = self.bounding_box()
                self._hash = hash((self.volume(), bb.min, bb.max))
        return self._hash

    def __str__(self) -> str:
        return "{" + ", ".join(str(b) for b in self.boxes) + "}"

    __repr__ = __str__


_EMPTY = Region.from_disjoint(())


class RegionMap:
    """Maps every point of a bounded index space to a value.

    Implemented as a list of ``(Region, value)`` entries with disjoint
    regions, kept sorted by bounding-box minimum with a parallel bounding-box
    index so ``query``/``update`` touch only candidate entries.
    ``update(region, value)`` overwrites previous values in that region —
    exactly the structure Celerity uses to track last writers, up-to-date
    memories, etc.
    """

    __slots__ = ("bounds", "entries", "default", "_bbs")

    def __init__(self, bounds: Box, default=None):
        self.bounds = bounds
        self.default = default
        self.entries: list[tuple[Region, object]] = []
        self._bbs: list[Box] = []
        if default is not None:
            self.entries.append((Region.from_box(bounds), default))
            self._bbs.append(bounds)

    def _set_entries(self, pairs: list[tuple[Region, object]]) -> None:
        pairs.sort(key=lambda rv: rv[0].bounding_box().min)
        self.entries = pairs
        self._bbs = [r.bounding_box() for r, _ in pairs]

    def update(self, region: Region, value) -> None:
        region = region.intersect_box(self.bounds)
        if region.is_empty():
            return
        qbb = region.bounding_box()
        new_entries: list[tuple[Region, object]] = []
        for (r, v), bb in zip(self.entries, self._bbs):
            if not _boxes_overlap(bb, qbb):
                new_entries.append((r, v))
                continue
            rem = r.difference(region)
            if not rem.is_empty():
                new_entries.append((rem, v))
        new_entries.append((region, value))
        self._set_entries(new_entries)

    def query(self, region: Region) -> list[tuple[Region, object]]:
        """All (subregion, value) pairs intersecting ``region``."""
        if region.is_empty() or not self.entries:
            return []
        qbb = region.bounding_box()
        q0max = qbb.max[0]
        out = []
        for (r, v), bb in zip(self.entries, self._bbs):
            if bb.min[0] >= q0max:
                break          # entries sorted by bbox min: no more overlaps
            if not _boxes_overlap(bb, qbb):
                continue
            i = r.intersect(region)
            if not i.is_empty():
                out.append((i, v))
        return out

    def covered(self) -> Region:
        boxes = [b for r, _ in self.entries for b in r.boxes]
        if not boxes:
            return _EMPTY
        return Region.from_disjoint(_merge_adjacent(boxes))

    def coalesce(self) -> None:
        """Merge entries that share the same value (bounds complexity)."""
        by_val: dict[int, tuple[object, list[Box]]] = {}
        order: list[int] = []
        for r, v in self.entries:
            k = (id(v) if not isinstance(v, (int, str, tuple, frozenset))
                 else hash((type(v).__name__, v)))
            if k in by_val:
                by_val[k][1].extend(r.boxes)
            else:
                by_val[k] = (v, list(r.boxes))
                order.append(k)
        self._set_entries(
            [(Region.from_disjoint(_merge_adjacent(boxes)), v)
             for k in order for v, boxes in [by_val[k]]])

    def __len__(self) -> int:
        return len(self.entries)


def split_box(box: Box, num_chunks: int, dims: Sequence[int] = (0,),
              granularity: Sequence[int] | None = None) -> list[Box]:
    """Split ``box`` into at most ``num_chunks`` boxes along ``dims``.

    This is Celerity's static work-assignment split: chunks are as even as
    possible, aligned to ``granularity`` in each split dimension, and empty
    chunks are dropped (small index spaces yield fewer chunks than requested).
    Multi-dim splits factor ``num_chunks`` greedily over ``dims``.
    """
    if num_chunks <= 1 or box.empty():
        return [box] if not box.empty() else []
    if len(dims) == 1:
        d = dims[0]
        extent = box.max[d] - box.min[d]
        gran = (granularity[0] if granularity else 1) or 1
        units = (extent + gran - 1) // gran
        n = min(num_chunks, units)
        out = []
        base, rem = divmod(units, n)
        cursor = box.min[d]
        for i in range(n):
            take = (base + (1 if i < rem else 0)) * gran
            lo, hi = list(box.min), list(box.max)
            lo[d] = cursor
            hi[d] = min(cursor + take, box.max[d])
            cursor = hi[d]
            b = Box(tuple(lo), tuple(hi))
            if not b.empty():
                out.append(b)
        return out
    # 2-D split: factor num_chunks as close to square as possible
    d0, d1 = dims[0], dims[1]
    best = (num_chunks, 1)
    for f in range(1, int(num_chunks ** 0.5) + 1):
        if num_chunks % f == 0:
            best = (num_chunks // f, f)
    rows = split_box(box, best[0], (d0,), granularity)
    out = []
    for r in rows:
        out.extend(split_box(r, best[1], (d1,),
                             (granularity[1:] if granularity and len(granularity) > 1 else None)))
    return out
