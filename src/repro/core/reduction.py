"""Reduction operators and reproducible accumulators (paper §2.2/§4).

Celerity treats reductions as first-class graph nodes: a kernel binds a
*reduction output* next to its accessors, every device produces a partial
value, node-local partials are combined, exchanged between all ranks and
folded into the final replicated buffer value.  This module defines the
*value semantics* of that pipeline; the graph layers (task graph, command
graph, instruction graph) and the executor wire it through the runtime.

Determinism contract
--------------------

The command graph is replicated-deterministic, so all ranks must compute a
**bitwise identical** reduction result — and our acceptance tests further
require the result to be *partition independent*: the same bits on 1, 2 and
4 simulated nodes.  Floating-point addition is not associative, so folding
per-chunk float partials can never satisfy that.  Instead:

* ``sum`` over float buffers uses an **exact fixed-point superaccumulator**
  (the ReproBLAS idea, radically simplified for arbitrary-precision Python
  integers): every finite float64 is an integer multiple of 2^-1074, so each
  contribution is scaled to an exact integer and partials are exact integer
  sums.  Integer addition is associative and commutative, and the single
  final rounding (via ``Fraction``) is correctly rounded — the result equals
  ``math.fsum`` of all contributions in any partition and any combine order.
* ``max``/``min`` are associative, commutative and exact on floats already;
  partials are plain element-wise folds.
* ``prod`` and custom callables fold partials in canonical node order —
  deterministic and replicated-identical, but (like any real MPI allreduce
  of floats) not partition independent; see DESIGN.md §7.

Accumulator state is an ndarray of the reduction-buffer shape: dtype
``object`` holding Python ints for the exact-sum path, the buffer dtype
otherwise.  On a real MPI wire the integer limbs would be serialized like
ReproBLAS bins; the in-process mailbox ships the object array directly.

Transport note (DESIGN.md §9): with the collective layer enabled the node
partials travel as packed fragments of a dissemination allgather (fused
across adjacent reductions) instead of N*(N-1) point-to-point sends.
Integer addition stays associative/commutative, so the exchange topology
— p2p, collective, fused or not — never changes a single bit of the
result.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from fractions import Fraction
from typing import Callable, Optional, Union

import numpy as np

# every finite double is n * 2^-_SCALE_BITS for an integer n
_SCALE_BITS = 1074


def _float_to_fixed(v: float) -> int:
    """Exact integer n with ``v == n * 2**-1074`` (finite doubles only)."""
    v = float(v)
    if not math.isfinite(v):
        raise ValueError(f"non-finite contribution {v!r} in exact-sum reduction")
    m, e = math.frexp(v)
    n = int(m * (1 << 53))           # exact: m has <= 53 significant bits
    s = e - 53 + _SCALE_BITS
    return n << s if s >= 0 else n >> (-s)   # negative shifts are exact too


def _fixed_to_float(n: int) -> float:
    """Correctly-rounded double for ``n * 2**-1074``."""
    if n == 0:
        return 0.0
    return float(Fraction(n, 1 << _SCALE_BITS))


def _float_fixed_parts(values: np.ndarray):
    """Vectorized decomposition of finite float64s on the 2^-1074 grid.

    Returns ``(sign, a, s)`` int64 arrays with ``v == sign * a * 2**(s-1074)``
    exactly, ``a < 2**53`` and ``s >= 0``: ``frexp`` yields ``v = m * 2**e``
    with ``m`` holding <= 53 significant bits, so ``a = |m| * 2**53`` is an
    exact int64 and ``s = e - 53 + 1074``.  Subnormals produce ``s < 0``
    with enough trailing zero bits in ``a`` for an exact right shift.
    """
    values = np.ascontiguousarray(values, dtype=np.float64)
    if not np.isfinite(values).all():
        bad = values[~np.isfinite(values)].ravel()[0]
        raise ValueError(f"non-finite contribution {bad!r} in exact-sum reduction")
    m, e = np.frexp(values)
    n = (m * float(1 << 53)).astype(np.int64)        # exact: integer-valued
    sign = np.sign(n)
    a = np.abs(n)
    s = e.astype(np.int64) - 53 + _SCALE_BITS
    neg = s < 0
    if neg.any():
        a = np.where(neg, a >> np.where(neg, -s, 0), a)
        s = np.where(neg, 0, s)
    return sign, a, s


def _exact_scale(values: np.ndarray) -> np.ndarray:
    """Element-wise exact fixed-point lift into object dtype.

    Integer inputs lift as ``int(v) << 1074`` (exact for any int64, unlike
    a cast through float64 which silently rounds above 2^53); floats use
    the vectorized frexp decomposition with one big-int shift per element.
    Both land on the same 2^-1074 fixed-point grid, so partials mix freely.
    """
    values = np.asarray(values)
    flat = values.ravel()
    out = np.empty(flat.shape, dtype=object)
    if np.issubdtype(values.dtype, np.integer):
        for i, v in enumerate(flat):
            out[i] = int(v) << _SCALE_BITS
    else:
        sign, a, s = _float_fixed_parts(flat)
        for i in range(flat.size):
            out[i] = int(sign[i]) * (int(a[i]) << int(s[i]))
    return out.reshape(values.shape)


# two-level binned accumulator (ReproBLAS-style): level 1 sums signed 32-bit
# limbs of each contribution into int64 bins (pure numpy, no Python ints on
# the per-element path); level 2 folds the bins into one arbitrary-precision
# integer per output element with a single carry pass.  2098 significant bits
# (s <= 2045, 53-bit mantissa) span ceil(2098/32) = 66 limbs; +2 slack.
_NBINS = 68
# each limb contribution is < 2^32, so int64 bins absorb 2^31 additions
# before overflow could occur — chunk longer inputs
_BIN_CHUNK = 1 << 30


def _exact_scale_sum(values: np.ndarray) -> np.ndarray:
    """Exact fixed-point sum over the leading axis, fully vectorized.

    ``values`` has shape ``(n_items, *out_shape)``; the result is an object
    ndarray of Python ints with shape ``out_shape``, bitwise identical to
    ``_exact_scale(values).sum(axis=0)`` (both are exact integer sums on the
    same grid — the fast path changes the work, not the value).
    """
    values = np.asarray(values, dtype=np.float64)
    out_shape = values.shape[1:]
    size = int(np.prod(out_shape, dtype=np.int64)) if out_shape else 1
    flat = values.reshape(values.shape[0], size)
    out = np.zeros(size, dtype=object)
    for lo in range(0, flat.shape[0], _BIN_CHUNK):
        chunk = flat[lo:lo + _BIN_CHUNK]
        # fresh bins per chunk: each row contributes at most one limb
        # (< 2^32) per bin, so 2^30 rows stay below the int64 overflow
        # threshold; the level-2 big-int fold below drains them
        bins = np.zeros((_NBINS, size), dtype=np.int64)
        pos = np.broadcast_to(np.arange(size, dtype=np.int64), chunk.shape)
        sign, a, s = _float_fixed_parts(chunk)
        q, r = s >> 5, s & 31
        # |a| << r spans up to 85 bits -> three 32-bit limbs, computed
        # without ever overflowing int64 (shift counts stay < 64)
        c0 = (a & ((np.int64(1) << (32 - r)) - 1)) << r
        c1 = (a >> (32 - r)) & np.int64(0xFFFFFFFF)
        c2 = (a >> 32) >> (32 - r)
        np.add.at(bins, (q, pos), sign * c0)
        np.add.at(bins, (q + 1, pos), sign * c1)
        np.add.at(bins, (q + 2, pos), sign * c2)
        for j in range(size):
            col = bins[:, j]
            total = 0
            for k in np.nonzero(col)[0]:
                total += int(col[k]) << (32 * int(k))
            out[j] += total
    return out.reshape(out_shape)


class ReductionOp:
    """Value semantics of one reduction operator.

    The accumulator array (``acc``) has the reduction-buffer shape.  All
    methods are pure element-wise transforms; ``combine`` must be
    deterministic when folded in canonical node order.
    """

    def __init__(self, name: str, *, exact_sum: bool,
                 fold: Optional[Callable] = None, identity=None,
                 order_free: bool = False):
        self.name = name
        self.exact_sum = exact_sum
        self._fold = fold                    # binary elementwise fold
        self._identity = identity
        # ``combine`` is associative, commutative AND exact: any combine
        # tree yields bitwise identical results.  True for the exact-sum
        # superaccumulator (integer addition) and max/min (elementwise
        # selection); False for float prod and custom callables, whose
        # results depend on the canonical fold order.  Gates the
        # reduce-scatter allreduce (DESIGN.md §9), whose recursive-halving
        # fold tree is not the canonical node order.
        self.combine_order_free = exact_sum or order_free

    # -- accumulator lifecycle -------------------------------------------
    def acc_dtype(self, buf_dtype: np.dtype) -> np.dtype:
        return np.dtype(object) if self.exact_sum else np.dtype(buf_dtype)

    def identity_acc(self, shape: tuple[int, ...], buf_dtype: np.dtype) -> np.ndarray:
        if self.exact_sum:
            acc = np.empty(shape, dtype=object)
            acc[...] = 0
            return acc
        acc = np.empty(shape, dtype=buf_dtype)
        acc[...] = self.identity_value(buf_dtype)
        return acc

    def identity_value(self, buf_dtype: np.dtype):
        if self._identity is not None:
            return self._identity
        if self.exact_sum:
            return 0
        if self.name in ("max", "min"):
            # dtype-aware default: +/-inf only exists for floats
            if np.issubdtype(buf_dtype, np.integer):
                info = np.iinfo(buf_dtype)
                return info.min if self.name == "max" else info.max
            return -np.inf if self.name == "max" else np.inf
        if self.name == "prod":
            return buf_dtype.type(1)
        raise ValueError(f"reduction op '{self.name}' needs an explicit identity")

    # -- the three pipeline steps ----------------------------------------
    @staticmethod
    def _stack(acc: np.ndarray, values: np.ndarray) -> np.ndarray:
        """Normalize ``values`` to shape ``(n_items,) + acc.shape``."""
        if acc.size == 1:
            return values.reshape((-1,) + acc.shape)
        if values.shape == acc.shape:
            return values[None]
        if values.ndim == acc.ndim + 1 and values.shape[1:] == acc.shape:
            return values
        raise ValueError(f"contribution shape {values.shape} does not match "
                         f"reduction shape {acc.shape}")

    def contribute(self, acc: np.ndarray, values: np.ndarray) -> None:
        """Fold ``values`` (leading axis = per-item contributions) into acc."""
        values = self._stack(acc, np.asarray(values))
        if not values.size:
            return
        if self.exact_sum:
            if (np.issubdtype(values.dtype, np.integer)
                    or values.dtype == np.dtype(object)):
                acc += _exact_scale(values).sum(axis=0)
            else:
                # vectorized two-level binned accumulation; bitwise
                # identical to the elementwise lift (both exact)
                acc += _exact_scale_sum(values)
        elif isinstance(self._fold, np.ufunc):
            acc[...] = self._fold(
                acc, self._fold.reduce(values.astype(acc.dtype, copy=False),
                                       axis=0))
        else:
            folder = np.frompyfunc(self._fold, 2, 1)
            folded = folder.reduce(values.astype(acc.dtype, copy=False), axis=0)
            acc[...] = self._fold(acc, folded.astype(acc.dtype, copy=False))

    def combine(self, acc: np.ndarray, other: np.ndarray) -> np.ndarray:
        """Merge two accumulators (exact for sum/max/min)."""
        if self.exact_sum:
            return acc + other
        return self._fold(acc, other)

    def lift(self, values: np.ndarray, buf_dtype: np.dtype) -> np.ndarray:
        """Lift plain buffer values into accumulator space
        (``include_current_value`` support)."""
        if self.exact_sum:
            return _exact_scale(values)
        return np.asarray(values, dtype=buf_dtype)

    def finalize(self, acc: np.ndarray, buf_dtype: np.dtype) -> np.ndarray:
        """Round the accumulator back to buffer dtype (single rounding)."""
        if self.exact_sum:
            flat_in = acc.ravel()
            if np.issubdtype(buf_dtype, np.integer):
                # exact: integer-lifted sums are multiples of 2^1074
                out = np.empty(acc.shape, dtype=buf_dtype)
                flat_out = out.ravel()
                for i in range(flat_in.size):
                    flat_out[i] = int(Fraction(flat_in[i], 1 << _SCALE_BITS))
                return out
            out = np.empty(acc.shape, dtype=np.float64)
            flat_out = out.ravel()
            for i in range(flat_in.size):
                flat_out[i] = _fixed_to_float(flat_in[i])
            return out.astype(buf_dtype, copy=False)
        return np.asarray(acc, dtype=buf_dtype)


def _make_op(op: Union[str, Callable], identity) -> ReductionOp:
    if callable(op):
        if identity is None:
            raise ValueError("custom reduction callables require an identity")
        return ReductionOp(getattr(op, "__name__", "custom"), exact_sum=False,
                           fold=op, identity=identity)
    if op == "sum":
        return ReductionOp("sum", exact_sum=True)
    if op == "max":
        return ReductionOp("max", exact_sum=False, fold=np.maximum,
                           identity=identity, order_free=True)
    if op == "min":
        return ReductionOp("min", exact_sum=False, fold=np.minimum,
                           identity=identity, order_free=True)
    if op == "prod":
        return ReductionOp("prod", exact_sum=False, fold=np.multiply,
                           identity=identity)
    raise ValueError(f"unknown reduction op {op!r}")


@dataclass(frozen=True)
class Reduction:
    """User-facing reduction descriptor — bound by kernels like an accessor.

    The kernel receives a :class:`~repro.core.executor.ReductionView` in
    binding order (after plain accessor views) and calls
    ``view.contribute(values)`` with per-item contributions; the runtime
    owns the partial/exchange/combine pipeline.  ``include_current_value``
    folds the buffer's pre-reduction contents into the result exactly once.
    """

    buffer: object                   # VirtualBuffer (untyped: avoid cycle)
    op: ReductionOp
    include_current_value: bool = False

    def __repr__(self) -> str:
        return (f"Reduction({self.buffer.name}, {self.op.name}"
                f"{', +current' if self.include_current_value else ''})")


def reduction(buffer, op: Union[str, Callable] = "sum", identity=None, *,
              include_current_value: bool = False) -> Reduction:
    """Create a reduction descriptor: ``reduction(E, 'sum')``.

    ``op`` is ``'sum' | 'max' | 'min' | 'prod'`` or a binary element-wise
    callable (requires ``identity``).  ``'sum'`` over float buffers is
    *reproducible*: bitwise identical on any node/device partition.
    """
    return Reduction(buffer=buffer, op=_make_op(op, identity),
                     include_current_value=include_current_value)
