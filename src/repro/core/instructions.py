"""Instruction vocabulary shared by the IDAG generator and the memory layer.

The instruction types and the :class:`Instruction` node itself live in their
own module so that :mod:`repro.core.memory` (allocation lifecycle, spilling)
and :mod:`repro.core.instruction_graph` (command lowering) can both emit
instructions without a circular import.  ``instruction_graph`` re-exports
everything here, so external users keep importing from there.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

from .allocation import Allocation
from .buffer import Accessor
from .reduction import Reduction
from .region import Box, Region
from .task_graph import DepKind


class InstructionType(enum.Enum):
    ALLOC = "alloc"
    COPY = "copy"
    FREE = "free"
    # budget-pressure data movement (memory.py): a SPILL copies the only
    # coherent replica of a region out of a budgeted memory before its
    # allocation is evicted; a RELOAD is the lazy copy back on next access.
    # Both execute exactly like COPY — the distinct types exist for
    # dependency auditing, tracing and overhead accounting.
    SPILL = "spill"
    RELOAD = "reload"
    SEND = "send"
    RECEIVE = "receive"
    SPLIT_RECEIVE = "split_receive"
    AWAIT_RECEIVE = "await_receive"
    # reduction pipeline (§2.2): identity-fill device scratch, combine device
    # partials per node, gather peer partials (multi-peer, pilot-driven,
    # fixed-stride slots) and fold them in canonical node order
    FILL_IDENTITY = "fill_identity"
    LOCAL_REDUCE = "local_reduce"
    GATHER_RECEIVE = "gather_receive"
    GLOBAL_REDUCE = "global_reduce"
    # collective exchange rounds (DESIGN.md §9): one COLL_SEND is one packed
    # message of one topology round (multiple block/slot fragments); a
    # COLL_RECV expects exactly one such message from one peer and lands its
    # fragments.  Transfer ids are round-tagged, so rounds of different
    # collectives interleave freely.
    COLL_SEND = "coll_send"
    COLL_RECV = "coll_recv"
    DEVICE_KERNEL = "device_kernel"
    HOST_TASK = "host_task"
    HORIZON = "horizon"
    EPOCH = "epoch"


_instr_ids = itertools.count()


@dataclass
class AccessorBinding:
    """Executor-facing: which allocation backs an accessor for one kernel."""
    accessor: Accessor
    allocation: Allocation
    region: Region                # buffer-space region the kernel may touch


@dataclass
class ReductionBinding:
    """Executor-facing: the identity-filled scratch a kernel reduces into."""
    reduction: Reduction
    allocation: Allocation        # per-device accumulator scratch


@dataclass(frozen=True)
class CollFragment:
    """One packed fragment of a collective message.

    ``key`` is the matching token the receiver expects: ``(member, slot)``
    for reduction-partial slots (member index within a fused group, slot =
    contributor rank), ``(member, lo, hi)`` for allreduce slot-range
    fragments, or a buffer-space :class:`Box` for region collectives.
    ``alloc`` is the allocation the sender reads from — or, on a
    ``COLL_RECV``'s ``coll_land`` list, the allocation the fragment lands
    into — addressed by slot index, slot range or box depending on which
    field is set.
    """

    key: object
    alloc: Allocation
    slot: Optional[int] = None          # reduction slot within ``alloc``
    box: Optional[Box] = None           # buffer-space box within ``alloc``
    srange: Optional[tuple] = None      # flat slot range [lo, hi) in alloc


@dataclass
class Pilot:
    """Pilot message: announces an inbound transfer to the receiver (§3.4).

    ``transfer_id`` is ``(task id, buffer id)`` for push traffic and
    ``(task id, buffer id, 1)`` for reduction-gather traffic, so the two
    protocols never alias; the arbiter routes by transfer id and lands
    gather payloads at the fixed-stride slot of their *source* rank rather
    than at a buffer-space offset.  ``gather`` is wire metadata only (a
    real MPI transport would select the superaccumulator datatype from
    it); the in-process arbiter treats pilots as accounting.
    """
    source: int
    target: int
    transfer_id: tuple
    box: Box                      # buffer-space box being sent
    msg_id: int
    gather: bool = False          # reduction-gather transfer (metadata)


@dataclass
class EpochAbort:
    """EPOCH_ABORT poison message: cross-node failure propagation (§10).

    A failing rank (or a watchdog that detected a dead peer) broadcasts one
    of these through the ``Communicator`` control plane; receivers abort the
    current epoch within ~1 RTT instead of stalling to the epoch timeout.
    The control plane is assumed reliable (it is not subject to the fault
    plan) — on a real transport it maps to the out-of-band error channel.
    """
    origin: int                        # rank that detected/raised the failure
    instruction: str                   # where the origin was when it failed
    cause: str                         # human-readable fault cause
    dead_peer: Optional[int] = None    # the rank believed crashed, if known


@dataclass
class Instruction:
    itype: InstructionType
    node: int
    # queue affinity: ("device", d) | ("host",) | ("comm",) — executor routing
    queue: tuple = ("host",)
    # ALLOC / FREE
    allocation: Optional[Allocation] = None
    # COPY / SPILL / RELOAD
    src_alloc: Optional[Allocation] = None
    dst_alloc: Optional[Allocation] = None
    copy_box: Optional[Box] = None           # buffer-space box to copy
    # SEND
    dest: Optional[int] = None
    msg_id: Optional[int] = None
    send_box: Optional[Box] = None
    # RECEIVE / SPLIT_RECEIVE / AWAIT_RECEIVE / GATHER_RECEIVE
    transfer_id: Optional[tuple] = None
    recv_region: Optional[Region] = None
    recv_alloc: Optional[Allocation] = None
    split_parent: Optional["Instruction"] = None
    # reductions: FILL_IDENTITY fills ``allocation``; LOCAL_REDUCE folds
    # ``reduce_srcs`` into ``dst_alloc``; GATHER_RECEIVE expects one partial
    # per rank in ``gather_sources`` landed at slot=rank in ``recv_alloc``;
    # GLOBAL_REDUCE folds slots of ``src_alloc`` (+ own partial in
    # ``reduce_srcs``) over ``participants`` in node order into ``dst_alloc``
    reduction: Optional[Reduction] = None
    reduce_srcs: tuple[Allocation, ...] = ()
    gather_sources: tuple[int, ...] = ()
    participants: tuple[int, ...] = ()
    include_current: bool = False
    # collective mode (DESIGN.md §9): LOCAL_REDUCE writes slot ``dst_slot``
    # of the staging allocation; GLOBAL_REDUCE with ``slot_all`` folds every
    # participant slot of ``src_alloc`` (own partial included).  COLL_SEND
    # carries ``coll_frags``; COLL_RECV expects keys ``coll_expect`` from
    # ``coll_source`` and lands them into ``coll_allocs``.
    dst_slot: Optional[int] = None
    slot_all: bool = False
    # allreduce mode (DESIGN.md §9): LOCAL_REDUCE with ``slot_range`` and
    # ``accumulate`` folds ``reduce_srcs[0]`` INTO ``dst_alloc[lo:hi]``
    # (fold-on-receive of one reduce-scatter fragment); GLOBAL_REDUCE with
    # ``prefolded`` takes ``src_alloc`` as the already fully folded flat
    # accumulator and only lifts/finalizes.  A COLL_RECV with ``coll_land``
    # lands each expected fragment at the slot range of its entry instead
    # of the (member, slot) addressing.
    slot_range: Optional[tuple] = None
    accumulate: bool = False
    prefolded: bool = False
    coll_frags: tuple[CollFragment, ...] = ()
    coll_allocs: tuple[Allocation, ...] = ()
    coll_expect: tuple = ()
    coll_land: tuple[CollFragment, ...] = ()
    coll_source: Optional[int] = None
    # optional tracer lane override (per-collective Perfetto tracks) — does
    # not affect executor routing, which keys on ``queue``
    trace_lane: Optional[str] = None
    # DEVICE_KERNEL / HOST_TASK
    kernel_fn: Optional[Callable] = None
    chunk: Optional[Box] = None
    bindings: tuple[AccessorBinding, ...] = ()
    red_bindings: tuple[ReductionBinding, ...] = ()
    device: Optional[int] = None
    name: str = ""
    command: Optional[object] = None          # the lowered Command, if any
    # serving-runtime tenant tag (core/memo.py): None for single-program
    # runs — the executor's fast path keys on it staying None
    tenant: Optional[str] = None
    # serving window sequence number (per tenant): lets the executor track
    # how many replayed windows are concurrently in flight (DESIGN.md §13)
    window: Optional[int] = None
    # ALLOC only, stamped at emission: whether the allocation was buffer-
    # backed (persistent) when the ALLOC was emitted.  Renaming mutates
    # ``allocation.bid`` after emission, so the verifier's leak check
    # (DESIGN.md §14) needs the emission-time value, not the current one.
    persistent: Optional[bool] = None
    iid: int = field(default_factory=lambda: next(_instr_ids))
    dependencies: list[tuple["Instruction", DepKind]] = field(default_factory=list)
    dependents: list["Instruction"] = field(default_factory=list)
    # set by the executor:
    state: str = "pending"

    @staticmethod
    def _frag_region(f: CollFragment) -> Region:
        """Allocation-space region one collective fragment addresses."""
        if f.box is not None:
            return Region.from_box(f.box)
        if f.srange is not None:
            lo, hi = f.srange
            return Region.from_box(Box((lo,), (hi,)))
        b = f.alloc.box
        s = f.slot
        return Region.from_box(Box((s,) + b.min[1:], (s + 1,) + b.max[1:]))

    def accesses(self) -> list[tuple[Allocation, Region, str]]:
        """Structured access metadata: ``(allocation, region, mode)`` triples.

        ``mode`` is ``"r"`` (read), ``"w"`` (discard-write), ``"rw"``
        (read-modify-write) or ``"red"`` (combining read-modify-write into a
        reduction accumulator: racing ``"red"`` accesses to the same
        allocation are permitted by construction — the one-writer exception,
        DESIGN.md §14).  Regions are in the coordinate space the allocation
        is addressed in: buffer space for buffer-backed allocations,
        slot-staging space for reduction scratch.  ALLOC/FREE/HORIZON/EPOCH
        perform no data access and return ``[]`` — allocation lifetime is
        carried by ``self.allocation`` instead.

        This is the single source of truth the schedule sanitizer
        (core/verify.py) and the memo hazard wiring (core/memo.py) analyze;
        an instruction type whose executor semantics touch memory not listed
        here is invisible to both.
        """
        T = InstructionType
        it = self.itype
        out: list[tuple[Allocation, Region, str]] = []

        def add(alloc: Optional[Allocation], region: Optional[Region],
                mode: str) -> None:
            if alloc is not None and region is not None:
                out.append((alloc, region, mode))

        def whole(a: Allocation) -> Region:
            return Region.from_box(a.box)

        def row(a: Allocation, s: int) -> Region:
            b = a.box
            return Region.from_box(
                Box((s,) + b.min[1:], (s + 1,) + b.max[1:]))

        if it in (T.COPY, T.SPILL, T.RELOAD):
            reg = Region.from_box(self.copy_box)
            add(self.src_alloc, reg, "r")
            add(self.dst_alloc, reg, "w")
        elif it is T.SEND:
            # ``recv_alloc`` is the *source* allocation for a SEND (the
            # field names the receiver-protocol role, not the direction)
            add(self.recv_alloc, Region.from_box(self.send_box), "r")
        elif it in (T.RECEIVE, T.SPLIT_RECEIVE):
            add(self.recv_alloc, self.recv_region, "w")
        elif it is T.AWAIT_RECEIVE:
            # the split parent is the writer; the await only observes its
            # sub-region (sibling awaits overlap would be false WW races)
            add(self.recv_alloc, self.recv_region, "r")
        elif it is T.GATHER_RECEIVE:
            for src in self.gather_sources:
                add(self.recv_alloc, row(self.recv_alloc, src), "w")
        elif it is T.FILL_IDENTITY:
            add(self.allocation, whole(self.allocation), "w")
        elif it is T.LOCAL_REDUCE:
            for a in self.reduce_srcs:
                add(a, whole(a), "r")
            d = self.dst_alloc
            if self.slot_range is not None:
                lo, hi = self.slot_range
                add(d, Region.from_box(Box((lo,), (hi,))),
                    "rw" if self.accumulate else "w")
            elif self.dst_slot is not None:
                add(d, row(d, self.dst_slot), "w")
            else:
                add(d, whole(d), "w")
        elif it is T.GLOBAL_REDUCE:
            if self.src_alloc is not None:
                add(self.src_alloc, whole(self.src_alloc), "r")
            for a in self.reduce_srcs:
                add(a, whole(a), "r")
            add(self.dst_alloc, whole(self.dst_alloc),
                "rw" if self.include_current else "w")
        elif it is T.COLL_SEND:
            for f in self.coll_frags:
                add(f.alloc, self._frag_region(f), "r")
        elif it is T.COLL_RECV:
            if self.coll_land:
                for f in self.coll_land:
                    add(f.alloc, self._frag_region(f), "w")
            elif self.recv_alloc is not None:
                add(self.recv_alloc, self.recv_region, "w")
            else:
                for key in self.coll_expect:
                    mi, slot = key[0], key[1]
                    a = self.coll_allocs[mi]
                    add(a, row(a, slot), "w")
        elif it in (T.DEVICE_KERNEL, T.HOST_TASK):
            for b in self.bindings:
                m = b.accessor.mode
                mode = ("rw" if (m.is_consumer and m.is_producer)
                        else "w" if m.is_producer else "r")
                add(b.allocation, b.region, mode)
            for rb in self.red_bindings:
                add(rb.allocation, whole(rb.allocation), "red")
        return out

    def add_dependency(self, dep: "Instruction", kind: DepKind) -> None:
        if dep is self:
            return
        for d, _ in self.dependencies:
            if d is dep:
                return
        self.dependencies.append((dep, kind))
        dep.dependents.append(self)

    def __hash__(self) -> int:
        return self.iid

    def __repr__(self) -> str:
        extra = ""
        if self.itype == InstructionType.DEVICE_KERNEL:
            extra = f":{self.name}@D{self.device}"
        elif self.itype in (InstructionType.ALLOC, InstructionType.FREE):
            extra = f":{self.allocation}"
        elif self.itype in (InstructionType.COPY, InstructionType.SPILL,
                            InstructionType.RELOAD):
            extra = (f":{self.src_alloc and self.src_alloc.aid}"
                     f"->{self.dst_alloc and self.dst_alloc.aid}")
        return f"I{self.iid}<{self.itype.value}{extra}>"
