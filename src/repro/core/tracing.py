"""Per-instruction timeline capture — reproduces the paper's fig. 7 profiles.

The tracer records timestamped spans for the three concurrent activities the
paper visualizes: main-thread task submission, scheduler-thread graph
generation, and per-lane instruction execution.  ``overlap_fraction``
quantifies how much scheduling work was hidden behind execution — the
paper's headline qualitative claim for the concurrent architecture.
"""

from __future__ import annotations

import json
import threading
import time
from collections import defaultdict
from dataclasses import dataclass
from typing import Optional

from .observability import InstrRecord


@dataclass
class Span:
    lane: str          # "main" | "sched-N0" | "N0.D1.q0" | "N0.host" | ...
    kind: str          # "task" | "cdag" | "idag" | instruction type
    name: str
    t0: float
    t1: float
    # propagated trace context ({"tid": ..}, {"iid": .., "cid": .., ..}) —
    # exported as event args and used to derive Perfetto flow arrows
    meta: Optional[dict] = None


class Tracer:
    """Thread-safe append-only span log."""

    # executors skip per-instruction issue() callbacks for this tracer:
    # execution spans are derived from completion records, so issue-time
    # open-span tracking would only add a lock round-trip per instruction.
    # Duck-typed tracer doubles that want live issue events leave this True.
    issue_events = False

    def __init__(self, *, record_sample: int = 1) -> None:
        self._lock = threading.Lock()
        # 1-in-N InstrRecord capture: with ``record_sample=N > 1`` only every
        # Nth completion is recorded, cutting traced issue overhead at the
        # cost of honestly widened gaps in the critical-path report (the
        # analyzer's ``unattributed_us`` absorbs the dropped records)
        self.record_sample = max(1, int(record_sample))
        self.records_sampled_out = 0
        self.spans: list[Span] = []
        # counter tracks: name -> [(t, value)] — used for the per-memory
        # byte high-water marks the budget acceptance checks read
        self.counters: dict[str, list[tuple[float, float]]] = defaultdict(list)
        # point-in-time events (fault injections, retransmits, aborts):
        # (lane, name, t, args) — rendered as Perfetto instant ("i") events
        self.instants: list[tuple[str, str, float, dict]] = []
        self._open: dict[tuple[int, int], float] = {}   # (node, iid) -> t_issue
        # per-instruction execution records (timing breakdown + trace
        # context); instruction spans are derived from these on demand, so
        # the executor's completion path appends exactly one object
        self.records: list[InstrRecord] = []
        self.epoch = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self.epoch

    def span(self, lane: str, kind: str, name: str, t0: float, t1: float,
             meta: Optional[dict] = None) -> None:
        with self._lock:
            self.spans.append(Span(lane, kind, name, t0, t1, meta))

    def counter(self, name: str, value: float) -> None:
        """Record one sample of a named counter (e.g. ``N0.M2.bytes``)."""
        with self._lock:
            self.counters[name].append((self.now(), value))

    def instant(self, lane: str, name: str, args: dict | None = None) -> None:
        """Record a point event (drop/retransmit/abort/watchdog fire)."""
        with self._lock:
            self.instants.append((lane, name, self.now(), args or {}))

    def instant_counts(self) -> dict[str, int]:
        """Event-name histogram — chaos tests assert injections were traced."""
        out: dict[str, int] = defaultdict(int)
        with self._lock:
            for _, name, _, _ in self.instants:
                out[name] += 1
        return dict(out)

    def counter_peaks(self, suffix: str = ".bytes") -> dict[str, float]:
        """Max observed value per counter track ending in ``suffix``."""
        with self._lock:
            return {name: max(v for _, v in samples)
                    for name, samples in self.counters.items()
                    if name.endswith(suffix) and samples}

    # executor integration -------------------------------------------------
    def issue(self, node: int, instr) -> None:
        # ``_open`` is shared mutable state: hold the lock (concurrent
        # executors of different nodes issue/complete simultaneously)
        t = self.now()
        with self._lock:
            self._open[(node, instr.iid)] = t

    def complete(self, node: int, instr) -> None:
        # collective rounds carry a per-collective lane override so each
        # exchange renders as its own named Perfetto track (DESIGN.md §9)
        lane = getattr(instr, "trace_lane", None) \
            or f"N{node}." + ".".join(map(str, instr.queue))
        t1 = self.now()
        name = instr.name or repr(instr)
        with self._lock:
            t0 = self._open.pop((node, instr.iid), t1)
            self.spans.append(Span(lane, instr.itype.value, name, t0, t1))

    def record(self, node: int, instr, lane: str, *, t_reg: float,
               t_ready: float, t_start: float, t_done: float,
               wait_cls: str, blame_iid: Optional[int]) -> None:
        """Append one instruction's full timing record (raw perf_counter
        stamps; converted to tracer-epoch time here).  Replaces the
        issue/complete pair on the executor's hot path: one lock, one
        append, and the fig.-7 execution span is derived lazily."""
        rs = self.record_sample
        if rs > 1 and instr.iid % rs:
            # the keep/drop decision is a pure function of the iid so the
            # executor's completion path can short-circuit dropped records
            # without this call (it batches the drop count and flushes it
            # via ``note_sampled_out`` at horizon boundaries)
            with self._lock:
                self.records_sampled_out += 1
                self._open.pop((node, instr.iid), None)
                return
        e = self.epoch
        cmd = instr.command
        task = cmd.task if cmd is not None else None
        rec = InstrRecord(
            node, instr.iid, instr.itype.value, lane,
            instr.name or instr.itype.value,
            t_reg - e, t_ready - e, t_start - e, t_done - e,
            wait_cls, blame_iid,
            task.tid if task is not None else None,
            cmd.cid if cmd is not None else None)
        with self._lock:
            self.records.append(rec)
            self._open.pop((node, instr.iid), None)

    def note_sampled_out(self, n: int) -> None:
        """Credit ``n`` executor-side-dropped records (sampling fast path)."""
        if n:
            with self._lock:
                self.records_sampled_out += n

    # analysis ---------------------------------------------------------------
    def lanes(self) -> dict[str, list[Span]]:
        out: dict[str, list[Span]] = defaultdict(list)
        with self._lock:
            spans = list(self.spans)
            records = list(self.records)
        for s in spans:
            out[s.lane].append(s)
        for r in records:
            out[r.lane].append(Span(
                r.lane, r.kind, r.name, r.t_start, r.t_done,
                {"iid": r.iid, "node": r.node, "tid": r.tid, "cid": r.cid}))
        for v in out.values():
            v.sort(key=lambda s: s.t0)
        return out

    @staticmethod
    def _busy_intervals(spans: list[Span]) -> list[tuple[float, float]]:
        iv = sorted((s.t0, s.t1) for s in spans)
        merged: list[tuple[float, float]] = []
        for a, b in iv:
            if merged and a <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], b))
            else:
                merged.append((a, b))
        return merged

    def overlap_fraction(self, lane_a_prefix: str, lane_b_prefix: str, *,
                         kind_a: str | None = None,
                         kind_b: str | None = None) -> float:
        """Fraction of lane-A busy time during which lane-B was also busy.

        ``kind_a``/``kind_b`` optionally restrict each side to spans of one
        kind (e.g. ``kind_a="reload"``, ``kind_b="device_kernel"`` measures
        how much reload traffic hid behind kernel execution).
        """
        lanes = self.lanes()
        a = self._busy_intervals([s for l, ss in lanes.items()
                                  if l.startswith(lane_a_prefix) for s in ss
                                  if kind_a is None or s.kind == kind_a])
        b = self._busy_intervals([s for l, ss in lanes.items()
                                  if l.startswith(lane_b_prefix) for s in ss
                                  if kind_b is None or s.kind == kind_b])
        total = sum(t1 - t0 for t0, t1 in a)
        if total == 0:
            return 0.0
        inter = 0.0
        j = 0
        for a0, a1 in a:
            while j < len(b) and b[j][1] < a0:
                j += 1
            k = j
            while k < len(b) and b[k][0] < a1:
                inter += max(0.0, min(a1, b[k][1]) - max(a0, b[k][0]))
                k += 1
        return inter / total

    def to_chrome_trace(self, path) -> int:
        """Export the span log as a Chrome/Perfetto trace-event JSON file.

        Each lane becomes a named thread of one process; spans are complete
        ("X") events with microsecond timestamps, so the fig.-7-style
        timeline can be inspected interactively in https://ui.perfetto.dev
        (or chrome://tracing).  Returns the number of events written.
        """
        lanes = self.lanes()
        tids = {lane: i + 1 for i, lane in enumerate(sorted(lanes))}
        events: list[dict] = []
        for lane, tid in tids.items():
            events.append({"ph": "M", "pid": 1, "tid": tid,
                           "name": "thread_name", "args": {"name": lane}})
        # trace-context indexes for the flow arrows: task spans on "main",
        # cdag/idag spans on "sched-N*" (the idag span, when present, is the
        # causally closest source for instruction arrows)
        task_src: dict[int, tuple[int, float]] = {}        # tid -> (ttid, ts)
        sched_src: dict[tuple[int, int], tuple[int, float]] = {}
        cdag_dst: list[tuple[int, int, int, float]] = []   # (node,tid,ttid,ts)
        instr_dst: list[tuple[int, int, Optional[int], int, float]] = []
        for lane, spans in lanes.items():
            tid = tids[lane]
            for s in spans:
                ev = {"ph": "X", "pid": 1, "tid": tid,
                      "name": s.name or s.kind, "cat": s.kind,
                      "ts": s.t0 * 1e6,
                      "dur": max((s.t1 - s.t0) * 1e6, 0.001)}
                if s.meta:
                    ev["args"] = {k: v for k, v in s.meta.items()
                                  if v is not None}
                events.append(ev)
                m = s.meta
                if not m:
                    continue
                if s.kind == "task" and m.get("tid") is not None:
                    task_src[m["tid"]] = (tid, ev["ts"])
                elif s.kind in ("cdag", "idag") and lane.startswith("sched-N"):
                    node, ttid = int(lane[len("sched-N"):]), m.get("tid")
                    if ttid is None:
                        continue
                    if s.kind == "cdag":
                        cdag_dst.append((node, ttid, tid, ev["ts"]))
                        sched_src.setdefault((node, ttid), (tid, ev["ts"]))
                    else:
                        sched_src[(node, ttid)] = (tid, ev["ts"])
                elif "iid" in m:
                    instr_dst.append((m.get("node", 0), m["iid"],
                                      m.get("tid"), tid, ev["ts"]))
        # flow arrows ("s"/"f"): task submission -> command generation ->
        # instruction execution, navigable causally in ui.perfetto.dev
        for node, ttid, tid, ts in cdag_dst:
            src = task_src.get(ttid)
            if src is None:
                continue
            fid = f"t{ttid}.N{node}"
            events.append({"ph": "s", "pid": 1, "tid": src[0], "ts": src[1],
                           "cat": "lower", "name": "lower", "id": fid})
            events.append({"ph": "f", "bp": "e", "pid": 1, "tid": tid,
                           "ts": ts, "cat": "lower", "name": "lower",
                           "id": fid})
        for node, iid, ttid, tid, ts in instr_dst:
            src = sched_src.get((node, ttid)) if ttid is not None else None
            if src is None:
                continue
            fid = f"i{node}.{iid}"
            events.append({"ph": "s", "pid": 1, "tid": src[0], "ts": src[1],
                           "cat": "lower", "name": "lower", "id": fid})
            events.append({"ph": "f", "bp": "e", "pid": 1, "tid": tid,
                           "ts": ts, "cat": "lower", "name": "lower",
                           "id": fid})
        # wait-state attribution: nested async spans under each instruction
        # lane — the pending wait (classified) followed by the queue wait
        with self._lock:
            records = list(self.records)
        for r in records:
            tid = tids.get(r.lane)
            if tid is None:
                continue
            wid = f"w{r.node}.{r.iid}"
            for name, t0, t1 in ((f"wait:{r.wait_cls}", r.t_reg, r.t_ready),
                                 ("wait:queue", r.t_ready, r.t_start)):
                if t1 - t0 <= 0:
                    continue
                events.append({"ph": "b", "pid": 1, "tid": tid, "cat": "wait",
                               "name": name, "id": wid, "ts": t0 * 1e6})
                events.append({"ph": "e", "pid": 1, "tid": tid, "cat": "wait",
                               "name": name, "id": wid, "ts": t1 * 1e6})
        # instant events (fault injections, retransmits, aborts) render as
        # thread-scoped markers on their wire/control lane
        with self._lock:
            instants = list(self.instants)
        for lane, name, t, args in instants:
            tid = tids.get(lane)
            if tid is None:
                tid = tids[lane] = len(tids) + 1
                events.append({"ph": "M", "pid": 1, "tid": tid,
                               "name": "thread_name", "args": {"name": lane}})
            events.append({"ph": "i", "s": "t", "pid": 1, "tid": tid,
                           "name": name, "ts": t * 1e6, "args": args})
        # counter tracks (per-memory bytes, …) render as area charts
        with self._lock:
            counters = {k: list(v) for k, v in self.counters.items()}
        for name, samples in counters.items():
            for t, v in samples:
                events.append({"ph": "C", "pid": 1, "name": name,
                               "ts": t * 1e6, "args": {"value": v}})
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)
        return len(events)

    def timeline_text(self, width: int = 78) -> str:
        """ASCII rendering of the fig.-7-style timeline."""
        lanes = self.lanes()
        if not lanes:
            return "(no spans)"
        tmax = max(s.t1 for ss in lanes.values() for s in ss) or 1e-9
        lines = []
        for lane in sorted(lanes):
            row = [" "] * width
            for s in lanes[lane]:
                i0 = min(width - 1, int(s.t0 / tmax * width))
                i1 = min(width - 1, max(i0, int(s.t1 / tmax * width)))
                for i in range(i0, i1 + 1):
                    row[i] = "#"
            lines.append(f"{lane:>16} |{''.join(row)}|")
        lines.append(f"{'':>16}  0{'':{width - 10}}{tmax * 1e3:8.2f}ms")
        return "\n".join(lines)
