"""The paper's contribution: Celerity-style TDAG -> CDAG -> IDAG scheduling
with lookahead, out-of-order execution and receive arbitration (see DESIGN.md).
"""

from .allocation import Allocation, PINNED_HOST, USER_HOST, device_memory
from .buffer import (AccessMode, Accessor, VirtualBuffer, read, read_write,
                     write)
from .command_graph import Command, CommandGraphGenerator, CommandType, generate_cdag
from .executor import BoundsError, BufferView, Executor, ReductionView
from .faults import (EpochTimeoutError, ExecutionAborted, FaultError,
                     FaultPlan, InjectedCrash, NodeFailure, PeerAborted,
                     TransportError, run_with_restarts)
from .instruction_graph import (EpochAbort, IdagGenerator, Instruction,
                                InstructionType, Pilot)
from .memo import ServingRuntime, Tenant, WindowHandle, window_signature
from .memory import MemoryManager, MemoryStats, MemState
from .observability import (CriticalPathReport, Histogram, MetricsRegistry,
                            classify_wait, critical_path)
from .reduction import Reduction, ReductionOp, reduction
from .lookahead import LookaheadScheduler
from .range_mapper import (all_range, fixed, fixed_row, neighborhood,
                           one_to_one, rows_upto, slice_dim)
from .region import Box, Region, RegionMap, split_box
from .runtime import Runtime, SupervisedResult
from .task_graph import DepKind, Task, TaskGraph, TaskType
from .tracing import Tracer
from .dot import cdag_to_dot, idag_to_dot, tdag_to_dot
from .verify import (CampaignResult, Mutation, ScheduleVerifier,
                     VerificationError, VerificationIssue, VerificationReport,
                     mutate_one, run_mutation_campaign, verify_graph)

__all__ = [
    "Allocation", "PINNED_HOST", "USER_HOST", "device_memory",
    "AccessMode", "Accessor", "VirtualBuffer", "read", "read_write", "write",
    "Command", "CommandGraphGenerator", "CommandType", "generate_cdag",
    "BoundsError", "BufferView", "Executor", "ReductionView",
    "EpochTimeoutError", "ExecutionAborted", "FaultError", "FaultPlan",
    "InjectedCrash", "NodeFailure", "PeerAborted", "TransportError",
    "run_with_restarts",
    "EpochAbort", "IdagGenerator", "Instruction", "InstructionType", "Pilot",
    "ServingRuntime", "Tenant", "WindowHandle", "window_signature",
    "MemoryManager", "MemoryStats", "MemState",
    "CriticalPathReport", "Histogram", "MetricsRegistry",
    "classify_wait", "critical_path",
    "Reduction", "ReductionOp", "reduction",
    "LookaheadScheduler",
    "all_range", "fixed", "fixed_row", "neighborhood", "one_to_one",
    "rows_upto", "slice_dim",
    "Box", "Region", "RegionMap", "split_box",
    "Runtime", "SupervisedResult",
    "DepKind", "Task", "TaskGraph", "TaskType",
    "Tracer",
    "cdag_to_dot", "idag_to_dot", "tdag_to_dot",
    "CampaignResult", "Mutation", "ScheduleVerifier", "VerificationError",
    "VerificationIssue", "VerificationReport", "mutate_one",
    "run_mutation_campaign", "verify_graph",
]
