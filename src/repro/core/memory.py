"""Memory management as a first-class layer (paper §3.2/§4.3 + budgets).

The :class:`MemoryManager` owns the complete allocation lifecycle that used
to be buried inside ``IdagGenerator``:

* the live backing allocations per (buffer, memory) and the resize-chain
  machinery of fig. 3 (merge-with-overlapping + lookahead widening hints);
* the per-(buffer, memory) producer/reader maps (``MemState``) — the
  anti-dependency bookkeeping that gives every allocation a *last user*;
* the coherence map (which memories hold an up-to-date replica of each
  buffer region);
* per-memory **byte budgets** with an LRU eviction policy: when a new
  allocation would exceed a memory's budget, victim allocations are
  *spilled* — their only-here coherent regions are copied down the chain
  device → pinned host (→ user host under pinned pressure) with ``SPILL``
  instructions, the victim is freed, and the next access to the evicted
  region lazily copies it back with a ``RELOAD`` instruction (the ordinary
  coherence machinery, tagged for accounting).

The ``IdagGenerator`` is a pure consumer: it requests regions
(:meth:`ensure`, :meth:`make_coherent`, :meth:`scratch`) and receives
placements; it never decides *where* bytes live or *what* gets dropped.

Budget-correctness invariants (see DESIGN.md §8):

* eviction happens **before** the ALLOC that caused the pressure is
  emitted, and every ALLOC in a budgeted memory takes anti-dependencies on
  all FREEs emitted in that memory since the last horizon/epoch — so the
  executor can never materialize the new allocation before the evicted
  bytes are actually released (cross-window ordering is covered by the
  ALLOC's sync dependency on the horizon);
* allocations pinned by the command currently being compiled, one-shot
  scratches (``evictable=False``) and — preferentially — allocations
  overlapping lookahead *reservations* are not selected as victims;
* eviction never fails: if no victim is available the manager goes over
  budget, records the event and appends a warning (a real system would
  rather thrash than crash).
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional

from .allocation import (Allocation, PINNED_HOST, USER_HOST,
                         is_device_memory, queue_for_mem)
from .buffer import VirtualBuffer
from .instructions import Instruction, InstructionType
from .region import Box, Region, RegionMap
from .task_graph import DepKind


@dataclass
class MemState:
    """Per (buffer, memory) instruction-level tracking.

    ``producers`` maps each region to the instruction that last wrote it in
    this memory; ``readers`` lists (region, instruction) pairs of everything
    that read it since.  Together they are the lifetime information the
    eviction policy relies on: a FREE is anti-ordered after all of them.
    """
    producers: RegionMap          # region -> original producer Instruction
    readers: list[tuple[Region, Instruction]] = field(default_factory=list)


@dataclass
class MemoryStats:
    """Spill/eviction accounting, exposed via ``Runtime.memory_report()``."""
    evictions: int = 0            # victim allocations freed under pressure
    spills: int = 0               # SPILL copy instructions emitted
    spill_bytes: int = 0
    reloads: int = 0              # RELOAD copy instructions emitted
    reload_bytes: int = 0
    over_budget: int = 0          # pressure events with no evictable victim
    # write-back elision: evicted regions whose replica survives elsewhere
    # are dropped without a device->host SPILL copy.  ``writeback_elisions``
    # counts evictions that needed NO spill copy at all (fully clean
    # victim); ``elided_bytes`` counts every dropped-clean byte.
    writeback_elisions: int = 0
    elided_bytes: int = 0
    # reloads issued ahead of first use by the lookahead flush (§4.3)
    prefetched_reloads: int = 0
    # allocation renaming (DESIGN.md §13)
    renames: int = 0              # writes redirected to a fresh physical
    pool_hits: int = 0            # renames served from the recycled pool
    pool_frees: int = 0           # pooled physicals drained under pressure

    def as_dict(self) -> dict:
        return dict(evictions=self.evictions, spills=self.spills,
                    spill_bytes=self.spill_bytes, reloads=self.reloads,
                    reload_bytes=self.reload_bytes,
                    over_budget=self.over_budget,
                    writeback_elisions=self.writeback_elisions,
                    elided_bytes=self.elided_bytes,
                    prefetched_reloads=self.prefetched_reloads,
                    renames=self.renames, pool_hits=self.pool_hits,
                    pool_frees=self.pool_frees)


class MemoryManager:
    """Budgeted allocation lifecycle for one node's instruction graph.

    ``host`` is the owning ``IdagGenerator``; the manager emits its
    ALLOC/FREE/COPY/SPILL/RELOAD instructions through ``host._emit`` so
    emission order, counters and retirement behave exactly as before the
    extraction.  With no budgets configured the emitted instruction stream
    is bit-identical to the historical in-generator implementation.
    """

    def __init__(self, host, *, d2d: bool = True,
                 budgets: Optional[dict[int, int]] = None,
                 hints: Optional[dict[tuple[int, int], Region]] = None,
                 metrics=None, namespace: Optional[str] = None,
                 buffer_owner: Optional[dict[int, str]] = None,
                 renaming: bool = False):
        self.host = host
        self.d2d = d2d
        # allocation renaming (DESIGN.md §13): pure overwrites retire the
        # current physical to a per-(memory, size-class) free pool and bind
        # the buffer version to a fresh physical, turning WAR/WAW hazards
        # into pool recycling.  Off by default: the renamed stream trades
        # peak memory (two physicals per hot buffer) for pipeline depth.
        self.renaming = renaming
        # free pool: (mid, box.min, box.max, dtype) -> recycled physicals.
        # Exact-box matching keeps the executor's lazy offset slicing valid
        # with zero copies; ``_pool_allocs`` is the drain/shutdown index.
        self._free_pool: dict[tuple, list[Allocation]] = {}
        self._pool_allocs: list[Allocation] = []
        # multi-tenant serving (DESIGN.md §12): managers of different
        # tenants share one process but must never alias buffers.
        # ``namespace`` scopes the metric prefix; ``buffer_owner`` is the
        # serving runtime's shared bid -> tenant map consulted on
        # registration so a program that smuggles another tenant's buffer
        # handle is rejected at lowering time, not at data corruption time.
        self.namespace = namespace
        self.buffer_owner = buffer_owner
        # observability (DESIGN.md §11): pressure events mirrored into the
        # unified registry under ``memory.N<node>.*`` (namespace-scoped to
        # ``memory.<ns>.N<node>.*`` for serving tenants)
        self.metrics = metrics
        ns = f"{namespace}." if namespace else ""
        self._metric_prefix = f"memory.{ns}N{getattr(host, 'node', 0)}."
        self.budgets: dict[int, int] = dict(budgets or {})
        if USER_HOST in self.budgets:
            raise ValueError(
                "M0 (user host) memory cannot be budgeted: it is user-owned "
                "and the final target of every spill chain")
        # allocation state (was IdagGenerator._allocs/_mem/_coherence/_buffers)
        self.allocations: dict[tuple[int, int], list[Allocation]] = {}
        self.mem: dict[tuple[int, int], MemState] = {}
        self.coherence: dict[int, RegionMap] = {}       # region -> frozenset(mids)
        self.buffers: dict[int, VirtualBuffer] = {}
        # lookahead cooperation: ``hints`` accumulate for allocation widening
        # (fig.-3 resize elision needs the whole history); ``reserved`` is
        # the CURRENT lookahead window's requirements only — the regions
        # about to be accessed, which eviction avoids.  Protecting the
        # accumulated set instead would degenerate to plain LRU once every
        # buffer has been hinted at least once.
        self.hints: dict[tuple[int, int], Region] = dict(hints or {})
        self.reserved: dict[tuple[int, int], Region] = dict(self.hints)
        # budget accounting (compile-time model, bytes)
        self.used: dict[int, int] = {}
        self.peak: dict[int, int] = {}
        self.stats = MemoryStats()
        # buffer regions whose device replica was dropped by eviction; the
        # next coherence copy back into a device memory is tagged RELOAD
        self.spilled: dict[int, Region] = {}
        # FREEs emitted per budgeted memory since the last sync — every new
        # ALLOC in that memory anti-depends on them (runtime ordering)
        self._free_anchor: dict[int, list[Instruction]] = {}
        # over-budget warning dedup per memory id (the node is fixed per
        # manager): warning-list index + repeat count, so long over-budget
        # runs keep ``Runtime.warnings`` bounded like everything else
        self._over_budget_warned: dict[int, tuple[int, int]] = {}
        # pin scope: allocations touched while compiling the current command
        self._pins: set[int] = set()
        self._pin_depth = 0
        self._clock = 0
        # the initial epoch instruction; set by the generator right after it
        # is emitted (default producer for fresh MemStates)
        self.init_anchor: Optional[Instruction] = None

    # -- small helpers -----------------------------------------------------
    def _touch(self, a: Allocation) -> None:
        self._clock += 1
        a.last_use = self._clock
        if self._pin_depth:
            self._pins.add(a.aid)

    @contextmanager
    def pin_scope(self):
        """Protect every allocation touched inside the scope from eviction.

        Scopes nest (spilling re-enters ``ensure`` for the spill target);
        pins clear when the outermost scope exits — i.e. per compiled
        command, which is exactly the working set that must stay resident.
        """
        self._pin_depth += 1
        try:
            yield
        finally:
            self._pin_depth -= 1
            if self._pin_depth == 0:
                self._pins.clear()

    def _charge(self, a: Allocation) -> None:
        n = self.used.get(a.mid, 0) + a.nbytes()
        self.used[a.mid] = n
        if n > self.peak.get(a.mid, 0):
            self.peak[a.mid] = n
        self._touch(a)

    def _release(self, a: Allocation, free_instr: Instruction) -> None:
        self.used[a.mid] = self.used.get(a.mid, 0) - a.nbytes()
        if a.mid in self.budgets:
            self._free_anchor.setdefault(a.mid, []).append(free_instr)

    # -- buffer / state registration --------------------------------------
    def register_buffer(self, buf: VirtualBuffer) -> None:
        if buf.bid in self.buffers:
            return
        if self.buffer_owner is not None and self.namespace is not None:
            owner = self.buffer_owner.get(buf.bid)
            if owner is not None and owner != self.namespace:
                raise PermissionError(
                    f"tenant '{self.namespace}' accessed buffer "
                    f"'{buf.name}' (B{buf.bid}) owned by tenant '{owner}'")
        self.buffers[buf.bid] = buf
        if buf.initial_value is not None:
            # data present in user host memory M0, produced by init epoch
            a = Allocation(mid=USER_HOST, bid=buf.bid, box=buf.full_box,
                           dtype=buf.dtype, evictable=False,
                           initial_data=buf.initial_value)
            self.allocations[(buf.bid, USER_HOST)] = [a]
            self.coherence[buf.bid] = RegionMap(buf.full_box,
                                                default=frozenset([USER_HOST]))
            ms = self.state(buf.bid, USER_HOST)
            ms.producers.update(buf.full_region, self.init_anchor)
        else:
            self.coherence[buf.bid] = RegionMap(buf.full_box, default=frozenset())

    def state(self, bid: int, mid: int) -> MemState:
        ms = self.mem.get((bid, mid))
        if ms is None:
            buf = self.buffers[bid]
            ms = MemState(producers=RegionMap(buf.full_box,
                                              default=self.init_anchor))
            self.mem[(bid, mid)] = ms
        return ms

    def coherent_region(self, bid: int, mid: int) -> Region:
        out = Region.empty()
        for r, mids in self.coherence[bid].entries:
            if mids and mid in mids:
                out = out.union(r)
        return out

    def note_write(self, bid: int, region: Region) -> None:
        """A kernel/reduce overwrote ``region`` — nothing to reload there."""
        sp = self.spilled.get(bid)
        if sp is not None and not sp.is_empty():
            self.spilled[bid] = sp.difference(region)

    # -- queries (lookahead / would_allocate) ------------------------------
    def would_allocate_box(self, bid: int, mid: int, box: Box) -> bool:
        for a in self.allocations.get((bid, mid), []):
            if a.live and a.box.contains(box):
                return False
        return True

    def live(self, bid: int, mid: int, box: Box) -> Allocation:
        """The live allocation containing ``box`` (must exist)."""
        for a in self.allocations.get((bid, mid), []):
            if a.live and a.box.contains(box):
                self._touch(a)
                return a
        raise AssertionError(f"no live allocation covers B{bid} M{mid} {box}")

    def reserve(self, hints: dict[tuple[int, int], Region],
                window: Optional[dict[tuple[int, int], Region]] = None) -> None:
        """Adopt ``hints`` (accumulated) for allocation widening and
        ``window`` (the current lookahead window's requirements only) as
        eviction-protection reservations; without ``window`` the full hint
        set is protected (direct callers outside the lookahead)."""
        self.hints = dict(hints)
        self.reserved = dict(hints if window is None else window)

    def prefetch_reloads(self,
                         window: dict[tuple[int, int], Region]) -> list[Instruction]:
        """Spill-aware lookahead (§4.3 + DESIGN.md §8): issue RELOAD copies
        for the window's spilled device regions AHEAD of their first use, so
        reload latency hides behind execution like every other copy.

        Called by the lookahead flush after :meth:`reserve` (the window is
        already eviction-protected, so the prefetched bytes stay resident)
        and BEFORE the window's commands compile — the later ``ensure`` /
        ``make_coherent`` calls then find the region already in flight.
        """
        out: list[Instruction] = []
        # capture EVERYTHING emitted (allocs, frees, cascade spills, copies)
        with self.host.capture_batch(out):
            for (bid, mid), region in window.items():
                if not is_device_memory(mid):
                    continue
                sp = self.spilled.get(bid)
                if sp is None or sp.is_empty():
                    continue
                need = sp.intersect(region)
                if need.is_empty():
                    continue
                buf = self.buffers.get(bid)
                if buf is None:
                    continue
                before = self.stats.reloads
                with self.pin_scope():
                    self.make_coherent(buf, mid, need)
                self.stats.prefetched_reloads += \
                    self.stats.reloads - before
        return out

    # -- instruction emission helpers --------------------------------------
    def _emit_alloc(self, alloc: Allocation, name: str) -> Instruction:
        gen = self.host
        instr = gen._emit(Instruction(
            InstructionType.ALLOC, node=gen.node,
            queue=queue_for_mem(alloc.mid), allocation=alloc, name=name,
            persistent=alloc.bid is not None))
        if gen._last_horizon is not None:
            instr.add_dependency(gen._last_horizon, DepKind.SYNC)
        elif gen._last_epoch is not None:
            instr.add_dependency(gen._last_epoch, DepKind.SYNC)
        if alloc.mid in self.budgets:
            # never materialize before the bytes we evicted are released
            for fr in self._free_anchor.get(alloc.mid, ()):
                instr.add_dependency(fr, DepKind.ANTI)
        alloc.alloc_instr = instr
        self._charge(alloc)
        return instr

    def _free_instruction(self, alloc: Allocation) -> Instruction:
        """Bare FREE emission; callers wire anti-deps, then retire it."""
        gen = self.host
        return gen._emit(Instruction(
            InstructionType.FREE, node=gen.node,
            queue=queue_for_mem(alloc.mid), allocation=alloc,
            name=f"free {alloc}"))

    def _emit_free(self, alloc: Allocation, ms: MemState) -> Instruction:
        """FREE anti-ordered after every reader/producer of the allocation."""
        fr = self._free_instruction(alloc)
        breg = Region.from_box(alloc.box)
        for r, reader in ms.readers:
            if r.overlaps(breg):
                fr.add_dependency(reader, DepKind.ANTI)
        for sub, producer in ms.producers.query(breg):
            fr.add_dependency(producer, DepKind.ANTI)
        alloc.live = False
        self._release(alloc, fr)
        return fr

    def _emit_copy(self, buf: VirtualBuffer, src: Allocation, dst: Allocation,
                   box: Box, producer: Instruction,
                   itype: InstructionType = InstructionType.COPY) -> Instruction:
        # copies between device memories run on the (src) device queue;
        # host<->device copies run on the device queue; host-host on host.
        gen = self.host
        q = queue_for_mem(dst.mid if is_device_memory(dst.mid) else src.mid)
        cp = gen._emit(Instruction(
            itype, node=gen.node, queue=q,
            src_alloc=src, dst_alloc=dst, copy_box=box,
            name=f"{itype.value} {buf.name} {box} M{src.mid}->M{dst.mid}"))
        cp.add_dependency(producer, DepKind.TRUE)
        for a in (src, dst):
            if a.alloc_instr is not None:
                cp.add_dependency(a.alloc_instr, DepKind.TRUE)
        # WAR/WAW against the destination region in dst memory
        dms = self.state(buf.bid, dst.mid)
        breg = Region.from_box(box)
        for r, reader in dms.readers:
            if r.overlaps(breg):
                cp.add_dependency(reader, DepKind.ANTI)
        for sub, w in dms.producers.query(breg):
            cp.add_dependency(w, DepKind.OUTPUT)
        dms.producers.update(breg, cp)
        # reading the source region
        sms = self.state(buf.bid, src.mid)
        sms.readers.append((breg, cp))
        self._touch(src)
        self._touch(dst)
        if itype is InstructionType.SPILL:
            self.stats.spills += 1
            self.stats.spill_bytes += box.volume() * buf.elem_bytes()
            if self.metrics is not None:
                self.metrics.counter(self._metric_prefix + "spills")
                self.metrics.counter(self._metric_prefix + "spill_bytes",
                                     box.volume() * buf.elem_bytes())
        elif itype is InstructionType.RELOAD:
            self.stats.reloads += 1
            self.stats.reload_bytes += box.volume() * buf.elem_bytes()
            if self.metrics is not None:
                self.metrics.counter(self._metric_prefix + "reloads")
                self.metrics.counter(self._metric_prefix + "reload_bytes",
                                     box.volume() * buf.elem_bytes())
        return cp

    # -- allocation management (§3.2) ---------------------------------------
    def ensure(self, buf: VirtualBuffer, mid: int, box: Box) -> Allocation:
        """Return a live allocation whose box contains ``box``; emit
        alloc/copy/free resize chains if needed (fig. 3), evicting under
        budget pressure first."""
        self.register_buffer(buf)
        key = (buf.bid, mid)
        allocs = self.allocations.setdefault(key, [])
        for a in allocs:
            if a.live and a.box.contains(box):
                self._touch(a)
                return a
        # need a new allocation: merge with all overlapping live allocations
        # AND with lookahead widening hints, to a fixpoint — widening may
        # newly overlap allocations that the original request did not
        # (found by hypothesis, tests/test_lookahead_property.py)
        hint = self.hints.get(key)
        new_box = box
        while True:
            overlapping = [a for a in allocs
                           if a.live and a.box.overlaps(new_box)]
            grown = new_box
            for a in overlapping:
                grown = grown.union_bbox(a.box)
            if hint is not None and not hint.is_empty():
                for hb in hint.boxes:
                    if hb.overlaps(grown) or any(a.box.overlaps(hb)
                                                 for a in overlapping):
                        grown = grown.union_bbox(hb)
                hint_bb = hint.bounding_box()
                if hint_bb.overlaps(grown):
                    grown = grown.union_bbox(hint_bb)
            if grown == new_box:
                break
            new_box = grown
        new_alloc = Allocation(mid=mid, bid=buf.bid, box=new_box, dtype=buf.dtype)
        # budget pressure: make room BEFORE materializing; the overlapping
        # allocations must survive until their data migrates, so they are
        # protected (their bytes release when the migration frees them)
        self._evict_until(mid, new_alloc.nbytes(),
                          protect={a.aid for a in overlapping})
        self._emit_alloc(new_alloc, f"alloc {buf.name} M{mid} {new_box}")
        ms = self.state(buf.bid, mid)
        # migrate live data from the old allocations into the new one
        coherent_here = self.coherent_region(buf.bid, mid)
        for old in overlapping:
            live_region = coherent_here.intersect_box(old.box)
            for sub, producer in ms.producers.query(live_region):
                for b in sub.boxes:
                    self._emit_copy(buf, old, new_alloc, b, producer)
            self._emit_free(old, ms)
        self.allocations[key] = [a for a in allocs if a.live] + [new_alloc]
        # producers of migrated regions are now the copies — but since the
        # copies carry the same data, we keep the original producer mapping;
        # dependency-wise, subsequent readers in this memory must depend on
        # the migration copies, which we ensure by updating producers to them.
        return new_alloc

    def scratch(self, mid: int, box: Box, dtype, name: str) -> Allocation:
        """Emit a one-shot scratch ALLOC (outside the resize machinery),
        sync-anchored like every other allocation.  Scratches are charged
        against the budget but never selected as eviction victims — their
        lifetime is one reduction pipeline and they die on schedule."""
        alloc = Allocation(mid=mid, bid=None, box=box, dtype=dtype,
                           evictable=False)
        self._evict_until(mid, alloc.nbytes(), protect=frozenset())
        self._emit_alloc(alloc, name)
        return alloc

    def free_scratch(self, alloc: Allocation,
                     anti: list[Instruction]) -> Instruction:
        """Free a one-shot scratch once all ``anti`` users completed."""
        fr = self._free_instruction(alloc)
        for a in anti:
            fr.add_dependency(a, DepKind.ANTI)
        alloc.live = False
        self._release(alloc, fr)
        return fr

    # -- allocation renaming (DESIGN.md §13) --------------------------------
    @staticmethod
    def _pool_key(a: Allocation) -> tuple:
        return (a.mid, a.box.min, a.box.max, str(a.dtype))

    def rename_for_write(self, buf: VirtualBuffer, mid: int,
                         write_region: Region) -> Optional[Allocation]:
        """Redirect a pure overwrite of ``write_region`` to a fresh physical.

        The current physical backing the buffer version in ``mid`` retires
        to the free pool carrying its outstanding users as *hazard records*;
        the version map rebinds to a recycled (exact size-class match) or
        freshly allocated physical.  The writer then depends only on the new
        physical's hazards — for a fresh physical, on nothing at all — so
        WAR/WAW edges against the previous timestep's readers disappear from
        the emitted IDAG.  Returns the new physical, or ``None`` when
        renaming does not apply (not a device/pinned memory, no current
        physical, or dropping the physical would lose the sole coherent
        replica of a region the write does not cover).
        """
        if not self.renaming or mid == USER_HOST:
            return None
        key = (buf.bid, mid)
        bbox = write_region.bounding_box()
        cur = None
        for a in self.allocations.get(key, []):
            if a.live and a.box.contains(bbox):
                cur = a
                break
        if cur is None or cur.alloc_instr is None:
            return None
        breg = Region.from_box(cur.box)
        # hazard snapshot: everyone still using the old version through this
        # physical; the pool entry carries them until its next writer.  A
        # physical nobody uses (fresh ensure, no reads/writes yet) is NOT
        # renamed — the write carries no hazard edges to begin with, and a
        # pooled physical with an empty hazard list would let its drain-FREE
        # execute unordered against its own ALLOC.
        ms = self.state(buf.bid, mid)
        hz: list[Instruction] = []
        for r, reader in ms.readers:
            if r.overlaps(breg):
                hz.append(reader)
        for sub, producer in ms.producers.query(breg):
            if producer not in hz:
                hz.append(producer)
        if not hz:
            return None
        uncovered = breg.difference(write_region)
        coh = self.coherence[buf.bid]
        drops: list[tuple[Region, frozenset]] = []
        if not uncovered.is_empty():
            for sub, mids in coh.query(uncovered):
                if not mids or mid not in mids:
                    continue
                if mids == frozenset([mid]):
                    return None      # sole replica lives here: cannot drop
                drops.append((sub, mids))
        # recycle BEFORE retiring ``cur`` so we never hand it back to itself
        pkey = self._pool_key(cur)
        pool = self._free_pool.get(pkey)
        nxt = pool.pop() if pool else None
        for sub, mids in drops:
            coh.update(sub, mids - {mid})
        cur.hazards = hz
        cur.live = False
        cur.bid = None
        self.allocations[key] = \
            [a for a in self.allocations.get(key, []) if a is not cur]
        self._free_pool.setdefault(pkey, []).append(cur)
        self._pool_allocs.append(cur)
        if nxt is not None:
            self._pool_allocs.remove(nxt)
            nxt.bid = buf.bid
            nxt.live = True
            self._touch(nxt)
            self.stats.pool_hits += 1
        else:
            nxt = Allocation(mid=mid, bid=buf.bid, box=cur.box,
                             dtype=cur.dtype)
            self._evict_until(mid, nxt.nbytes(), protect=frozenset())
            self._emit_alloc(
                nxt, f"alloc {buf.name} M{mid} {cur.box} (rename)")
        # the old version's bookkeeping moves off the map: readers of the
        # retired physical live on only as its hazard records, and the
        # producer map re-anchors on the last sync point
        gen = self.host
        anchor = gen._last_horizon or gen._last_epoch or self.init_anchor
        ms.readers = [(r, t) for r, t in ms.readers if not r.overlaps(breg)]
        ms.producers.update(breg, anchor)
        self.allocations.setdefault(key, []).append(nxt)
        self.stats.renames += 1
        if self.metrics is not None:
            self.metrics.counter(self._metric_prefix + "renames")
        return nxt

    def take_hazards(self, alloc: Allocation) -> list[Instruction]:
        """Consume the hazard records of a recycled physical (the caller
        wires them as ANTI deps of the first new writer)."""
        hz = alloc.hazards
        if hz:
            alloc.hazards = []
        return hz

    def _drain_pool(self, mid: int) -> bool:
        """Free ONE pooled physical in ``mid`` to relieve budget pressure.

        Preference order cooperates with the lookahead: physicals whose box
        no reservation in this memory overlaps go first; reserved-size
        entries are drained only as a last resort (they would likely be
        re-allocated by the window's next rename)."""
        candidates = [a for a in self._pool_allocs if a.mid == mid]
        if not candidates:
            return False

        def wanted(a: Allocation) -> bool:
            areg = Region.from_box(a.box)
            for (bid, m), r in self.reserved.items():
                if m == mid and r is not None and not r.is_empty() \
                        and r.overlaps(areg):
                    return True
            return False

        victim = next((a for a in candidates if not wanted(a)),
                      candidates[0])
        fr = self._free_instruction(victim)
        if victim.alloc_instr is not None:
            fr.add_dependency(victim.alloc_instr, DepKind.TRUE)
        for h in victim.hazards:
            fr.add_dependency(h, DepKind.ANTI)
        victim.hazards = []
        self._release(victim, fr)
        self._pool_allocs.remove(victim)
        lst = self._free_pool.get(self._pool_key(victim))
        if lst and victim in lst:
            lst.remove(victim)
        self.stats.pool_frees += 1
        return True

    # -- eviction / spilling ------------------------------------------------
    def _evict_until(self, mid: int, need: int, protect: frozenset | set) -> None:
        budget = self.budgets.get(mid)
        if budget is None:
            return
        while self.used.get(mid, 0) + need > budget:
            # recycled-but-idle physicals are the cheapest bytes to reclaim:
            # no spill copy, no coherence loss — drain the pool first
            if self._drain_pool(mid):
                continue
            victim = self._pick_victim(mid, protect)
            if victim is None:
                self.stats.over_budget += 1
                msg = (f"memory M{mid} over budget on N{self.host.node}: "
                       f"{self.used.get(mid, 0)} bytes live + {need} "
                       f"requested > budget {budget}, nothing evictable")
                prev = self._over_budget_warned.get(mid)
                if prev is None:
                    # first occurrence for this (memory, node): new entry
                    self.host.warnings.append(msg)
                    self._over_budget_warned[mid] = \
                        (len(self.host.warnings) - 1, 1)
                else:
                    # repeat: update the entry in place with the latest
                    # numbers and a counter instead of growing the list
                    idx, count = prev
                    self.host.warnings[idx] = \
                        f"{msg} (repeated {count + 1} times)"
                    self._over_budget_warned[mid] = (idx, count + 1)
                return
            self._spill(victim)
            self.stats.evictions += 1
            if self.metrics is not None:
                self.metrics.counter(self._metric_prefix + "evictions")

    def _is_dirty(self, a: Allocation) -> bool:
        """Whether evicting ``a`` would need a write-back: some region of it
        is coherent ONLY here.  In this coherence model a write makes its
        memory the sole coherent holder, so clean <=> replica elsewhere."""
        coh = self.coherence.get(a.bid)
        if coh is None:
            return False
        for sub, mids in coh.query(Region.from_box(a.box)):
            if mids and mids == frozenset([a.mid]):
                return True
        return False

    def _pick_victim(self, mid: int, protect) -> Optional[Allocation]:
        """Victim scoring: reservations first (cooperate, don't fight §4.3),
        then clean-before-dirty (a clean victim's eviction elides the
        write-back copy entirely), then LRU."""
        best = None
        best_key = None
        for (bid, m), lst in self.allocations.items():
            if m != mid:
                continue
            res = self.reserved.get((bid, mid))
            for a in lst:
                if (not a.live or not a.evictable or a.aid in self._pins
                        or a.aid in protect):
                    continue
                reserved = bool(res is not None and not res.is_empty()
                                and res.overlaps(Region.from_box(a.box)))
                k = (reserved, self._is_dirty(a), a.last_use)
                if best_key is None or k < best_key:
                    best, best_key = a, k
        return best

    def _spill(self, victim: Allocation) -> None:
        """Evict one allocation: copy its only-here coherent regions down
        the spill chain (device -> pinned host -> user host), then free it.

        Regions also coherent in another memory are simply dropped (the
        replica survives); the device-resident regions lost here are marked
        so the next coherence copy back is tagged RELOAD.
        """
        bid, mid = victim.bid, victim.mid
        buf = self.buffers[bid]
        ms = self.state(bid, mid)
        coh = self.coherence[bid]
        vregion = Region.from_box(victim.box)
        only_here: list[Region] = []
        elsewhere: list[tuple[Region, frozenset]] = []
        spilled_out = Region.empty()
        for sub, mids in coh.query(vregion):
            if not mids or mid not in mids:
                continue
            if mids == frozenset([mid]):
                only_here.append(sub)
                # only regions actually copied out count as spilled — a
                # dropped replica survives elsewhere, so copying it back
                # later is ordinary coherence traffic, not a RELOAD
                spilled_out = spilled_out.union(sub)
            else:
                elsewhere.append((sub, mids))
                # write-back elision: the region is clean here (a coherent
                # replica survives elsewhere), so dropping it needs no copy
                self.stats.elided_bytes += \
                    sum(b.volume() for b in sub.boxes) * buf.elem_bytes()
        if not only_here:
            self.stats.writeback_elisions += 1
        target_mid = PINNED_HOST if is_device_memory(mid) else USER_HOST
        if only_here:
            out = Region.empty()
            for sub in only_here:
                out = out.union(sub)
            # the spill target may itself come under pressure -> cascades
            dst = self.ensure(buf, target_mid, out.bounding_box())
            for sub in only_here:
                for psub, producer in ms.producers.query(sub):
                    for b in psub.boxes:
                        self._emit_copy(buf, victim, dst, b, producer,
                                        itype=InstructionType.SPILL)
                coh.update(sub, frozenset([target_mid]))
        for sub, mids in elsewhere:
            coh.update(sub, mids - {mid})
        if is_device_memory(mid) and not spilled_out.is_empty():
            sp = self.spilled.get(bid, Region.empty())
            self.spilled[bid] = sp.union(spilled_out)
        self._emit_free(victim, ms)
        self.allocations[(bid, mid)] = \
            [a for a in self.allocations.get((bid, mid), []) if a is not victim]

    # -- coherence (§3.3) ----------------------------------------------------
    def make_coherent(self, buf: VirtualBuffer, mid: int,
                      region: Region) -> list[Instruction]:
        """Emit producer-split copies so ``region`` is up-to-date in ``mid``.
        Copies of previously evicted regions back into device memory are
        tagged RELOAD (lazy reload-on-next-access)."""
        self.register_buffer(buf)
        copies: list[Instruction] = []
        coh = self.coherence[buf.bid]
        stale = Region.empty()
        for sub, mids in coh.query(region):
            if not mids or mid in mids:
                continue
            stale = stale.union(sub)
        if stale.is_empty():
            return copies
        dst = self.ensure(buf, mid, region.bounding_box())
        sp = self.spilled.get(buf.bid)
        track_reload = (is_device_memory(mid) and sp is not None
                        and not sp.is_empty())
        for sub, mids in coh.query(stale):
            if not mids:
                continue
            src_mid = self._pick_source(mids, mid)
            if (is_device_memory(src_mid) and is_device_memory(mid)
                    and not self.d2d):
                # no P2P: stage through pinned host memory (§3.3)
                copies += self.make_coherent(buf, PINNED_HOST, sub)
                src_mid = PINNED_HOST
            src_ms = self.state(buf.bid, src_mid)
            itype = (InstructionType.RELOAD
                     if track_reload and sp.overlaps(sub)
                     else InstructionType.COPY)
            for src_alloc in self.allocations.get((buf.bid, src_mid), []):
                if not src_alloc.live:
                    continue
                part = sub.intersect_box(src_alloc.box)
                # producer split: one copy per original-producer entry
                for psub, producer in src_ms.producers.query(part):
                    for b in psub.boxes:
                        copies.append(self._emit_copy(buf, src_alloc, dst, b,
                                                      producer, itype=itype))
            coh.update(sub, (frozenset(mids) | {mid}))
        if track_reload:
            self.spilled[buf.bid] = sp.difference(stale)
        return copies

    def _pick_source(self, mids: frozenset, target: int) -> int:
        """Prefer same-kind memory, then pinned host, then user host."""
        mids = set(mids)
        if is_device_memory(target):
            dev = [m for m in mids if is_device_memory(m)]
            if dev and self.d2d:
                return min(dev)
            if PINNED_HOST in mids:
                return PINNED_HOST
            if USER_HOST in mids:
                return USER_HOST
            return min(mids)
        for pref in (PINNED_HOST, USER_HOST):
            if pref in mids:
                return pref
        return min(mids)

    # -- sync integration ----------------------------------------------------
    def compact_at_sync(self, sync_instr: Instruction) -> None:
        """Horizon compaction: prior producers collapse onto the sync point;
        the free-anchor lists reset (the ALLOC sync dependency now covers
        runtime ordering against everything before the horizon)."""
        for ms in self.mem.values():
            ms.producers.update(ms.producers.covered(), sync_instr)
            ms.producers.coalesce()
            ms.readers = []
        self._free_anchor.clear()
        # pooled physicals' hazards collapse onto the sync too — NOT to
        # empty: an instruction compiled after this sync that has other
        # dependencies gets no sync edge of its own, so a recycled
        # physical's first writer must still order behind the sync here
        for a in self._pool_allocs:
            a.hazards = [sync_instr]

    # -- shutdown -------------------------------------------------------------
    def free_all(self) -> list[Instruction]:
        """Emit frees for all live allocations (buffer destruction, §3.2)."""
        out = []
        for (bid, mid), allocs in self.allocations.items():
            for a in allocs:
                if not a.live or mid == USER_HOST:
                    continue
                out.append(self._emit_free(a, self.state(bid, mid)))
        for a in self._pool_allocs:
            fr = self._free_instruction(a)
            if a.alloc_instr is not None:
                fr.add_dependency(a.alloc_instr, DepKind.TRUE)
            for h in a.hazards:
                fr.add_dependency(h, DepKind.ANTI)
            a.hazards = []
            self._release(a, fr)
            out.append(fr)
        self._pool_allocs.clear()
        self._free_pool.clear()
        return out

    def pool_provenance(self) -> list[dict]:
        """Free-pool state for the schedule sanitizer (DESIGN.md §14).

        One record per currently pooled (retired, recyclable) physical:
        its identity, its size-class pool key, the ALLOC instruction that
        materialized it, and the iids of the hazard records its next writer
        must consume as ANTI deps.  The verifier cross-checks these against
        the captured instruction stream — a pooled physical whose hazards
        were dropped is exactly the PR 9 drain-FREE bug shape.
        """
        return [dict(aid=a.aid, mid=a.mid, key=self._pool_key(a),
                     alloc_iid=(a.alloc_instr.iid
                                if a.alloc_instr is not None else None),
                     hazard_iids=[h.iid for h in a.hazards],
                     nbytes=a.nbytes())
                for a in self._pool_allocs]

    # -- introspection --------------------------------------------------------
    def snapshot(self) -> dict:
        """Compile-time model state for benchmarks/diagnostics."""
        return dict(budgets=dict(self.budgets), used=dict(self.used),
                    peak=dict(self.peak), **self.stats.as_dict())
