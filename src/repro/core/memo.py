"""Schedule memoization + multi-tenant serving runtime (DESIGN.md §12).

The paper's thesis is that graph-based IRs move scheduling work off the
latency-sensitive critical path; a long-lived service handling millions of
near-identical requests takes that to its limit.  After the first few
submissions of a task-graph *shape*, TDAG→CDAG→IDAG lowering is pure
repeated work: this module caches the lowered instruction window, keyed by a
canonical shape signature, and **replays** it on subsequent submissions with
only the per-request parameters patched in — fresh instruction/epoch/
transfer ids and the new kernel closures.  Amortized scheduling cost per
request approaches the cost of one ``copy.copy`` per instruction.

Multi-tenancy is the second axis: a :class:`ServingRuntime` hosts many
concurrent client programs (*tenants*) over one communicator + executor
grid.  Each tenant owns a buffer namespace (cross-tenant buffer access is
rejected at lowering time by the MemoryManager ownership map), its own
``memory_budgets``, its own TDAG/CDAG/IDAG pipeline and its own memo cache.
Executors interleave ready instructions of different tenants round-robin
and bound per-tenant in-flight work (``max_inflight_per_tenant``).

Correctness is anchored by the bit-identical oracle tests in
``tests/test_memo.py``: a replayed window must produce exactly the bytes a
cold-lowered execution produces, on any node/device grid, reductions
included.

Replay protocol (id-renaming rules — DESIGN.md §12.3):

* every clone gets a fresh ``iid``; in-window dependency edges are remapped
  onto the clone counterparts, every out-of-window edge onto the tenant's
  *boundary* (the executed epoch of the previous window) — this serializes
  a tenant's windows, which is REQUIRED: clones share the template's
  ``Allocation`` objects ("same base addresses"), so window k+1's scratch
  ALLOC must not overtake window k's FREE;
* ``transfer_id`` tuples lead with a task id by convention — patched as
  ``(tid_map[t[0]],) + t[1:]`` with fresh global task ids, computed once
  per replay and shared by all nodes so sender and receiver agree;
* each SEND/COLL_SEND clone draws a fresh ``msg_id`` from its node's IDAG
  counter and re-posts the matching pilot with patched transfer/msg ids;
* the window epoch clone gets a fresh EPOCH ``Command`` (fresh cid) so
  ``wait_epoch`` has a unique completion token per replay;
* kernel/host closures are patched by task position, which is how
  per-request data (and ``gather`` collection closures) enter a replay.

A window is *replayable* only if its lowering reached an allocation steady
state: no persistent (buffer-backed) ALLOC/FREE, no SPILL/RELOAD, and every
scratch ALLOC balanced by an in-window FREE.  Capture waits for two
consecutive cold lowerings of the same signature with identical structural
digests (the lowering fixpoint), so warm-up windows that materialize
allocations are never cached.
"""

from __future__ import annotations

import copy
import dataclasses
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from . import allocation as _alloc_mod
from . import instructions as _instr_mod
from . import task_graph as _task_mod
from .allocation import device_memory
from .buffer import Accessor, VirtualBuffer
from .command_graph import Command, CommandGraphGenerator, CommandType
from .communicator import Communicator
from .executor import Executor
from .instruction_graph import IdagGenerator
from .instructions import (AccessorBinding, Instruction, InstructionType,
                           Pilot, ReductionBinding)
from .lookahead import LookaheadScheduler
from .observability import MetricsRegistry
from .reduction import Reduction
from .region import Box, Region, split_box
from .task_graph import DepKind, TaskGraph, TaskType
from .verify import ScheduleVerifier
from .tracing import Tracer


# -- window signatures -------------------------------------------------------

@dataclass(frozen=True)
class _Call:
    """One recorded ``submit`` — structure only, no graph work done yet."""
    name: str
    index_space: Box
    accessors: tuple                 # Accessor | Reduction descriptors
    kernel_fn: Optional[Callable]
    ttype: TaskType
    split_dims: tuple[int, ...]
    granularity: tuple[int, ...]


def _region_sig(region: Region) -> tuple:
    return tuple((b.min, b.max) for b in region.boxes)


def _accessor_sig(acc: Accessor, index_space: Box, chunks: list[Box],
                  subchunks: list[Box]) -> tuple:
    """Canonical accessor shape: buffer identity + the *evaluated* range
    mapper over the full index space, every node chunk and every device
    subchunk.  Evaluating (rather than hashing the mapper object) makes two
    submissions equal exactly when lowering cannot tell them apart."""
    buf = acc.buffer
    return (buf.bid, buf.shape, str(buf.dtype), acc.mode.value,
            _region_sig(acc.mapped_region(index_space)),
            tuple(_region_sig(acc.mapped_region(c)) for c in chunks),
            tuple(_region_sig(acc.mapped_region(c)) for c in subchunks))


def _reduction_sig(red: Reduction) -> tuple:
    buf = red.buffer
    return (buf.bid, buf.shape, str(buf.dtype), red.op.name,
            bool(red.op.combine_order_free), bool(red.include_current_value))


def window_signature(calls: Sequence[_Call], *, num_nodes: int,
                     devices_per_node: int, config: tuple,
                     budgets: Optional[dict[int, int]],
                     namespace: str) -> tuple:
    """Canonical shape signature of one submission window.

    Covers task structure, evaluated ranges/accessors, grid shape, reduction
    operators, memory budgets and the tenant namespace — and deliberately
    NOT the data (kernel closures), which is patched in at replay.  Any
    difference that could change the lowered instruction stream must change
    the signature; data that cannot, must not.
    """
    call_sigs = []
    for c in calls:
        chunks = split_box(c.index_space, num_nodes, c.split_dims,
                           c.granularity)
        subchunks = [s for ch in chunks
                     for s in split_box(ch, devices_per_node, c.split_dims,
                                        c.granularity)]
        accs = tuple(_accessor_sig(a, c.index_space, chunks, subchunks)
                     for a in c.accessors if isinstance(a, Accessor))
        reds = tuple(_reduction_sig(r)
                     for r in c.accessors if isinstance(r, Reduction))
        call_sigs.append((c.ttype.value, c.name,
                          (c.index_space.min, c.index_space.max),
                          c.split_dims, c.granularity, accs, reds))
    return (tuple(call_sigs), (num_nodes, devices_per_node) + config,
            tuple(sorted((budgets or {}).items())), namespace)


# -- cached windows ----------------------------------------------------------

_SEND_TYPES = (InstructionType.SEND, InstructionType.COLL_SEND)
_SYNC_TYPES = (InstructionType.HORIZON, InstructionType.EPOCH)


def _window_digest(node_instrs: list[list[Instruction]]) -> tuple:
    """Structural digest of one lowered window.

    Scratch allocation ids are canonicalized to first-appearance order
    within the window — scratch draws a fresh global ``aid`` on every
    lowering, which must not defeat the fixpoint.  PERSISTENT (buffer-
    backed) allocations keep their raw ``aid``: a replay freezes the
    window's version→physical bindings, so capture must only fire once
    those bindings repeat exactly.  Under write renaming (DESIGN.md §13)
    a buffer's physical ping-pongs through the free pool every window —
    structurally identical, semantically alternating — and the raw-aid
    digest keeps such windows from ever reaching a (false) fixpoint.
    """
    out = []
    for instrs in node_instrs:
        canon: dict[int, int] = {}

        def _key(a):
            if a is None:
                return None
            if a.bid is not None:
                return ("p", a.bid, a.aid)
            return ("s", canon.setdefault(a.aid, len(canon)))

        sig = []
        for i in instrs:
            reads, writes = _alloc_touches(i)
            # FREE names embed the raw aid — the allocation keys already
            # identify the allocation, so keep the digest name id-free
            name = "" if i.itype == InstructionType.FREE else i.name
            sig.append((i.itype.value, name, i.queue, i.dest,
                        tuple(_key(a) for a in reads),
                        tuple(_key(a) for a in writes)))
        out.append(tuple(sig))
    return tuple(out)


def _replayable(node_instrs: list[list[Instruction]]) -> Optional[str]:
    """Why this window may NOT be replayed (None = replayable).

    Persistent (buffer-backed) ALLOC/FREE or SPILL/RELOAD mean the
    allocation pattern has not reached steady state — replaying would
    re-materialize or tear down long-lived backings.  Scratch ALLOCs must
    be balanced by in-window FREEs so each replay's alloc/free pairs nest.
    """
    for instrs in node_instrs:
        open_scratch: set[int] = set()
        for i in instrs:
            if i.itype in (InstructionType.SPILL, InstructionType.RELOAD):
                return f"{i.itype.value} in window (budget pressure)"
            if i.itype == InstructionType.ALLOC:
                if i.allocation.bid is not None:
                    return f"persistent alloc of B{i.allocation.bid}"
                open_scratch.add(i.allocation.aid)
            elif i.itype == InstructionType.FREE:
                if i.allocation.bid is not None:
                    return f"persistent free of B{i.allocation.bid}"
                open_scratch.discard(i.allocation.aid)
        if open_scratch:
            return f"unbalanced scratch allocs {sorted(open_scratch)}"
    return None


def _alloc_touches(i: Instruction) -> tuple[list, list]:
    """(read, written) allocations of one instruction, by executor semantics.

    Feeds the cross-window hazard wiring of pipelined replay (DESIGN.md
    §13.4): persistent allocations shared by concurrently in-flight windows
    need explicit RAW/WAR/WAW edges between windows, since replay bypasses
    the MemoryManager's producer/reader maps entirely.

    Derived from :meth:`Instruction.accesses` (the structured access
    metadata the schedule sanitizer also analyzes), collapsed to
    allocation granularity, with two deliberate hazard-level deviations:
    ALLOC/FREE count as writers of their allocation (backing-store
    lifetime IS a hazard between windows), and AWAIT_RECEIVE counts as a
    writer of the landing allocation (the arbiter materializes payload
    bytes under it, so a concurrent window's reader must order behind it,
    not beside it).
    """
    T = InstructionType
    it = i.itype
    if it in (T.ALLOC, T.FREE):
        return [], [i.allocation]
    reads: list = []
    writes: list = []
    for a, _region, mode in i.accesses():
        if it is T.AWAIT_RECEIVE:
            writes.append(a)
        elif mode == "r":
            reads.append(a)
        elif mode == "w":
            writes.append(a)
        else:                       # "rw" / "red": read-modify-write
            reads.append(a)
            writes.append(a)

    def _dedup(lst: list) -> list:
        seen: set[int] = set()
        out = []
        for a in lst:
            if id(a) not in seen:
                seen.add(id(a))
                out.append(a)
        return out

    return _dedup(reads), _dedup(writes)


@dataclass
class _Template:
    """One captured, relocatable instruction window (the memo cache value).

    The template instructions are pristine: never submitted to an executor
    (state stays ``pending``, dependency lists intact).  Replay clones
    them, patching the parameter table; see the module docstring for the
    id-renaming rules.

    Pipelined replay (DESIGN.md §13.4) double-buffers the template's
    scratch allocations: replay ``u`` binds rename set ``u % depth`` —
    set 0 is the identity (the template's own scratch), higher sets are
    lazily cloned physicals with fresh ``aid``s — so consecutive replays
    never collide on scratch backing and can execute concurrently.
    """
    node_instrs: list[list[Instruction]]
    node_pilots: list[list[Pilot]]             # per node, this window's pilots
    epoch_idx: list[int]                        # per node: window-epoch index
    tids: tuple[int, ...]                       # distinct template task ids
    tid_to_call: dict[int, int]                 # template task id -> call pos
    scratch_allocs: dict[int, object] = field(default_factory=dict)
    rename_sets: list[dict] = field(default_factory=list)
    uses: int = 0                               # replay sequence (set rotation)
    replays: int = 0


@dataclass
class _CacheEntry:
    digest: Optional[tuple] = None
    template: Optional[_Template] = None
    unreplayable: Optional[str] = None          # sticky guard-failure reason


class WindowHandle:
    """Completion token of one submitted window (cold or replayed)."""

    def __init__(self, tenant: "Tenant", cids: list[Optional[int]],
                 cached: bool):
        self.tenant = tenant
        self.cached = cached                    # True = replayed from cache
        self._cids = cids
        self._done = False

    def wait(self, timeout: float = 60.0) -> None:
        if self._done:
            return
        for n, cid in enumerate(self._cids):
            if cid is None:
                continue
            ex = self.tenant.srv.executors[n]
            ex.wait_epoch(cid, timeout=timeout)
            # a serving process sees an unbounded epoch stream: drop the
            # completion token so executor epoch state stays bounded
            ex.forget_epoch(cid)
        self._done = True


class Tenant:
    """One client program: its own namespace, budgets, pipeline and cache.

    ``submit`` only records call structure; ``run`` closes the window,
    consults the memo cache, and either lowers cold (synchronously, on the
    calling thread — the scheduling work we are amortizing away) or replays
    the cached template.  All submission-side state is guarded by a
    per-tenant lock; different tenants submit fully concurrently.
    """

    def __init__(self, srv: "ServingRuntime", name: str,
                 memory_budgets: Optional[dict[int, int]] = None,
                 max_queued_windows: int = 8):
        self.srv = srv
        self.name = name
        self.memory_budgets = dict(memory_budgets or {})
        self._lock = threading.RLock()
        self.tdag = TaskGraph(horizon_step=srv.horizon_step,
                              fuse_reductions=srv.reduction_fusion)
        self.cdags = [CommandGraphGenerator(srv.num_nodes, retire_for=n,
                                            collectives=srv.collectives,
                                            allreduce=srv.reduction_allreduce)
                      for n in range(srv.num_nodes)]
        self.idags = [IdagGenerator(n, srv.devices_per_node, d2d=srv.d2d,
                                    retire=True,
                                    budgets=self.memory_budgets or None,
                                    metrics=srv.metrics_registry,
                                    namespace=name,
                                    buffer_owner=srv._buffer_owner,
                                    renaming=srv.renaming)
                      for n in range(srv.num_nodes)]
        self.lookaheads = [LookaheadScheduler(self.idags[n],
                                              enabled=srv.lookahead,
                                              retire_compiled=True,
                                              metrics=srv.metrics_registry)
                           for n in range(srv.num_nodes)]
        self._sent = 0                      # lifetime task indices broadcast
        self._calls: list[_Call] = []
        # memo cache in LRU order (satellite of DESIGN.md §13): bounded by
        # ``srv.memo_cache_max`` entries, least-recently-hit evicted first
        self._memo: OrderedDict[tuple, _CacheEntry] = OrderedDict()
        # the executed epoch instruction every out-of-window replay edge
        # remaps onto (starts at the bootstrap init epoch)
        self.last_boundary: list[Instruction] = []
        # pipelined replay state (DESIGN.md §13.4).  ``depth`` windows of
        # this tenant may be in flight at once; window ``m`` boundary-syncs
        # on epoch(m - depth) — the ring of the last ``depth`` window
        # epochs per node — instead of epoch(m - 1).
        self.depth = max(1, srv.max_inflight_windows)
        self._window_seq = 0
        self._ring: list[deque[Instruction]] = []
        # fence: after a cold (non-replay) window, the next ``depth``
        # replays serialize behind their immediate predecessor — cold
        # windows execute the template's own allocations outside the
        # hazard-table protocol, so the ring boundary alone cannot cover
        # them
        self._fence_left: list[int] = [0] * srv.num_nodes
        # per-node cross-window hazard table: persistent allocation id ->
        # last writer clone + reader clones of the last ``depth`` windows
        self._aid_last: list[dict[int, dict]] = [
            {} for _ in range(srv.num_nodes)]
        # pinned gather collection buffers: bid -> (ndarray, closure), so
        # repeated gathers replay the SAME closure instead of re-anchoring
        # a fresh one per call (ROADMAP serving follow-up)
        self._gather_pins: dict[int, tuple] = {}
        # submission-side backpressure: run() blocks on the window
        # ``max_queued_windows`` back, bounding blocked-instruction state
        # held inside the executors per tenant
        self._inflight: deque[WindowHandle] = deque()
        self.max_queued_windows = max_queued_windows
        self.lowered_windows = 0
        self.replayed_windows = 0
        # bootstrap: the IDAG's construction-time init epoch must execute
        for n in range(srv.num_nodes):
            boot = list(self.idags[n].instructions)
            for i in boot:
                i.tenant = name
            self.last_boundary.append(self.idags[n]._init_epoch)
            self._ring.append(deque([self.idags[n]._init_epoch],
                                    maxlen=self.depth))
            if srv.verifier is not None:
                srv.verifier.capture(n, boot)
            srv.executors[n].submit(boot)

    # -- client API --------------------------------------------------------
    def buffer(self, shape: Sequence[int], dtype=np.float64, *,
               name: str = "", init: Optional[np.ndarray] = None
               ) -> VirtualBuffer:
        buf = VirtualBuffer(shape=tuple(shape), dtype=np.dtype(dtype),
                            name=f"{self.name}/{name}" if name else "",
                            initial_value=init)
        if not name:
            buf.name = f"{self.name}/{buf.name}"
        self.srv._buffer_owner[buf.bid] = self.name
        return buf

    def submit(self, name: str, index_space, accessors: Sequence,
               kernel_fn: Callable | None = None, *,
               ttype: TaskType = TaskType.KERNEL,
               split_dims: Sequence[int] = (0,),
               granularity: Sequence[int] = (1,)) -> None:
        """Record one command group for the current window (no lowering)."""
        if not isinstance(index_space, Box):
            index_space = Box.full(tuple(index_space))
        with self._lock:
            self._calls.append(_Call(name, index_space, tuple(accessors),
                                     kernel_fn, ttype, tuple(split_dims),
                                     tuple(granularity)))

    def run(self, timeout: float = 60.0) -> WindowHandle:
        """Close the current window and submit it (cached or cold)."""
        with self._lock:
            calls, self._calls = self._calls, []
            while len(self._inflight) >= self.max_queued_windows:
                self._inflight.popleft().wait(timeout=timeout)
            handle = self._run_window(calls)
            self._inflight.append(handle)
            return handle

    def gather(self, buf: VirtualBuffer, timeout: float = 60.0) -> np.ndarray:
        """Assemble the buffer on the caller's side (itself memoizable).

        The collection target is a *pinned* per-buffer ndarray + closure,
        created once and replayed on every subsequent gather — so repeat
        gathers hit the memo cache with a byte-identical parameter table
        instead of re-anchoring a fresh closure per call.  The caller gets
        an independent copy of the pinned buffer.
        """
        from .buffer import read as read_acc
        from .range_mapper import one_to_one
        with self._lock:
            pin = self._gather_pins.get(buf.bid)
            if pin is None:
                out = np.empty(buf.shape, dtype=buf.dtype)
                lock = threading.Lock()

                def collect(chunk: Box, view, _out=out, _lock=lock) -> None:
                    data = view.get(chunk)
                    sl = tuple(slice(a, b)
                               for a, b in zip(chunk.min, chunk.max))
                    with _lock:
                        _out[sl] = data

                pin = self._gather_pins[buf.bid] = (out, collect)
            out, collect = pin
            self.submit(f"gather {buf.name}", buf.shape,
                        [read_acc(buf, one_to_one())], collect,
                        ttype=TaskType.HOST)
            self.run(timeout=timeout).wait(timeout=timeout)
            self.drain(timeout=timeout)
            return np.array(out, copy=True)

    def drain(self, timeout: float = 60.0) -> None:
        """Wait for every submitted window of this tenant to complete."""
        with self._lock:
            while self._inflight:
                self._inflight.popleft().wait(timeout=timeout)

    # -- window machinery --------------------------------------------------
    def _signature(self, calls: list[_Call]) -> tuple:
        return window_signature(calls, num_nodes=self.srv.num_nodes,
                                devices_per_node=self.srv.devices_per_node,
                                config=self.srv._config_sig,
                                budgets=self.memory_budgets,
                                namespace=self.name)

    def _run_window(self, calls: list[_Call]) -> WindowHandle:
        srv = self.srv
        m = srv.metrics_registry
        entry: Optional[_CacheEntry] = None
        if srv.memo:
            sig = self._signature(calls)
            entry = self._memo.get(sig)
            if entry is None:
                entry = self._memo[sig] = _CacheEntry()
                cap = srv.memo_cache_max
                if cap is not None:
                    while len(self._memo) > cap:
                        self._memo.popitem(last=False)
                        if m is not None:
                            m.counter("memo.evictions")
                            m.counter(f"serve.{self.name}.memo_evictions")
            else:
                self._memo.move_to_end(sig)
        if entry is not None and entry.template is not None:
            t0 = time.perf_counter()
            handle = self._replay(entry.template, calls)
            if m is not None:
                m.counter("memo.hits")
                m.counter(f"serve.{self.name}.hits")
                m.observe("memo.patch_us", (time.perf_counter() - t0) * 1e6)
            self.replayed_windows += 1
            entry.template.replays += 1
            return handle
        if m is not None and srv.memo:
            m.counter("memo.misses")
            m.counter(f"serve.{self.name}.misses")
        node_instrs, node_pilots, cids, tid_to_call = self._lower(calls)
        self.lowered_windows += 1
        if entry is not None and entry.unreplayable is None:
            digest = _window_digest(node_instrs)
            if entry.digest is not None and digest == entry.digest:
                # lowering fixpoint reached: two consecutive cold lowerings
                # of this signature were structurally identical — capture
                why = _replayable(node_instrs)
                if why is None:
                    entry.template = self._capture(node_instrs, node_pilots,
                                                   tid_to_call)
                    # the capturing lowering executes as a CLONE so the
                    # template instructions stay pristine
                    return self._replay(entry.template, calls, identity=True)
                entry.unreplayable = why
                if m is not None:
                    m.counter("memo.unreplayable")
            entry.digest = digest
        # cold path: execute the lowered window directly
        wseq = self._window_seq
        self._window_seq += 1
        for n in range(srv.num_nodes):
            self._submit_window(n, node_instrs[n], node_pilots[n], wseq)
        return WindowHandle(self, cids, cached=False)

    def _lower(self, calls: list[_Call]):
        """Cold TDAG→CDAG→IDAG lowering of one window, synchronously on the
        calling thread (the cost the memo cache amortizes away)."""
        srv, tdag = self.srv, self.tdag
        call_tasks = []
        for c in calls:
            call_tasks.append(tdag.submit(
                c.name, c.index_space, c.accessors, c.kernel_fn,
                ttype=c.ttype, split_dims=c.split_dims,
                granularity=c.granularity))
        epoch_task = tdag.emit_epoch("window")
        tid_to_call = {t.tid: pos for pos, t in enumerate(call_tasks)}
        N = srv.num_nodes
        node_instrs: list[list[Instruction]] = [[] for _ in range(N)]
        cids: list[Optional[int]] = [None] * N
        newly = tdag.tasks[self._sent - tdag._base:]
        for task in newly:
            self._sent += 1
            if task.ttype == TaskType.EPOCH and task.name == "init":
                continue
            for n in range(N):
                for cmd in self.cdags[n].process(task):
                    if cmd.node != n:
                        continue
                    if (cmd.ctype == CommandType.EPOCH
                            and task is epoch_task):
                        cids[n] = cmd.cid
                    node_instrs[n].extend(self.lookaheads[n].push(cmd))
        tdag.retire_to(self._sent)
        # the window ends in an epoch, so the lookahead flushed completely:
        # each IDAG's pilot list is exactly this window's pilots
        node_pilots: list[list[Pilot]] = []
        for n in range(N):
            pilots = self.idags[n].pilots
            node_pilots.append(list(pilots))
            del pilots[:]
        return node_instrs, node_pilots, cids, tid_to_call

    def _submit_window(self, n: int, instrs: list[Instruction],
                       pilots: list[Pilot], wseq: int) -> None:
        """Execute a cold-lowered window: rewire edges that point at never-
        executed template instructions onto the executed boundary, tag the
        tenant, post pilots, and advance the boundary.

        Under pipelined replay a cold window may run while up to ``depth``
        replayed windows are still in flight; its allocations live outside
        the hazard-table protocol, so it syncs on EVERY ring epoch and arms
        the fence that makes the next ``depth`` replays serialize behind
        their immediate predecessor (which transitively covers this window).
        """
        pipelined = self.depth > 1
        syncs = (list(self._ring[n]) if pipelined
                 else [self.last_boundary[n]])
        if pipelined:
            self._aid_last[n].clear()
            self._fence_left[n] = self.depth
        epoch_instr = None
        for i in instrs:
            i.tenant = self.name
            i.window = wseq
            if any(getattr(d, "_memo_template", False)
                   for d, _ in i.dependencies):
                i.dependencies = [(d, k) for d, k in i.dependencies
                                  if not getattr(d, "_memo_template", False)]
                for b in syncs:
                    i.add_dependency(b, _task_mod.DepKind.SYNC)
            if i.itype == InstructionType.EPOCH:
                epoch_instr = i
        for p in pilots:
            self.srv.comm.post_pilot(p)
        if epoch_instr is not None:
            self.last_boundary[n] = epoch_instr
            self._ring[n].append(epoch_instr)
        if self.srv.verifier is not None:
            self.srv.verifier.capture_pilots(pilots)
            span = self.srv.verifier.capture(n, instrs)
            self.srv.executors[n].submit(instrs)
            if self.srv.verifier.mode == "window":
                self.srv.verifier.verify_window(n, span)
            return
        self.srv.executors[n].submit(instrs)

    def _capture(self, node_instrs, node_pilots, tid_to_call) -> _Template:
        tids: list[int] = []
        seen: set[int] = set()
        epoch_idx: list[int] = []
        scratch: dict[int, object] = {}
        for instrs in node_instrs:
            e = -1
            for idx, i in enumerate(instrs):
                i._memo_template = True
                if i.itype == InstructionType.EPOCH:
                    e = idx
                elif (i.itype == InstructionType.ALLOC
                        and i.allocation.bid is None):
                    scratch[i.allocation.aid] = i.allocation
                t = i.transfer_id
                if t is not None and t[0] not in seen:
                    seen.add(t[0])
                    tids.append(t[0])
            epoch_idx.append(e)
        # stamp each instruction with the PERSISTENT allocations it touches
        # (scratch is template-private per rename set, so excluded) — drives
        # the cross-window hazard wiring of pipelined replay
        for instrs in node_instrs:
            for i in instrs:
                reads, writes = _alloc_touches(i)
                i._memo_reads = tuple(a.aid for a in reads
                                      if a is not None
                                      and a.aid not in scratch)
                i._memo_writes = tuple(a.aid for a in writes
                                       if a is not None
                                       and a.aid not in scratch)
        for pilots in node_pilots:
            for p in pilots:
                if p.transfer_id[0] not in seen:
                    seen.add(p.transfer_id[0])
                    tids.append(p.transfer_id[0])
        return _Template(node_instrs=node_instrs, node_pilots=node_pilots,
                         epoch_idx=epoch_idx, tids=tuple(tids),
                         tid_to_call=dict(tid_to_call),
                         scratch_allocs=scratch)

    def _rename_map(self, tpl: _Template, sidx: int) -> dict:
        """Rename set ``sidx`` of a template's scratch allocations.

        Set 0 is the identity (the template's own scratch objects); higher
        sets are lazily built clones with fresh ``aid``s, so two concurrent
        replays bound to different sets never alias scratch backing in the
        executor stores.  Sets are cached on the template and reused
        round-robin (``uses % depth``) — safe because the ring boundary
        guarantees the previous user of a set has fully completed.
        """
        while len(tpl.rename_sets) <= sidx:
            k = len(tpl.rename_sets)
            if k == 0:
                tpl.rename_sets.append({})
            else:
                m: dict[int, object] = {}
                for aid, a in tpl.scratch_allocs.items():
                    na = copy.copy(a)
                    na.aid = next(_alloc_mod._alloc_ids)
                    na.alloc_instr = None
                    na.hazards = []
                    m[aid] = na
                tpl.rename_sets.append(m)
        return tpl.rename_sets[sidx]

    @staticmethod
    def _remap_clone(c: Instruction, amap: dict) -> None:
        """Point one clone's allocation references at a rename set."""
        for f in ("allocation", "src_alloc", "dst_alloc", "recv_alloc"):
            a = getattr(c, f)
            if a is not None and a.aid in amap:
                setattr(c, f, amap[a.aid])
        if c.reduce_srcs:
            c.reduce_srcs = tuple(amap.get(a.aid, a) for a in c.reduce_srcs)
        if c.coll_allocs:
            c.coll_allocs = tuple(amap.get(a.aid, a) for a in c.coll_allocs)
        if c.coll_frags:
            c.coll_frags = tuple(
                dataclasses.replace(f, alloc=amap[f.alloc.aid])
                if f.alloc.aid in amap else f
                for f in c.coll_frags)
        if c.coll_land:
            c.coll_land = tuple(
                dataclasses.replace(f, alloc=amap[f.alloc.aid])
                if f.alloc.aid in amap else f
                for f in c.coll_land)
        if c.bindings:
            c.bindings = tuple(
                AccessorBinding(b.accessor, amap[b.allocation.aid], b.region)
                if b.allocation.aid in amap else b
                for b in c.bindings)
        if c.red_bindings:
            c.red_bindings = tuple(
                ReductionBinding(rb.reduction, amap[rb.allocation.aid])
                if rb.allocation.aid in amap else rb
                for rb in c.red_bindings)

    def _replay(self, tpl: _Template, calls: list[_Call], *,
                identity: bool = False) -> WindowHandle:
        """Instantiate a cached window: clone + patch + submit.

        ``identity=True`` is the capture submission itself: the very
        lowering that produced the template still has to execute once, with
        its original ids (its pilots and transfer ids are already the
        template's) — so the parameter table maps every id to itself.

        Pipelined replay (``depth > 1``, DESIGN.md §13.4): instead of
        serializing behind the previous window's epoch, a replay boundary-
        syncs on the OLDEST ring epoch (window ``m`` waits for window
        ``m - depth``), binds rename set ``uses % depth`` for scratch, and
        wires precise RAW/WAR/WAW edges against the last writer/readers of
        each persistent allocation, so only truly conflicting instructions
        of overlapping windows serialize.
        """
        srv = self.srv
        N = srv.num_nodes
        pipelined = self.depth > 1
        # one tid map for the whole replay: sender and receiver nodes must
        # agree on the patched transfer ids
        if identity:
            tid_map = {t: t for t in tpl.tids}
        else:
            tid_map = {t: next(_task_mod._task_ids) for t in tpl.tids}
        # identity replay must keep the template's own allocation objects
        # (its ALLOCs carry them), so it always binds the identity set 0
        sidx = 0 if (identity or not pipelined) else tpl.uses % self.depth
        amap = self._rename_map(tpl, sidx) if pipelined else {}
        tpl.uses += 1
        wseq = self._window_seq
        self._window_seq += 1
        cids: list[Optional[int]] = [None] * N
        for n in range(N):
            idag = self.idags[n]
            clones: dict[int, Instruction] = {}
            out: list[Instruction] = []
            msg_map: dict[int, int] = {}
            if not pipelined or identity or self._fence_left[n] > 0:
                # fenced (or unpipelined): serialize behind the immediate
                # predecessor window, which transitively covers everything
                boundary = self.last_boundary[n]
                if pipelined and not identity and self._fence_left[n] > 0:
                    self._fence_left[n] -= 1
            else:
                boundary = self._ring[n][0]
            aid_tab = self._aid_last[n]
            written_this: set[int] = set()
            new_readers: dict[int, list[Instruction]] = {}
            new_writer: dict[int, Instruction] = {}
            for i in tpl.node_instrs[n]:
                c = copy.copy(i)
                c.iid = next(_instr_mod._instr_ids)
                c.dependencies = []
                c.dependents = []
                c.state = "pending"
                c.tenant = self.name
                c.window = wseq
                c._memo_template = False
                if c.transfer_id is not None:
                    t = c.transfer_id
                    c.transfer_id = (tid_map[t[0]],) + t[1:]
                if c.msg_id is not None:
                    nm = c.msg_id if identity else next(idag._msg_ids)
                    msg_map[i.msg_id] = nm
                    c.msg_id = nm
                if c.split_parent is not None:
                    c.split_parent = clones[c.split_parent.iid]
                if (not identity and c.itype == InstructionType.EPOCH
                        and c.command is not None):
                    c.command = Command(CommandType.EPOCH, node=n, task=None)
                if (c.itype in (InstructionType.DEVICE_KERNEL,
                                InstructionType.HOST_TASK)
                        and c.command is not None
                        and c.command.task is not None):
                    pos = tpl.tid_to_call.get(c.command.task.tid)
                    if pos is not None and pos < len(calls):
                        c.kernel_fn = calls[pos].kernel_fn
                if amap:
                    self._remap_clone(c, amap)
                needs_boundary = not i.dependencies
                for d, k in i.dependencies:
                    dc = clones.get(d.iid)
                    if dc is not None:
                        c.add_dependency(dc, k)
                    else:
                        needs_boundary = True
                if needs_boundary:
                    c.add_dependency(boundary, _task_mod.DepKind.SYNC)
                if pipelined and not identity:
                    # cross-window hazards on persistent allocations: RAW
                    # on the previous writer, WAW + WAR when first writing.
                    # Entries older than ``depth`` windows are covered by
                    # the ring boundary and skipped.
                    cut = wseq - self.depth
                    for aid in getattr(i, "_memo_reads", ()):
                        if aid not in written_this:
                            ent = aid_tab.get(aid)
                            if (ent and ent["w"] is not None
                                    and ent["w"][0] > cut):
                                c.add_dependency(ent["w"][1], DepKind.TRUE)
                        new_readers.setdefault(aid, []).append(c)
                    for aid in getattr(i, "_memo_writes", ()):
                        if aid not in written_this:
                            ent = aid_tab.get(aid)
                            if ent:
                                if (ent["w"] is not None
                                        and ent["w"][0] > cut):
                                    c.add_dependency(ent["w"][1],
                                                     DepKind.OUTPUT)
                                for rs, r in ent["r"]:
                                    if rs > cut:
                                        c.add_dependency(r, DepKind.ANTI)
                            written_this.add(aid)
                        new_writer[aid] = c
                clones[i.iid] = c
                out.append(c)
            e = tpl.epoch_idx[n]
            if e >= 0:
                epoch_clone = clones[tpl.node_instrs[n][e].iid]
                cids[n] = (epoch_clone.command.cid
                           if epoch_clone.command is not None else None)
                self.last_boundary[n] = epoch_clone
                self._ring[n].append(epoch_clone)
            if pipelined and not identity:
                cutoff = wseq - self.depth
                for aid in set(new_readers) | set(new_writer):
                    ent = aid_tab.setdefault(aid, {"w": None, "r": []})
                    if aid in new_writer:
                        ent["w"] = (wseq, new_writer[aid])
                        ent["r"] = [(wseq, r)
                                    for r in new_readers.get(aid, [])]
                    else:
                        ent["r"] = [x for x in ent["r"] if x[0] > cutoff]
                        ent["r"] += [(wseq, r)
                                     for r in new_readers.get(aid, [])]
            new_pilots = []
            for p in tpl.node_pilots[n]:
                t = p.transfer_id
                new_pilots.append(Pilot(
                    source=p.source, target=p.target,
                    transfer_id=(tid_map[t[0]],) + t[1:], box=p.box,
                    msg_id=msg_map.get(p.msg_id, p.msg_id), gather=p.gather))
            for p in new_pilots:
                srv.comm.post_pilot(p)
            if srv.verifier is not None:
                srv.verifier.capture_pilots(new_pilots)
                span = srv.verifier.capture(n, out)
                srv.executors[n].submit(out)
                if srv.verifier.mode == "window":
                    srv.verifier.verify_window(n, span)
            else:
                srv.executors[n].submit(out)
        return WindowHandle(self, cids, cached=not identity)


class ServingRuntime:
    """Long-lived multi-tenant runtime with schedule memoization.

    One communicator + per-node executor grid shared by every tenant; the
    per-program scheduler layers (TDAG/CDAG/IDAG/lookahead) are per-tenant
    and run synchronously on the submitting client thread — on a memo-cache
    hit they are not run at all.
    """

    def __init__(self, num_nodes: int = 1, devices_per_node: int = 1, *,
                 memo: bool = True, lookahead: bool = True, d2d: bool = True,
                 collectives: bool = True, reduction_fusion: bool = True,
                 reduction_allreduce: bool = True, horizon_step: int = 4,
                 queues_per_device: int = 2, host_threads: int = 4,
                 max_inflight_per_tenant: Optional[int] = None,
                 max_inflight_windows: int = 1,
                 memo_cache_max: Optional[int] = None,
                 renaming: bool = False,
                 metrics: bool = True, trace: bool = False,
                 record_sample: int = 1, reliable: bool = True,
                 verify: str = "off"):
        self.num_nodes = num_nodes
        self.devices_per_node = devices_per_node
        self.memo = memo
        self.lookahead = lookahead
        self.d2d = d2d
        self.collectives = collectives
        self.reduction_fusion = reduction_fusion and collectives
        self.reduction_allreduce = reduction_allreduce and collectives
        self.horizon_step = horizon_step
        # DESIGN.md §13: how many replayed windows of one tenant may be in
        # flight concurrently (1 = serialized, the pre-renaming behavior)
        self.max_inflight_windows = max(1, max_inflight_windows)
        # memo-template LRU cap per tenant (None = unbounded)
        self.memo_cache_max = memo_cache_max
        self.renaming = renaming
        self.tracer = Tracer(record_sample=record_sample) if trace else None
        self.metrics_registry = MetricsRegistry() if metrics else None
        # grid-shape part of every window signature: anything here that
        # changes lowering output MUST invalidate cached windows
        self._config_sig = (d2d, self.collectives, self.reduction_fusion,
                            self.reduction_allreduce, horizon_step, lookahead,
                            renaming)
        self._buffer_owner: dict[int, str] = {}
        # schedule sanitizer (DESIGN.md §14) over every submitted window —
        # including memo-replay clones and their cross-window re-anchored
        # edges, the first structural check that path has ever had.  No
        # budget model here: replay clones are not charged to a fresh
        # compile-time model, and budgets are per-tenant.
        if verify not in ("off", "final", "window"):
            raise ValueError(
                f"verify must be 'off', 'final' or 'window', got {verify!r}")
        self.verifier: Optional[ScheduleVerifier] = None
        if verify != "off":
            self.verifier = ScheduleVerifier(num_nodes, mode=verify,
                                             metrics=self.metrics_registry)
        self.comm = Communicator(num_nodes, reliable=reliable,
                                 tracer=self.tracer,
                                 metrics=self.metrics_registry)
        self.executors = [
            Executor(n, devices_per_node, self.comm,
                     queues_per_device=queues_per_device,
                     host_threads=host_threads, tracer=self.tracer,
                     metrics=self.metrics_registry,
                     max_inflight_per_tenant=max_inflight_per_tenant)
            for n in range(num_nodes)]
        self.tenants: dict[str, Tenant] = {}
        self._tenant_lock = threading.Lock()
        self._shut = False

    def tenant(self, name: str, *,
               memory_budgets: Optional[dict[int, int]] = None,
               device_memory_budget: Optional[int] = None,
               max_queued_windows: int = 8) -> Tenant:
        budgets = dict(memory_budgets or {})
        if device_memory_budget is not None:
            for d in range(self.devices_per_node):
                budgets.setdefault(device_memory(d), device_memory_budget)
        with self._tenant_lock:
            if name in self.tenants:
                raise ValueError(f"tenant '{name}' already exists")
            t = self.tenants[name] = Tenant(
                self, name, memory_budgets=budgets,
                max_queued_windows=max_queued_windows)
        return t

    # -- observability -----------------------------------------------------
    def memo_stats(self) -> dict:
        """Cache effectiveness + per-tenant window counters."""
        snap = (self.metrics_registry.snapshot()
                if self.metrics_registry is not None else
                dict(counters={}, histograms={}))
        counters = snap.get("counters", {})
        return dict(
            hits=counters.get("memo.hits", 0),
            misses=counters.get("memo.misses", 0),
            unreplayable=counters.get("memo.unreplayable", 0),
            evictions=counters.get("memo.evictions", 0),
            patch_us=snap.get("histograms", {}).get("memo.patch_us"),
            tenants={name: dict(lowered=t.lowered_windows,
                                replayed=t.replayed_windows,
                                tasks=t.tdag.task_count,
                                instructions=sum(g.emitted_count
                                                 for g in t.idags),
                                done={n: self.executors[n].tenant_done
                                          .get(name, 0)
                                      for n in range(self.num_nodes)},
                                window_peak={n: self.executors[n]
                                                 .tenant_window_peak
                                                 .get(name, 0)
                                             for n in range(self.num_nodes)})
                     for name, t in self.tenants.items()})

    def metrics(self) -> dict:
        snap = (self.metrics_registry.snapshot()
                if self.metrics_registry is not None
                else dict(counters={}, gauges={}, histograms={}))
        snap["memo"] = self.memo_stats()
        return snap

    def verify_now(self):
        """Finalize the schedule sanitizer over everything captured so far
        and raise :class:`~repro.core.verify.VerificationError` on issues.

        Call after the tenants of interest have drained, so every submitted
        window (cold, cached-replay, bootstrap) has been captured.
        """
        if self.verifier is None:
            raise RuntimeError("verify_now() needs ServingRuntime(verify=...)")
        report = self.verifier.finalize()
        self.verifier.check()
        return report

    # -- lifecycle ---------------------------------------------------------
    def shutdown(self) -> None:
        if self._shut:
            return
        self._shut = True
        for t in self.tenants.values():
            try:
                t.drain(timeout=30.0)
            except Exception:       # noqa: BLE001 — teardown is best-effort
                pass
        for ex in self.executors:
            ex.shutdown()
        if self.tracer is not None and self.metrics_registry is not None:
            self.metrics_registry.export_counters(self.tracer)

    def __enter__(self) -> "ServingRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
