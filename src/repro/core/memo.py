"""Schedule memoization + multi-tenant serving runtime (DESIGN.md §12).

The paper's thesis is that graph-based IRs move scheduling work off the
latency-sensitive critical path; a long-lived service handling millions of
near-identical requests takes that to its limit.  After the first few
submissions of a task-graph *shape*, TDAG→CDAG→IDAG lowering is pure
repeated work: this module caches the lowered instruction window, keyed by a
canonical shape signature, and **replays** it on subsequent submissions with
only the per-request parameters patched in — fresh instruction/epoch/
transfer ids and the new kernel closures.  Amortized scheduling cost per
request approaches the cost of one ``copy.copy`` per instruction.

Multi-tenancy is the second axis: a :class:`ServingRuntime` hosts many
concurrent client programs (*tenants*) over one communicator + executor
grid.  Each tenant owns a buffer namespace (cross-tenant buffer access is
rejected at lowering time by the MemoryManager ownership map), its own
``memory_budgets``, its own TDAG/CDAG/IDAG pipeline and its own memo cache.
Executors interleave ready instructions of different tenants round-robin
and bound per-tenant in-flight work (``max_inflight_per_tenant``).

Correctness is anchored by the bit-identical oracle tests in
``tests/test_memo.py``: a replayed window must produce exactly the bytes a
cold-lowered execution produces, on any node/device grid, reductions
included.

Replay protocol (id-renaming rules — DESIGN.md §12.3):

* every clone gets a fresh ``iid``; in-window dependency edges are remapped
  onto the clone counterparts, every out-of-window edge onto the tenant's
  *boundary* (the executed epoch of the previous window) — this serializes
  a tenant's windows, which is REQUIRED: clones share the template's
  ``Allocation`` objects ("same base addresses"), so window k+1's scratch
  ALLOC must not overtake window k's FREE;
* ``transfer_id`` tuples lead with a task id by convention — patched as
  ``(tid_map[t[0]],) + t[1:]`` with fresh global task ids, computed once
  per replay and shared by all nodes so sender and receiver agree;
* each SEND/COLL_SEND clone draws a fresh ``msg_id`` from its node's IDAG
  counter and re-posts the matching pilot with patched transfer/msg ids;
* the window epoch clone gets a fresh EPOCH ``Command`` (fresh cid) so
  ``wait_epoch`` has a unique completion token per replay;
* kernel/host closures are patched by task position, which is how
  per-request data (and ``gather`` collection closures) enter a replay.

A window is *replayable* only if its lowering reached an allocation steady
state: no persistent (buffer-backed) ALLOC/FREE, no SPILL/RELOAD, and every
scratch ALLOC balanced by an in-window FREE.  Capture waits for two
consecutive cold lowerings of the same signature with identical structural
digests (the lowering fixpoint), so warm-up windows that materialize
allocations are never cached.
"""

from __future__ import annotations

import copy
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from . import instructions as _instr_mod
from . import task_graph as _task_mod
from .allocation import device_memory
from .buffer import Accessor, VirtualBuffer
from .command_graph import Command, CommandGraphGenerator, CommandType
from .communicator import Communicator
from .executor import Executor
from .instruction_graph import IdagGenerator
from .instructions import Instruction, InstructionType, Pilot
from .lookahead import LookaheadScheduler
from .observability import MetricsRegistry
from .reduction import Reduction
from .region import Box, Region, split_box
from .task_graph import TaskGraph, TaskType
from .tracing import Tracer


# -- window signatures -------------------------------------------------------

@dataclass(frozen=True)
class _Call:
    """One recorded ``submit`` — structure only, no graph work done yet."""
    name: str
    index_space: Box
    accessors: tuple                 # Accessor | Reduction descriptors
    kernel_fn: Optional[Callable]
    ttype: TaskType
    split_dims: tuple[int, ...]
    granularity: tuple[int, ...]


def _region_sig(region: Region) -> tuple:
    return tuple((b.min, b.max) for b in region.boxes)


def _accessor_sig(acc: Accessor, index_space: Box, chunks: list[Box],
                  subchunks: list[Box]) -> tuple:
    """Canonical accessor shape: buffer identity + the *evaluated* range
    mapper over the full index space, every node chunk and every device
    subchunk.  Evaluating (rather than hashing the mapper object) makes two
    submissions equal exactly when lowering cannot tell them apart."""
    buf = acc.buffer
    return (buf.bid, buf.shape, str(buf.dtype), acc.mode.value,
            _region_sig(acc.mapped_region(index_space)),
            tuple(_region_sig(acc.mapped_region(c)) for c in chunks),
            tuple(_region_sig(acc.mapped_region(c)) for c in subchunks))


def _reduction_sig(red: Reduction) -> tuple:
    buf = red.buffer
    return (buf.bid, buf.shape, str(buf.dtype), red.op.name,
            bool(red.op.combine_order_free), bool(red.include_current_value))


def window_signature(calls: Sequence[_Call], *, num_nodes: int,
                     devices_per_node: int, config: tuple,
                     budgets: Optional[dict[int, int]],
                     namespace: str) -> tuple:
    """Canonical shape signature of one submission window.

    Covers task structure, evaluated ranges/accessors, grid shape, reduction
    operators, memory budgets and the tenant namespace — and deliberately
    NOT the data (kernel closures), which is patched in at replay.  Any
    difference that could change the lowered instruction stream must change
    the signature; data that cannot, must not.
    """
    call_sigs = []
    for c in calls:
        chunks = split_box(c.index_space, num_nodes, c.split_dims,
                           c.granularity)
        subchunks = [s for ch in chunks
                     for s in split_box(ch, devices_per_node, c.split_dims,
                                        c.granularity)]
        accs = tuple(_accessor_sig(a, c.index_space, chunks, subchunks)
                     for a in c.accessors if isinstance(a, Accessor))
        reds = tuple(_reduction_sig(r)
                     for r in c.accessors if isinstance(r, Reduction))
        call_sigs.append((c.ttype.value, c.name,
                          (c.index_space.min, c.index_space.max),
                          c.split_dims, c.granularity, accs, reds))
    return (tuple(call_sigs), (num_nodes, devices_per_node) + config,
            tuple(sorted((budgets or {}).items())), namespace)


# -- cached windows ----------------------------------------------------------

_SEND_TYPES = (InstructionType.SEND, InstructionType.COLL_SEND)
_SYNC_TYPES = (InstructionType.HORIZON, InstructionType.EPOCH)


def _window_digest(node_instrs: list[list[Instruction]]) -> tuple:
    """Structural digest of one lowered window.

    Id-free: two lowerings of the same shape at the allocation fixpoint
    digest identically.  Allocation ids are canonicalized to first-
    appearance order within the window — scratch allocations draw a fresh
    global ``aid`` on every lowering, which must not defeat the fixpoint.
    """
    out = []
    for instrs in node_instrs:
        canon: dict[int, int] = {}
        sig = []
        for i in instrs:
            a = i.allocation
            aid = (None if a is None
                   else (a.bid, canon.setdefault(a.aid, len(canon))))
            # FREE names embed the raw aid — the canonical tuple already
            # identifies the allocation, so keep the digest id-free
            name = "" if i.itype == InstructionType.FREE else i.name
            sig.append((i.itype.value, name, i.queue, i.dest, aid))
        out.append(tuple(sig))
    return tuple(out)


def _replayable(node_instrs: list[list[Instruction]]) -> Optional[str]:
    """Why this window may NOT be replayed (None = replayable).

    Persistent (buffer-backed) ALLOC/FREE or SPILL/RELOAD mean the
    allocation pattern has not reached steady state — replaying would
    re-materialize or tear down long-lived backings.  Scratch ALLOCs must
    be balanced by in-window FREEs so each replay's alloc/free pairs nest.
    """
    for instrs in node_instrs:
        open_scratch: set[int] = set()
        for i in instrs:
            if i.itype in (InstructionType.SPILL, InstructionType.RELOAD):
                return f"{i.itype.value} in window (budget pressure)"
            if i.itype == InstructionType.ALLOC:
                if i.allocation.bid is not None:
                    return f"persistent alloc of B{i.allocation.bid}"
                open_scratch.add(i.allocation.aid)
            elif i.itype == InstructionType.FREE:
                if i.allocation.bid is not None:
                    return f"persistent free of B{i.allocation.bid}"
                open_scratch.discard(i.allocation.aid)
        if open_scratch:
            return f"unbalanced scratch allocs {sorted(open_scratch)}"
    return None


@dataclass
class _Template:
    """One captured, relocatable instruction window (the memo cache value).

    The template instructions are pristine: never submitted to an executor
    (state stays ``pending``, dependency lists intact).  Replay clones
    them, patching the parameter table; see the module docstring for the
    id-renaming rules.
    """
    node_instrs: list[list[Instruction]]
    node_pilots: list[list[Pilot]]             # per node, this window's pilots
    epoch_idx: list[int]                        # per node: window-epoch index
    tids: tuple[int, ...]                       # distinct template task ids
    tid_to_call: dict[int, int]                 # template task id -> call pos
    replays: int = 0


@dataclass
class _CacheEntry:
    digest: Optional[tuple] = None
    template: Optional[_Template] = None
    unreplayable: Optional[str] = None          # sticky guard-failure reason


class WindowHandle:
    """Completion token of one submitted window (cold or replayed)."""

    def __init__(self, tenant: "Tenant", cids: list[Optional[int]],
                 cached: bool):
        self.tenant = tenant
        self.cached = cached                    # True = replayed from cache
        self._cids = cids
        self._done = False

    def wait(self, timeout: float = 60.0) -> None:
        if self._done:
            return
        for n, cid in enumerate(self._cids):
            if cid is None:
                continue
            ex = self.tenant.srv.executors[n]
            ex.wait_epoch(cid, timeout=timeout)
            # a serving process sees an unbounded epoch stream: drop the
            # completion token so executor epoch state stays bounded
            ex.forget_epoch(cid)
        self._done = True


class Tenant:
    """One client program: its own namespace, budgets, pipeline and cache.

    ``submit`` only records call structure; ``run`` closes the window,
    consults the memo cache, and either lowers cold (synchronously, on the
    calling thread — the scheduling work we are amortizing away) or replays
    the cached template.  All submission-side state is guarded by a
    per-tenant lock; different tenants submit fully concurrently.
    """

    def __init__(self, srv: "ServingRuntime", name: str,
                 memory_budgets: Optional[dict[int, int]] = None,
                 max_queued_windows: int = 8):
        self.srv = srv
        self.name = name
        self.memory_budgets = dict(memory_budgets or {})
        self._lock = threading.RLock()
        self.tdag = TaskGraph(horizon_step=srv.horizon_step,
                              fuse_reductions=srv.reduction_fusion)
        self.cdags = [CommandGraphGenerator(srv.num_nodes, retire_for=n,
                                            collectives=srv.collectives,
                                            allreduce=srv.reduction_allreduce)
                      for n in range(srv.num_nodes)]
        self.idags = [IdagGenerator(n, srv.devices_per_node, d2d=srv.d2d,
                                    retire=True,
                                    budgets=self.memory_budgets or None,
                                    metrics=srv.metrics_registry,
                                    namespace=name,
                                    buffer_owner=srv._buffer_owner)
                      for n in range(srv.num_nodes)]
        self.lookaheads = [LookaheadScheduler(self.idags[n],
                                              enabled=srv.lookahead,
                                              retire_compiled=True,
                                              metrics=srv.metrics_registry)
                           for n in range(srv.num_nodes)]
        self._sent = 0                      # lifetime task indices broadcast
        self._calls: list[_Call] = []
        self._memo: dict[tuple, _CacheEntry] = {}
        # the executed epoch instruction every out-of-window replay edge
        # remaps onto (starts at the bootstrap init epoch)
        self.last_boundary: list[Instruction] = []
        # submission-side backpressure: run() blocks on the window
        # ``max_queued_windows`` back, bounding blocked-instruction state
        # held inside the executors per tenant
        self._inflight: deque[WindowHandle] = deque()
        self.max_queued_windows = max_queued_windows
        self.lowered_windows = 0
        self.replayed_windows = 0
        # bootstrap: the IDAG's construction-time init epoch must execute
        for n in range(srv.num_nodes):
            boot = list(self.idags[n].instructions)
            for i in boot:
                i.tenant = name
            self.last_boundary.append(self.idags[n]._init_epoch)
            srv.executors[n].submit(boot)

    # -- client API --------------------------------------------------------
    def buffer(self, shape: Sequence[int], dtype=np.float64, *,
               name: str = "", init: Optional[np.ndarray] = None
               ) -> VirtualBuffer:
        buf = VirtualBuffer(shape=tuple(shape), dtype=np.dtype(dtype),
                            name=f"{self.name}/{name}" if name else "",
                            initial_value=init)
        if not name:
            buf.name = f"{self.name}/{buf.name}"
        self.srv._buffer_owner[buf.bid] = self.name
        return buf

    def submit(self, name: str, index_space, accessors: Sequence,
               kernel_fn: Callable | None = None, *,
               ttype: TaskType = TaskType.KERNEL,
               split_dims: Sequence[int] = (0,),
               granularity: Sequence[int] = (1,)) -> None:
        """Record one command group for the current window (no lowering)."""
        if not isinstance(index_space, Box):
            index_space = Box.full(tuple(index_space))
        with self._lock:
            self._calls.append(_Call(name, index_space, tuple(accessors),
                                     kernel_fn, ttype, tuple(split_dims),
                                     tuple(granularity)))

    def run(self, timeout: float = 60.0) -> WindowHandle:
        """Close the current window and submit it (cached or cold)."""
        with self._lock:
            calls, self._calls = self._calls, []
            while len(self._inflight) >= self.max_queued_windows:
                self._inflight.popleft().wait(timeout=timeout)
            handle = self._run_window(calls)
            self._inflight.append(handle)
            return handle

    def gather(self, buf: VirtualBuffer, timeout: float = 60.0) -> np.ndarray:
        """Assemble the buffer on the caller's side (itself memoizable:
        replays patch in the fresh collection closure)."""
        from .buffer import read as read_acc
        from .range_mapper import one_to_one
        out = np.empty(buf.shape, dtype=buf.dtype)
        lock = threading.Lock()

        def collect(chunk: Box, view) -> None:
            data = view.get(chunk)
            sl = tuple(slice(a, b) for a, b in zip(chunk.min, chunk.max))
            with lock:
                out[sl] = data

        with self._lock:
            self.submit(f"gather {buf.name}", buf.shape,
                        [read_acc(buf, one_to_one())], collect,
                        ttype=TaskType.HOST)
            self.run(timeout=timeout).wait(timeout=timeout)
            self.drain(timeout=timeout)
        return out

    def drain(self, timeout: float = 60.0) -> None:
        """Wait for every submitted window of this tenant to complete."""
        with self._lock:
            while self._inflight:
                self._inflight.popleft().wait(timeout=timeout)

    # -- window machinery --------------------------------------------------
    def _signature(self, calls: list[_Call]) -> tuple:
        return window_signature(calls, num_nodes=self.srv.num_nodes,
                                devices_per_node=self.srv.devices_per_node,
                                config=self.srv._config_sig,
                                budgets=self.memory_budgets,
                                namespace=self.name)

    def _run_window(self, calls: list[_Call]) -> WindowHandle:
        srv = self.srv
        m = srv.metrics_registry
        entry: Optional[_CacheEntry] = None
        if srv.memo:
            sig = self._signature(calls)
            entry = self._memo.get(sig)
            if entry is None:
                entry = self._memo[sig] = _CacheEntry()
        if entry is not None and entry.template is not None:
            t0 = time.perf_counter()
            handle = self._replay(entry.template, calls)
            if m is not None:
                m.counter("memo.hits")
                m.counter(f"serve.{self.name}.hits")
                m.observe("memo.patch_us", (time.perf_counter() - t0) * 1e6)
            self.replayed_windows += 1
            entry.template.replays += 1
            return handle
        if m is not None and srv.memo:
            m.counter("memo.misses")
            m.counter(f"serve.{self.name}.misses")
        node_instrs, node_pilots, cids, tid_to_call = self._lower(calls)
        self.lowered_windows += 1
        if entry is not None and entry.unreplayable is None:
            digest = _window_digest(node_instrs)
            if entry.digest is not None and digest == entry.digest:
                # lowering fixpoint reached: two consecutive cold lowerings
                # of this signature were structurally identical — capture
                why = _replayable(node_instrs)
                if why is None:
                    entry.template = self._capture(node_instrs, node_pilots,
                                                   tid_to_call)
                    # the capturing lowering executes as a CLONE so the
                    # template instructions stay pristine
                    return self._replay(entry.template, calls, identity=True)
                entry.unreplayable = why
                if m is not None:
                    m.counter("memo.unreplayable")
            entry.digest = digest
        # cold path: execute the lowered window directly
        for n in range(srv.num_nodes):
            self._submit_window(n, node_instrs[n], node_pilots[n])
        return WindowHandle(self, cids, cached=False)

    def _lower(self, calls: list[_Call]):
        """Cold TDAG→CDAG→IDAG lowering of one window, synchronously on the
        calling thread (the cost the memo cache amortizes away)."""
        srv, tdag = self.srv, self.tdag
        call_tasks = []
        for c in calls:
            call_tasks.append(tdag.submit(
                c.name, c.index_space, c.accessors, c.kernel_fn,
                ttype=c.ttype, split_dims=c.split_dims,
                granularity=c.granularity))
        epoch_task = tdag.emit_epoch("window")
        tid_to_call = {t.tid: pos for pos, t in enumerate(call_tasks)}
        N = srv.num_nodes
        node_instrs: list[list[Instruction]] = [[] for _ in range(N)]
        cids: list[Optional[int]] = [None] * N
        newly = tdag.tasks[self._sent - tdag._base:]
        for task in newly:
            self._sent += 1
            if task.ttype == TaskType.EPOCH and task.name == "init":
                continue
            for n in range(N):
                for cmd in self.cdags[n].process(task):
                    if cmd.node != n:
                        continue
                    if (cmd.ctype == CommandType.EPOCH
                            and task is epoch_task):
                        cids[n] = cmd.cid
                    node_instrs[n].extend(self.lookaheads[n].push(cmd))
        tdag.retire_to(self._sent)
        # the window ends in an epoch, so the lookahead flushed completely:
        # each IDAG's pilot list is exactly this window's pilots
        node_pilots: list[list[Pilot]] = []
        for n in range(N):
            pilots = self.idags[n].pilots
            node_pilots.append(list(pilots))
            del pilots[:]
        return node_instrs, node_pilots, cids, tid_to_call

    def _submit_window(self, n: int, instrs: list[Instruction],
                       pilots: list[Pilot]) -> None:
        """Execute a cold-lowered window: rewire edges that point at never-
        executed template instructions onto the executed boundary, tag the
        tenant, post pilots, and advance the boundary."""
        boundary = self.last_boundary[n]
        epoch_instr = None
        for i in instrs:
            i.tenant = self.name
            if any(getattr(d, "_memo_template", False)
                   for d, _ in i.dependencies):
                i.dependencies = [(d, k) for d, k in i.dependencies
                                  if not getattr(d, "_memo_template", False)]
                i.add_dependency(boundary, _task_mod.DepKind.SYNC)
            if i.itype == InstructionType.EPOCH:
                epoch_instr = i
        for p in pilots:
            self.srv.comm.post_pilot(p)
        if epoch_instr is not None:
            self.last_boundary[n] = epoch_instr
        self.srv.executors[n].submit(instrs)

    def _capture(self, node_instrs, node_pilots, tid_to_call) -> _Template:
        tids: list[int] = []
        seen: set[int] = set()
        epoch_idx: list[int] = []
        for instrs in node_instrs:
            e = -1
            for idx, i in enumerate(instrs):
                i._memo_template = True
                if i.itype == InstructionType.EPOCH:
                    e = idx
                t = i.transfer_id
                if t is not None and t[0] not in seen:
                    seen.add(t[0])
                    tids.append(t[0])
            epoch_idx.append(e)
        for pilots in node_pilots:
            for p in pilots:
                if p.transfer_id[0] not in seen:
                    seen.add(p.transfer_id[0])
                    tids.append(p.transfer_id[0])
        return _Template(node_instrs=node_instrs, node_pilots=node_pilots,
                         epoch_idx=epoch_idx, tids=tuple(tids),
                         tid_to_call=dict(tid_to_call))

    def _replay(self, tpl: _Template, calls: list[_Call], *,
                identity: bool = False) -> WindowHandle:
        """Instantiate a cached window: clone + patch + submit.

        ``identity=True`` is the capture submission itself: the very
        lowering that produced the template still has to execute once, with
        its original ids (its pilots and transfer ids are already the
        template's) — so the parameter table maps every id to itself.
        """
        srv = self.srv
        N = srv.num_nodes
        # one tid map for the whole replay: sender and receiver nodes must
        # agree on the patched transfer ids
        if identity:
            tid_map = {t: t for t in tpl.tids}
        else:
            tid_map = {t: next(_task_mod._task_ids) for t in tpl.tids}
        cids: list[Optional[int]] = [None] * N
        for n in range(N):
            idag = self.idags[n]
            clones: dict[int, Instruction] = {}
            out: list[Instruction] = []
            msg_map: dict[int, int] = {}
            boundary = self.last_boundary[n]
            for i in tpl.node_instrs[n]:
                c = copy.copy(i)
                c.iid = next(_instr_mod._instr_ids)
                c.dependencies = []
                c.dependents = []
                c.state = "pending"
                c.tenant = self.name
                c._memo_template = False
                if c.transfer_id is not None:
                    t = c.transfer_id
                    c.transfer_id = (tid_map[t[0]],) + t[1:]
                if c.msg_id is not None:
                    nm = c.msg_id if identity else next(idag._msg_ids)
                    msg_map[i.msg_id] = nm
                    c.msg_id = nm
                if c.split_parent is not None:
                    c.split_parent = clones[c.split_parent.iid]
                if (not identity and c.itype == InstructionType.EPOCH
                        and c.command is not None):
                    c.command = Command(CommandType.EPOCH, node=n, task=None)
                if (c.itype in (InstructionType.DEVICE_KERNEL,
                                InstructionType.HOST_TASK)
                        and c.command is not None
                        and c.command.task is not None):
                    pos = tpl.tid_to_call.get(c.command.task.tid)
                    if pos is not None and pos < len(calls):
                        c.kernel_fn = calls[pos].kernel_fn
                needs_boundary = not i.dependencies
                for d, k in i.dependencies:
                    dc = clones.get(d.iid)
                    if dc is not None:
                        c.add_dependency(dc, k)
                    else:
                        needs_boundary = True
                if needs_boundary:
                    c.add_dependency(boundary, _task_mod.DepKind.SYNC)
                clones[i.iid] = c
                out.append(c)
            e = tpl.epoch_idx[n]
            if e >= 0:
                epoch_clone = clones[tpl.node_instrs[n][e].iid]
                cids[n] = (epoch_clone.command.cid
                           if epoch_clone.command is not None else None)
                self.last_boundary[n] = epoch_clone
            for p in tpl.node_pilots[n]:
                t = p.transfer_id
                srv.comm.post_pilot(Pilot(
                    source=p.source, target=p.target,
                    transfer_id=(tid_map[t[0]],) + t[1:], box=p.box,
                    msg_id=msg_map.get(p.msg_id, p.msg_id), gather=p.gather))
            srv.executors[n].submit(out)
        return WindowHandle(self, cids, cached=not identity)


class ServingRuntime:
    """Long-lived multi-tenant runtime with schedule memoization.

    One communicator + per-node executor grid shared by every tenant; the
    per-program scheduler layers (TDAG/CDAG/IDAG/lookahead) are per-tenant
    and run synchronously on the submitting client thread — on a memo-cache
    hit they are not run at all.
    """

    def __init__(self, num_nodes: int = 1, devices_per_node: int = 1, *,
                 memo: bool = True, lookahead: bool = True, d2d: bool = True,
                 collectives: bool = True, reduction_fusion: bool = True,
                 reduction_allreduce: bool = True, horizon_step: int = 4,
                 queues_per_device: int = 2, host_threads: int = 4,
                 max_inflight_per_tenant: Optional[int] = None,
                 metrics: bool = True, trace: bool = False,
                 record_sample: int = 1, reliable: bool = True):
        self.num_nodes = num_nodes
        self.devices_per_node = devices_per_node
        self.memo = memo
        self.lookahead = lookahead
        self.d2d = d2d
        self.collectives = collectives
        self.reduction_fusion = reduction_fusion and collectives
        self.reduction_allreduce = reduction_allreduce and collectives
        self.horizon_step = horizon_step
        self.tracer = Tracer(record_sample=record_sample) if trace else None
        self.metrics_registry = MetricsRegistry() if metrics else None
        # grid-shape part of every window signature: anything here that
        # changes lowering output MUST invalidate cached windows
        self._config_sig = (d2d, self.collectives, self.reduction_fusion,
                            self.reduction_allreduce, horizon_step, lookahead)
        self._buffer_owner: dict[int, str] = {}
        self.comm = Communicator(num_nodes, reliable=reliable,
                                 tracer=self.tracer,
                                 metrics=self.metrics_registry)
        self.executors = [
            Executor(n, devices_per_node, self.comm,
                     queues_per_device=queues_per_device,
                     host_threads=host_threads, tracer=self.tracer,
                     metrics=self.metrics_registry,
                     max_inflight_per_tenant=max_inflight_per_tenant)
            for n in range(num_nodes)]
        self.tenants: dict[str, Tenant] = {}
        self._tenant_lock = threading.Lock()
        self._shut = False

    def tenant(self, name: str, *,
               memory_budgets: Optional[dict[int, int]] = None,
               device_memory_budget: Optional[int] = None,
               max_queued_windows: int = 8) -> Tenant:
        budgets = dict(memory_budgets or {})
        if device_memory_budget is not None:
            for d in range(self.devices_per_node):
                budgets.setdefault(device_memory(d), device_memory_budget)
        with self._tenant_lock:
            if name in self.tenants:
                raise ValueError(f"tenant '{name}' already exists")
            t = self.tenants[name] = Tenant(
                self, name, memory_budgets=budgets,
                max_queued_windows=max_queued_windows)
        return t

    # -- observability -----------------------------------------------------
    def memo_stats(self) -> dict:
        """Cache effectiveness + per-tenant window counters."""
        snap = (self.metrics_registry.snapshot()
                if self.metrics_registry is not None else
                dict(counters={}, histograms={}))
        counters = snap.get("counters", {})
        return dict(
            hits=counters.get("memo.hits", 0),
            misses=counters.get("memo.misses", 0),
            unreplayable=counters.get("memo.unreplayable", 0),
            patch_us=snap.get("histograms", {}).get("memo.patch_us"),
            tenants={name: dict(lowered=t.lowered_windows,
                                replayed=t.replayed_windows,
                                tasks=t.tdag.task_count,
                                instructions=sum(g.emitted_count
                                                 for g in t.idags),
                                done={n: self.executors[n].tenant_done
                                          .get(name, 0)
                                      for n in range(self.num_nodes)})
                     for name, t in self.tenants.items()})

    def metrics(self) -> dict:
        snap = (self.metrics_registry.snapshot()
                if self.metrics_registry is not None
                else dict(counters={}, gauges={}, histograms={}))
        snap["memo"] = self.memo_stats()
        return snap

    # -- lifecycle ---------------------------------------------------------
    def shutdown(self) -> None:
        if self._shut:
            return
        self._shut = True
        for t in self.tenants.values():
            try:
                t.drain(timeout=30.0)
            except Exception:       # noqa: BLE001 — teardown is best-effort
                pass
        for ex in self.executors:
            ex.shutdown()
        if self.tracer is not None and self.metrics_registry is not None:
            self.metrics_registry.export_counters(self.tracer)

    def __enter__(self) -> "ServingRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
