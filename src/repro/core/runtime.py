"""User-facing Celerity-style runtime (paper §2, architecture §4 / fig. 5).

The main thread submits *command groups* and creates task objects (TDAG).
Each simulated cluster node ("rank") runs its own **scheduler thread** —
replicated-deterministic CDAG generation plus per-node IDAG compilation with
lookahead — and its own **executor thread** with backend lanes.  All
inter-thread hand-off is via SPSC queues; pilot messages are posted by the
scheduler as soon as sends are compiled, ahead of execution (§4.2).

A single process hosts all ranks (one physical CPU in this container); the
protocol — pilots, receive arbitration, push/await-push asymmetry — is the
paper's, byte for byte.  See DESIGN.md §2 for the deviation record.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import numpy as np

from .allocation import device_memory
from .buffer import Accessor, VirtualBuffer
from .command_graph import CommandGraphGenerator, CommandType
from .communicator import Communicator
from .executor import Executor
from .faults import ExecutionAborted, FaultPlan, run_with_restarts
from .instruction_graph import IdagGenerator, InstructionType
from .lookahead import LookaheadScheduler
from .observability import (CriticalPathReport, MetricsRegistry,
                            critical_path, lane_utilization)
from .region import Box
from .task_graph import Task, TaskGraph, TaskType
from .tracing import Tracer
from .verify import ScheduleVerifier


@dataclass
class _EpochRequest:
    task: Task
    futures: list["queue.SimpleQueue"]


class _NodeScheduler:
    """Scheduler thread of one rank: TDAG stream -> CDAG -> lookahead -> IDAG."""

    def __init__(self, node: int, rt: "Runtime"):
        self.node = node
        self.rt = rt
        self.cdag = CommandGraphGenerator(rt.num_nodes, retire_for=node,
                                          collectives=rt.collectives,
                                          allreduce=rt.reduction_allreduce)
        budgets: dict[int, int] = dict(rt.memory_budgets or {})
        if rt.device_memory_budget is not None:
            for d in range(rt.devices_per_node):
                budgets.setdefault(device_memory(d), rt.device_memory_budget)
        self.idag = IdagGenerator(node, rt.devices_per_node, d2d=rt.d2d,
                                  retire=True, budgets=budgets or None,
                                  metrics=rt.metrics_registry,
                                  renaming=rt.renaming)
        self.lookahead = LookaheadScheduler(self.idag, enabled=rt.lookahead,
                                            retire_compiled=True,
                                            metrics=rt.metrics_registry,
                                            tracer=rt.tracer)
        self.inbox: "queue.SimpleQueue" = queue.SimpleQueue()
        # bootstrap instructions (initial epoch) emitted at construction;
        # count its sync instruction so the throttle lag is not off by one
        bootstrap = list(self.idag.instructions)
        self._horizons_sent = sum(
            1 for i in bootstrap
            if i.itype in (InstructionType.HORIZON, InstructionType.EPOCH))
        if rt.verifier is not None:
            rt.verifier.capture(node, bootstrap)
        rt.executors[node].submit(bootstrap)
        self._thread = threading.Thread(target=self._run,
                                        name=f"sched-N{node}", daemon=True)
        self._thread.start()

    def _run(self) -> None:
        rt = self.rt
        while True:
            msg = self.inbox.get()
            if msg is None:
                return
            t0 = rt.tracer.now() if rt.tracer else 0.0
            if isinstance(msg, _EpochRequest):
                task = msg.task
            else:
                task = msg
            cmds = self.cdag.process(task)
            t1 = rt.tracer.now() if rt.tracer else 0.0
            my_epoch_cid: Optional[int] = None
            instrs = []
            for cmd in cmds:
                if cmd.node != self.node:
                    continue
                if cmd.ctype == CommandType.EPOCH:
                    my_epoch_cid = cmd.cid
                instrs.extend(self.lookahead.push(cmd))
            # pilots are transmitted as soon as the sends are compiled (§3.4)
            self._post_new_pilots()
            if instrs:
                # snapshot before submit: the executor rebinds dependency
                # lists when it retires instructions
                span = (rt.verifier.capture(self.node, instrs)
                        if rt.verifier is not None else None)
                rt.executors[self.node].submit(instrs)
                if span is not None and rt.verifier.mode == "window":
                    # async: enqueues the span for the verifier worker
                    # thread, concurrent with the executor draining it
                    rt.verifier.verify_window(self.node, span)
                self._horizons_sent += sum(
                    1 for i in instrs
                    if i.itype in (InstructionType.HORIZON,
                                   InstructionType.EPOCH))
                self._throttle()
            t2 = rt.tracer.now() if rt.tracer else 0.0
            if rt.tracer:
                meta = {"tid": task.tid}
                rt.tracer.span(f"sched-N{self.node}", "cdag", task.name,
                               t0, t1, meta)
                rt.tracer.span(f"sched-N{self.node}", "idag", task.name,
                               t1, t2, meta)
            self._sample_lag()
            if isinstance(msg, _EpochRequest):
                msg.futures[self.node].put(my_epoch_cid)

    def _sample_lag(self) -> None:
        """Scheduler-lag time series (DESIGN.md §11.4), sampled per task:
        how many horizon windows the scheduler runs ahead of execution."""
        rt = self.rt
        if rt.metrics_registry is None and rt.tracer is None:
            return
        name = f"sched.N{self.node}.horizon_lag"
        lag = float(self._horizons_sent
                    - rt.executors[self.node].horizons_done)
        if rt.metrics_registry is not None:
            rt.metrics_registry.gauge(name, lag)
        if rt.tracer is not None:
            rt.tracer.counter(name, lag)

    def _throttle(self) -> None:
        """Bound scheduler run-ahead to ``max_horizon_lag`` horizon windows.

        Without this the scheduler can compile arbitrarily far ahead of
        execution, and completed-instruction retirement (which happens when
        horizons *execute*) never catches up — retained-instruction memory
        would grow linearly with program length on execution-bound runs.
        """
        rt = self.rt
        lag_limit = (rt.max_inflight_windows
                     if rt.max_inflight_windows is not None
                     else rt.max_horizon_lag)
        if not lag_limit:
            return
        ex = self.rt.executors[self.node]
        while (self._horizons_sent - ex.horizons_done) > lag_limit:
            if ex.errors or self.rt._shut:
                return
            ex.horizon_event.clear()
            if (self._horizons_sent - ex.horizons_done) <= lag_limit:
                return
            ex.horizon_event.wait(0.01)

    _pilot_cursor = 0

    def _post_new_pilots(self) -> None:
        pilots = self.idag.pilots
        new = pilots[self._pilot_cursor:]
        for p in new:
            self.rt.comm.post_pilot(p)
        self._pilot_cursor += len(new)
        if new and self.rt.verifier is not None:
            self.rt.verifier.capture_pilots(new)
        # posted pilots are never re-read: trim so the list stays bounded
        # (only this scheduler thread touches idag.pilots)
        if self._pilot_cursor:
            del pilots[:self._pilot_cursor]
            self._pilot_cursor = 0

    def shutdown(self) -> None:
        self.inbox.put(None)
        self._thread.join(timeout=10)


class Runtime:
    """The distributed queue a user program submits command groups to."""

    def __init__(self, num_nodes: int = 1, devices_per_node: int = 1, *,
                 lookahead: bool = True, d2d: bool = True,
                 check_bounds: bool = False, trace: bool = False,
                 horizon_step: int = 4, queues_per_device: int = 2,
                 host_threads: int = 4, max_horizon_lag: int = 8,
                 device_memory_budget: Optional[int] = None,
                 memory_budgets: Optional[dict[int, int]] = None,
                 collectives: bool = True, reduction_fusion: bool = True,
                 reduction_allreduce: bool = True,
                 fault_plan: Optional[FaultPlan] = None,
                 reliable: bool = True,
                 watchdog_timeout: Optional[float] = None,
                 retransmit_timeout: float = 0.05, max_retries: int = 12,
                 metrics: bool = True, renaming: bool = False,
                 issue_width: Optional[int] = None,
                 max_inflight_windows: Optional[int] = None,
                 verify: str = "off"):
        self.num_nodes = num_nodes
        self.devices_per_node = devices_per_node
        self.lookahead = lookahead
        self.max_horizon_lag = max_horizon_lag
        # out-of-order issue (DESIGN.md §13): allocation renaming eliminates
        # WAR/WAW hazards at lowering time; ``max_inflight_windows`` is the
        # reorder-buffer-style bound on horizon windows between lowering and
        # retirement (when given it replaces ``max_horizon_lag``); and
        # ``issue_width`` caps instructions issued per executor drain pass
        self.renaming = renaming
        self.issue_width = issue_width
        self.max_inflight_windows = max_inflight_windows
        # collective exchange layer (DESIGN.md §9): tree/recursive-doubling
        # collectives instead of N*(N-1) point-to-point pushes, and packed
        # fusion of adjacent reduction exchanges
        self.collectives = collectives
        self.reduction_fusion = reduction_fusion and collectives
        # reduce-scatter + allgather allreduce for order-free reduction
        # exchanges (DESIGN.md §9): ~2/N of the full-partial bytes.
        # ``False`` retains the slot-allgather exchange everywhere — the
        # fallback/oracle path the allreduce must match bit for bit.
        self.reduction_allreduce = reduction_allreduce and collectives
        # per-device-memory byte budget (None = unbudgeted, the historical
        # behavior); ``memory_budgets`` maps explicit memory ids -> bytes
        # for finer control (e.g. a pinned-host budget), overriding the
        # per-device default where both are given
        self.device_memory_budget = device_memory_budget
        self.memory_budgets = memory_budgets
        self.d2d = d2d
        self.tracer = Tracer() if trace else None
        # unified metrics registry (DESIGN.md §11): one namespace for
        # executor wait-state histograms, scheduler-lag gauges, memory
        # pressure and transport counters — snapshot via ``metrics()``
        self.metrics_registry = MetricsRegistry() if metrics else None
        self.tdag = TaskGraph(horizon_step=horizon_step,
                              fuse_reductions=self.reduction_fusion)
        # fault model + resilient transport (DESIGN.md §10): the communicator
        # injects wire faults and runs the ack/retransmit protocol; executors
        # inject crash/slow faults and run the watchdog
        self.fault_plan = fault_plan
        self.comm = Communicator(num_nodes, reliable=reliable,
                                 fault_plan=fault_plan,
                                 retransmit_timeout=retransmit_timeout,
                                 max_retries=max_retries,
                                 tracer=self.tracer,
                                 metrics=self.metrics_registry)
        # schedule sanitizer (DESIGN.md §14): "final" verifies the captured
        # instruction streams at every sync; "window" additionally checks
        # each submitted window on the scheduler thread, concurrent with
        # its execution
        if verify not in ("off", "final", "window"):
            raise ValueError(
                f"verify must be 'off', 'final' or 'window', got {verify!r}")
        self.verifier: Optional[ScheduleVerifier] = None
        if verify != "off":
            vbudgets: dict[int, int] = dict(memory_budgets or {})
            if device_memory_budget is not None:
                for d in range(devices_per_node):
                    vbudgets.setdefault(device_memory(d), device_memory_budget)
            self.verifier = ScheduleVerifier(num_nodes, mode=verify,
                                             metrics=self.metrics_registry,
                                             budgets=vbudgets or None)
        self.executors = [Executor(n, devices_per_node, self.comm,
                                   queues_per_device=queues_per_device,
                                   host_threads=host_threads,
                                   check_bounds=check_bounds,
                                   tracer=self.tracer,
                                   metrics=self.metrics_registry,
                                   fault_plan=fault_plan,
                                   watchdog_timeout=watchdog_timeout,
                                   issue_width=issue_width)
                          for n in range(num_nodes)]
        self.schedulers = [_NodeScheduler(n, self) for n in range(num_nodes)]
        self._shut = False

    # -- user API ------------------------------------------------------------
    def buffer(self, shape: Sequence[int], dtype=np.float64, *,
               name: str = "", init: Optional[np.ndarray] = None) -> VirtualBuffer:
        return VirtualBuffer(shape=tuple(shape), dtype=np.dtype(dtype),
                             name=name, initial_value=init)

    def submit(self, name: str, index_space, accessors: Sequence[Accessor],
               kernel_fn: Callable | None = None, *,
               ttype: TaskType = TaskType.KERNEL,
               split_dims: Sequence[int] = (0,),
               granularity: Sequence[int] = (1,)) -> Task:
        t0 = self.tracer.now() if self.tracer else 0.0
        task = self.tdag.submit(name, index_space, accessors, kernel_fn,
                                ttype=ttype, split_dims=split_dims,
                                granularity=granularity)
        if self.tracer:
            self.tracer.span("main", "task", name, t0, self.tracer.now(),
                             {"tid": task.tid})
        # the TDAG may have auto-emitted a horizon right after this task
        self._broadcast()
        return task

    _sent = 0

    def _broadcast(self) -> None:
        # ``_sent`` counts lifetime task indices; the TDAG list may have a
        # retired prefix (``_base``), so index relative to it
        newly = self.tdag.tasks[self._sent - self.tdag._base:]
        for task in newly:
            if task.ttype == TaskType.EPOCH and task.name == "init":
                self._sent += 1
                continue
            for sched in self.schedulers:
                sched.inbox.put(task)
            self._sent += 1
        # everything broadcast and behind the last sync point can retire
        self.tdag.retire_to(self._sent)

    def sync(self, timeout: float = 120.0) -> None:
        """Emit an epoch and block until every rank has executed it."""
        epoch = self.tdag.emit_epoch("sync")
        futures = [queue.SimpleQueue() for _ in range(self.num_nodes)]
        # flush any tasks emitted before the epoch, then the epoch itself
        newly = self.tdag.tasks[self._sent - self.tdag._base:]
        for task in newly:
            if task is epoch:
                req = _EpochRequest(task=epoch, futures=futures)
                for sched in self.schedulers:
                    sched.inbox.put(req)
            else:
                for sched in self.schedulers:
                    sched.inbox.put(task)
            self._sent += 1
        self.tdag.retire_to(self._sent)
        failures: list[tuple[int, BaseException]] = []
        for n, ex in enumerate(self.executors):
            cid = futures[n].get(timeout=timeout)
            if cid is None:
                continue
            try:
                ex.wait_epoch(cid, timeout=timeout)
            except Exception as e:  # noqa: BLE001 — aggregated below
                failures.append((n, ex.errors[0] if ex.errors else e))
        # a node whose epoch landed before a late-arriving abort still holds
        # an error — fold those in so the report names every failed rank
        for n, ex in enumerate(self.executors):
            if ex.errors and all(fn != n for fn, _ in failures):
                failures.append((n, ex.errors[0]))
        if failures:
            raise ExecutionAborted(
                "executor failure; " + self.comm.transport_summary(),
                sorted(failures)) from failures[0][1]
        if self.verifier is not None:
            self.verifier.finalize(
                peaks=[dict(s.idag.mem.peak) for s in self.schedulers])
            self.verifier.check()

    def gather(self, buf: VirtualBuffer, timeout: float = 120.0) -> np.ndarray:
        """Assemble the current buffer contents on the caller's side."""
        from .buffer import read as read_acc
        from .range_mapper import one_to_one
        out = np.empty(buf.shape, dtype=buf.dtype)
        lock = threading.Lock()

        def collect(chunk: Box, view) -> None:
            data = view.get(chunk)
            sl = tuple(slice(a, b) for a, b in zip(chunk.min, chunk.max))
            with lock:
                out[sl] = data

        self.submit(f"gather {buf.name}", buf.shape,
                    [read_acc(buf, one_to_one())], collect,
                    ttype=TaskType.HOST)
        self.sync(timeout=timeout)
        return out

    # -- diagnostics -----------------------------------------------------------
    @property
    def warnings(self) -> list[str]:
        w = list(self.tdag.warnings)
        for s in self.schedulers:
            w.extend(s.cdag.errors)
            w.extend(s.idag.warnings)
        for ex in self.executors:
            w.extend(ex.warnings)
        return w

    def comm_stats(self) -> dict:
        """Wire-level accounting: total messages/bytes plus the collective-
        round share (DESIGN.md §9) and the resilient-transport counters
        (DESIGN.md §10).  Retransmit traffic is accounted separately
        (``retries``/``retry_bytes``) so logical message/byte counts stay
        fault-independent."""
        return dict(messages=self.comm.num_messages,
                    bytes=self.comm.bytes_sent,
                    coll_messages=self.comm.coll_messages,
                    coll_bytes=self.comm.coll_bytes,
                    red_messages=self.comm.red_messages,
                    red_bytes=self.comm.red_bytes,
                    retries=self.comm.retries,
                    retry_bytes=self.comm.retry_bytes,
                    acks=self.comm.acks,
                    aborts=self.comm.aborts,
                    dups_suppressed=sum(ex.arbiter.dups_suppressed
                                        for ex in self.executors),
                    stale_rejected=sum(ex.arbiter.stale_rejected
                                       for ex in self.executors),
                    faults_injected=dict(self.comm.fault_counts))

    def metrics(self) -> dict:
        """One unified observability snapshot (DESIGN.md §11).

        Merges the metrics registry (counters / gauges / histograms with
        p50/p95/p99) with the previously scattered stat dicts: wire-level
        ``comm`` accounting, the per-node ``memory`` reports, per-node
        ``lookahead`` and ``executor`` scheduler stats, and the traced
        instant-event histogram when tracing is on.
        """
        from dataclasses import asdict
        snap = (self.metrics_registry.snapshot()
                if self.metrics_registry is not None
                else dict(counters={}, gauges={}, histograms={}))
        snap["comm"] = self.comm_stats()
        snap["memory"] = self.memory_report()
        snap["lookahead"] = {n: asdict(s.lookahead.stats)
                             for n, s in enumerate(self.schedulers)}
        snap["executor"] = {
            n: dict(done=ex._done_count, retired=ex._retired_count,
                    peak_registered=ex._peak_registered,
                    horizons_done=ex.horizons_done,
                    queue_latency_ewma=ex.straggler_report())
            for n, ex in enumerate(self.executors)}
        if self.tracer is not None:
            snap["instants"] = self.tracer.instant_counts()
        return snap

    def critical_path_report(self) -> CriticalPathReport:
        """Critical-path / wait-state attribution over the traced run.

        Requires ``trace=True``; call after a ``sync()`` so the chain ends
        at a quiesced epoch.
        """
        if self.tracer is None:
            raise RuntimeError("critical_path_report() needs Runtime(trace=True)")
        return critical_path(self.tracer)

    def utilization_report(self) -> dict:
        """Per-device-lane busy/idle occupancy over the traced run.

        Computed from the flight recorder's :class:`InstrRecord` stamps
        (union of execution intervals per backend lane over the global
        observation window); the ``occupancy`` key is the mean busy
        fraction over all lanes — the number the renaming/issue-window
        knobs (DESIGN.md §13) are meant to push up.  Requires
        ``Runtime(trace=True)``.
        """
        if self.tracer is None:
            raise RuntimeError("utilization_report() needs Runtime(trace=True)")
        with self.tracer._lock:
            records = list(self.tracer.records)
        return lane_utilization(records)

    def thread_report(self) -> dict:
        """Worker-thread health after shutdown: leaked (unjoinable) thread
        count per node plus the warning text explaining each leak."""
        return dict(
            leaked_threads={n: ex.leaked_threads
                            for n, ex in enumerate(self.executors)},
            total_leaked=sum(ex.leaked_threads for ex in self.executors),
            warnings=[w for ex in self.executors for w in ex.warnings])

    def total_instructions(self) -> int:
        return sum(s.idag.emitted_count for s in self.schedulers)

    def total_allocs(self) -> int:
        return sum(s.idag.alloc_count for s in self.schedulers)

    def device_peak_bytes(self) -> int:
        """Max real materialized bytes observed in any device memory of any
        node — the high-water mark budget acceptance compares against."""
        from .allocation import is_device_memory
        return max((v for ex in self.executors
                    for mid, v in ex.mem_peak.items() if is_device_memory(mid)),
                   default=0)

    def memory_report(self) -> list[dict]:
        """Per-node memory-layer report: the scheduler-side compile-time
        model (budgets, modeled peaks, spill/reload/eviction counters) and
        the executor-side real materialized-byte peaks per memory id."""
        out = []
        for n in range(self.num_nodes):
            mm = self.schedulers[n].idag.mem
            ex = self.executors[n]
            rep = mm.snapshot()
            rep["node"] = n
            rep["real_used"] = dict(ex.mem_used)
            rep["real_peak"] = dict(ex.mem_peak)
            rep["leaked_threads"] = ex.leaked_threads
            out.append(rep)
        return out

    def shutdown(self) -> None:
        if self._shut:
            return
        self._shut = True
        # a failed/crashed grid cannot reach another epoch: skip the final
        # sync (it would burn the full timeout) and go straight to teardown
        if not any(ex.errors or ex.crashed for ex in self.executors):
            try:
                self.sync()
            except Exception:
                pass
        for s in self.schedulers:
            s.shutdown()
        for ex in self.executors:
            ex.shutdown()
        # final registry values become Perfetto counter samples, so the
        # exported trace carries the unified metrics end state
        if self.tracer is not None and self.metrics_registry is not None:
            self.metrics_registry.export_counters(self.tracer)

    def __enter__(self) -> "Runtime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- supervised execution (DESIGN.md §10.4) ------------------------------
    @classmethod
    def run_supervised(cls, build, step, *, steps: int, num_nodes: int,
                       devices_per_node: int = 1, checkpoint_every: int = 1,
                       max_restarts: int = 3, min_nodes: int = 1,
                       fault_plan: Optional[FaultPlan] = None,
                       manager=None, watchdog_timeout: Optional[float] = 2.0,
                       sync_timeout: float = 60.0,
                       **rt_kwargs) -> "SupervisedResult":
        """Run a stepwise program under bounded-restart supervision.

        ``build(rt, init)`` creates the program's buffers on runtime ``rt``
        and returns ``{name: VirtualBuffer}``; ``init`` is ``None`` on a
        fresh start, else the ``{name: ndarray}`` snapshot to resume from.
        ``step(rt, bufs, i)`` submits step ``i``'s command groups.

        Every ``checkpoint_every`` steps the buffers are gathered into an
        in-memory snapshot (and handed to ``manager.save`` when a
        checkpoint manager is supplied).  On a recoverable failure —
        crashed rank, exhausted retransmits, watchdog abort — the grid is
        torn down, any in-flight async checkpoint save is joined
        (``manager.close``), one node is dropped (elastic shrink, floor
        ``min_nodes``), one-shot crash faults are cleared
        (:meth:`FaultPlan.survivors`), and the program is resubmitted from
        the last snapshot.  After ``max_restarts`` failed recoveries the
        last error propagates.
        """
        state: dict = {"step": 0, "snap": None, "world": num_nodes}

        def attempt(restarts: int) -> dict[str, np.ndarray]:
            world = max(min_nodes, num_nodes - restarts)
            plan = (fault_plan.survivors()
                    if (fault_plan is not None and restarts) else fault_plan)
            rt = cls(world, devices_per_node, fault_plan=plan,
                     watchdog_timeout=watchdog_timeout, **rt_kwargs)
            state["world"] = world
            try:
                bufs = build(rt, state["snap"])
                for i in range(state["step"], steps):
                    step(rt, bufs, i)
                    if (i + 1) % checkpoint_every == 0 or i + 1 == steps:
                        snap = {k: rt.gather(b, timeout=sync_timeout)
                                for k, b in sorted(bufs.items())}
                        state["snap"], state["step"] = snap, i + 1
                        if manager is not None:
                            manager.save(i + 1, snap)
                return state["snap"]
            finally:
                rt.shutdown()

        def on_failure(err: BaseException, restarts: int) -> None:
            # join any in-flight async checkpoint save before the next grid
            # comes up — a half-written checkpoint must never race a restore
            if manager is not None:
                manager.close()

        results, restarts = run_with_restarts(attempt, on_failure,
                                              max_restarts=max_restarts)
        if manager is not None:
            manager.close()
        return SupervisedResult(results=results, restarts=restarts,
                                world=state["world"], steps=state["step"])


@dataclass
class SupervisedResult:
    """Outcome of :meth:`Runtime.run_supervised`."""
    results: dict[str, np.ndarray]
    restarts: int
    world: int          # surviving grid size that produced the result
    steps: int          # steps completed (== requested steps on success)
