"""Command graph (CDAG) generation — paper §2.4.

The CDAG distributes each task's kernel index space onto cluster nodes and
models the peer-to-peer communication (push / await-push) needed to satisfy
the resulting data dependencies.  Generation is a *replicated deterministic*
process: every node computes the same global ownership information, but only
materializes the commands it will itself execute.  Push commands carry the
precise target and region; await-push commands only know the *union* of
subregions that will arrive for a task (the paper's scalability trade-off,
§3.4) — which is what later forces split-receive handling in the IDAG.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional

from .buffer import VirtualBuffer
from .collective import schedule_for
from .reduction import Reduction
from .region import Box, Region, RegionMap, split_box
from .task_graph import DepKind, Task, TaskGraph, TaskType


class CommandType(enum.Enum):
    EXECUTION = "execution"
    PUSH = "push"
    AWAIT_PUSH = "await_push"
    # reductions (§2.2): N partial producers -> 1 replicated value.  Each
    # participating node combines its device partials and broadcasts them
    # (REDUCE_PARTIAL); every node then gathers all partials and folds them
    # in canonical node order (REDUCE_GLOBAL) — replicated-deterministic.
    REDUCE_PARTIAL = "reduce_partial"
    REDUCE_GLOBAL = "reduce_global"
    # collective exchanges (DESIGN.md §9): detected from the replicated
    # all-pairs picture and lowered into O(log N) topology rounds.  One
    # command per involved node; the point-to-point PUSH/AWAIT_PUSH path is
    # kept for irregular / partial-overlap exchanges.
    COLL_ALLGATHER = "coll_allgather"
    COLL_BROADCAST = "coll_broadcast"
    COLL_SCATTER = "coll_scatter"
    # reduce-scatter + allgather allreduce (DESIGN.md §9): the reduction
    # exchange of a fusion group whose members all have an order-free
    # combine.  Carries the same member metadata as the fused allgather;
    # the IDAG derives the two-phase schedule from the replicated
    # participant set.  The slot-allgather exchange stays available as the
    # fallback/oracle path (``allreduce=False``).
    COLL_ALLREDUCE = "coll_allreduce"
    HORIZON = "horizon"
    EPOCH = "epoch"


_cmd_ids = itertools.count()


@dataclass
class Command:
    ctype: CommandType
    node: int
    task: Optional[Task] = None
    chunk: Optional[Box] = None                 # EXECUTION: this node's chunk
    buffer: Optional[VirtualBuffer] = None      # PUSH/AWAIT_PUSH/REDUCE_*
    region: Optional[Region] = None             # PUSH: precise; AWAIT: union
    target: Optional[int] = None                # PUSH only
    # PUSH/AWAIT: (task id, buffer id); REDUCE_*: (task id, buffer id, 1) so
    # gather traffic never aliases include_current_value coherence transfers
    transfer_id: Optional[tuple] = None
    reduction: Optional[Reduction] = None       # REDUCE_* only
    participants: tuple[int, ...] = ()          # REDUCE_*: nodes with chunks
    targets: tuple[int, ...] = ()               # REDUCE_PARTIAL: broadcast set
    # collective metadata (COLL_*, replicated on every node; DESIGN.md §9)
    coll_group: tuple[int, ...] = ()            # ordered exchange group
    coll_blocks: Optional[dict] = None          # block rank -> Region
    coll_root: Optional[int] = None             # broadcast/scatter root
    # fused reduction exchange: ((rtid, Reduction), ...) member components
    coll_members: tuple = ()
    # REDUCE_PARTIAL/REDUCE_GLOBAL lowered in collective (staging-slot) mode
    collective: bool = False
    # reduction exchange lowered as reduce-scatter + allgather (flat
    # slot-space staging) instead of the full-partial slot allgather
    allreduce: bool = False
    cid: int = field(default_factory=lambda: next(_cmd_ids))
    dependencies: list[tuple["Command", DepKind]] = field(default_factory=list)
    dependents: list["Command"] = field(default_factory=list)

    def add_dependency(self, dep: "Command", kind: DepKind) -> None:
        if dep is self:
            return
        for d, _ in self.dependencies:
            if d is dep:
                return
        self.dependencies.append((dep, kind))
        dep.dependents.append(self)

    def __hash__(self) -> int:
        return self.cid

    def __repr__(self) -> str:
        t = f":{self.task.name}" if self.task else ""
        return f"C{self.cid}<{self.ctype.value}{t}@N{self.node}>"


@dataclass
class _NodeBufferState:
    last_writers: RegionMap                     # region -> local Command
    last_readers: list[tuple[Region, Command]] = field(default_factory=list)


class CommandGraphGenerator:
    """Generates per-node command graphs from a TDAG stream."""

    def __init__(self, num_nodes: int, *, retire_for: Optional[int] = None,
                 collectives: bool = False, allreduce: bool = True):
        self.num_nodes = num_nodes
        # ``collectives=True`` turns all-pairs exchange patterns into COLL_*
        # commands and reduction exchanges into (fusable) allgathers; the
        # point-to-point path remains for irregular exchanges and is the
        # default for structural/back-compat consumers (``generate_cdag``).
        self.collectives = collectives
        # ``allreduce=True`` (with collectives): reduction exchanges whose
        # members all have an order-free combine lower as reduce-scatter +
        # allgather (~2/N of the full-partial bytes); ``False`` keeps the
        # slot-allgather exchange everywhere (the fallback/oracle path).
        # Below 3 nodes the decomposition cannot reduce bytes (every slot
        # crosses the wire once per direction regardless) and only doubles
        # the message count, so the fallback stays in charge there.
        self.allreduce = allreduce and collectives and num_nodes >= 3
        # open fused-reduction group: reduction exchanges are deferred until
        # the fusion chain breaks (next non-fusable task, horizon or epoch),
        # then emitted as ONE packed allgather + per-member REDUCE_GLOBALs
        self._open_red: Optional[dict] = None
        self.commands: list[list[Command]] = [[] for _ in range(num_nodes)]
        # ``retire_for=k`` (runtime mode, one generator per node scheduler):
        # at every horizon/epoch the per-node command lists are trimmed to
        # the new sync command, so CDAG memory is O(window) on long runs.
        # Commands of nodes != k also get their dependency lists cleared at
        # the sync (nothing ever compiles them here); node k's edges are
        # cleared by the lookahead once each command is lowered.
        # ``emitted_counts`` keeps the lifetime totals.
        self.retire_for = retire_for
        self.emitted_counts: list[int] = [0] * num_nodes
        # replicated global ownership: buffer -> RegionMap(region -> owner rank)
        self._ownership: dict[int, RegionMap] = {}
        self._buffers: dict[int, VirtualBuffer] = {}
        self._node_state: list[dict[int, _NodeBufferState]] = [dict() for _ in range(num_nodes)]
        self._init_epochs: list[Command] = []
        self._last_horizon: list[Optional[Command]] = [None] * num_nodes
        self._last_epoch: list[Optional[Command]] = [None] * num_nodes
        self._frontier_pos: list[int] = [0] * num_nodes  # last sync cmd index
        self.errors: list[str] = []
        for n in range(num_nodes):
            epoch = Command(CommandType.EPOCH, node=n, task=None)
            self._add(n, epoch)
            self._init_epochs.append(epoch)
            self._last_epoch[n] = epoch

    def _add(self, n: int, cmd: Command) -> None:
        self.commands[n].append(cmd)
        self.emitted_counts[n] += 1

    # ------------------------------------------------------------------
    def _ownership_map(self, buf: VirtualBuffer) -> RegionMap:
        m = self._ownership.get(buf.bid)
        if m is None:
            # buffers with initial values are replicated on every node at t=0;
            # we mark rank 0 as canonical owner and all nodes as up-to-date.
            m = RegionMap(buf.full_box, default=frozenset(range(self.num_nodes))
                          if buf.initial_value is not None else None)
            self._ownership[buf.bid] = m
            self._buffers[buf.bid] = buf
        return m

    def _node_buf(self, node: int, buf: VirtualBuffer) -> _NodeBufferState:
        st = self._node_state[node].get(buf.bid)
        if st is None:
            st = _NodeBufferState(
                last_writers=RegionMap(buf.full_box, default=self._init_epochs[node]))
            self._node_state[node][buf.bid] = st
        return st

    # ------------------------------------------------------------------
    def process(self, task: Task) -> list[Command]:
        if task.ttype == TaskType.HORIZON:
            return self._flush_reductions() + self._emit_sync(task, CommandType.HORIZON)
        if task.ttype == TaskType.EPOCH:
            return self._flush_reductions() + self._emit_sync(task, CommandType.EPOCH)
        return self._process_kernel(task)

    def _emit_sync(self, task: Task, ctype: CommandType) -> list[Command]:
        out = []
        for n in range(self.num_nodes):
            cmd = Command(ctype, node=n, task=task)
            # commands before the previous sync already have a dependent
            # (that sync): only the tail can contribute to the frontier
            for c in self.commands[n][self._frontier_pos[n]:]:
                if not c.dependents:
                    cmd.add_dependency(c, DepKind.SYNC)
            self._add(n, cmd)
            self._frontier_pos[n] = len(self.commands[n]) - 1
            if ctype == CommandType.HORIZON:
                self._last_horizon[n] = cmd
            else:
                self._last_epoch[n] = cmd
                self._last_horizon[n] = None
            # horizon compaction of per-node tracking structures
            for st in self._node_state[n].values():
                st.last_writers.update(st.last_writers.covered(), cmd)
                st.last_writers.coalesce()
                st.last_readers = []
            if self.retire_for is not None:
                # everything before this sync is dominated by it; the
                # tracking maps above now reference only the sync command
                if n != self.retire_for:
                    for c in self.commands[n][:-1]:
                        c.dependencies.clear()
                        c.dependents.clear()
                del self.commands[n][:-1]
                self._frontier_pos[n] = 0
            out.append(cmd)
        return out

    # ------------------------------------------------------------------
    def _fetch_missing(self, n: int, buf: VirtualBuffer, need: Region,
                       task: Task, consumer: Command,
                       new_cmds: list[Command]) -> None:
        """Emit sender pushes + one await-push so ``need`` is up-to-date on
        node ``n``; wires the await-push as a TRUE dep of ``consumer``."""
        own = self._ownership_map(buf)
        missing_union = Region.empty()
        for sub, owner in own.query(need):
            if owner is None:
                continue  # uninitialized — TDAG already warned
            owners = owner if isinstance(owner, frozenset) else frozenset([owner])
            if n in owners:
                continue
            src = min(owners)  # deterministic sender choice
            missing_union = missing_union.union(sub)
            # sender-side push (materialized on the sender node)
            push = Command(CommandType.PUSH, node=src, task=task, buffer=buf,
                           region=sub, target=n,
                           transfer_id=(task.tid, buf.bid))
            sst = self._node_buf(src, buf)
            for ssub, writer in sst.last_writers.query(sub):
                push.add_dependency(writer, DepKind.TRUE)
            sst.last_readers.append((sub, push))
            self._add(src, push)
            new_cmds.append(push)
        if not missing_union.is_empty():
            ap = Command(CommandType.AWAIT_PUSH, node=n, task=task, buffer=buf,
                         region=missing_union,
                         transfer_id=(task.tid, buf.bid))
            nst = self._node_buf(n, buf)
            # anti-dep: receive overwrites stale local data
            for ssub, writer in nst.last_writers.query(missing_union):
                ap.add_dependency(writer, DepKind.ANTI)
            for rreg, reader in nst.last_readers:
                if rreg.overlaps(missing_union):
                    ap.add_dependency(reader, DepKind.ANTI)
            nst.last_writers.update(missing_union, ap)
            self._add(n, ap)
            new_cmds.append(ap)
            consumer.add_dependency(ap, DepKind.TRUE)
            # received data is now also up-to-date on n (replicated info)
            for sub, owner in own.query(missing_union):
                owners = owner if isinstance(owner, frozenset) else frozenset([owner])
                own.update(sub, owners | {n})

    def _fetch_missing_grouped(self, task: Task, buf: VirtualBuffer,
                               needs: dict[int, Region],
                               consumers: dict[int, Command],
                               new_cmds: list[Command]) -> None:
        """Coherence pre-fetch for several consumers of the same buffer —
        as ONE broadcast when a single owner serves every participant
        (the ``include_current_value`` shape; ROADMAP "collectivize
        include_current"), point-to-point pushes otherwise."""
        if self.collectives:
            coll = self._classify_exchange(buf, needs)
            if coll is not None and coll["kind"] == "broadcast":
                self._emit_collective(task, buf, coll, needs, consumers,
                                      new_cmds)
                return
        for n, need in needs.items():
            self._fetch_missing(n, buf, need, task, consumers[n], new_cmds)

    # ------------------------------------------------------------------
    def _process_kernel(self, task: Task) -> list[Command]:
        chunks = split_box(task.index_space, self.num_nodes,
                           dims=task.split_dims, granularity=task.granularity)
        # node i executes chunk i (static assignment); nodes beyond the chunk
        # count execute nothing for this task.
        node_chunks: dict[int, Box] = {i: c for i, c in enumerate(chunks)}
        new_cmds: list[Command] = []

        # fused-reduction scope: the open group survives only while the
        # (replicated) TDAG fusion chain continues AND the participant set
        # is unchanged; otherwise its deferred exchange flushes first, so
        # this task observes the folded results as the last writers.
        if self._open_red is not None:
            fusable = (task.reductions and task.fuse_with_prev
                       and tuple(sorted(node_chunks))
                       == self._open_red["participants"]
                       # the exchange mode (allreduce vs slot allgather) is
                       # per group: an order-free task never shares a packed
                       # exchange with a canonical-order one
                       and self._order_free(task)
                       == self._open_red["order_free"])
            if not fusable:
                new_cmds.extend(self._flush_reductions())

        # --- pass 1: writer-ownership + overlapping-write detection -------
        writes_per_node: dict[int, dict[int, Region]] = {}
        for n, chunk in node_chunks.items():
            for acc in task.accessors:
                if acc.mode.is_producer:
                    reg = acc.mapped_region(chunk)
                    writes_per_node.setdefault(acc.buffer.bid, {})[n] = \
                        writes_per_node.get(acc.buffer.bid, {}).get(n, Region.empty()).union(reg)
        for bid, per_node in writes_per_node.items():
            nodes = list(per_node)
            for i in range(len(nodes)):
                for j in range(i + 1, len(nodes)):
                    if per_node[nodes[i]].overlaps(per_node[nodes[j]]):
                        self.errors.append(
                            f"overlapping writes to {self._buffers.get(bid, bid)} by nodes "
                            f"{nodes[i]} and {nodes[j]} in task {task.name}")

        # --- pass 2: reads → pushes / await-pushes ------------------------
        exec_cmds: dict[int, Command] = {}
        for n, chunk in node_chunks.items():
            cmd = Command(CommandType.EXECUTION, node=n, task=task, chunk=chunk)
            exec_cmds[n] = cmd

        if self.collectives:
            handled: set[int] = set()
            for acc in task.accessors:
                if not acc.mode.is_consumer or acc.buffer.bid in handled:
                    continue
                handled.add(acc.buffer.bid)
                self._exchange_buffer(task, acc.buffer, node_chunks,
                                      exec_cmds, new_cmds)
        else:
            for n, chunk in node_chunks.items():
                cmd = exec_cmds[n]
                for acc in task.accessors:
                    if not acc.mode.is_consumer:
                        continue
                    need = acc.mapped_region(chunk)
                    self._fetch_missing(n, acc.buffer, need, task, cmd, new_cmds)

        # --- pass 3: local deps + ownership update for writes -------------
        for n, chunk in node_chunks.items():
            cmd = exec_cmds[n]
            for acc in task.accessors:
                buf = acc.buffer
                nst = self._node_buf(n, buf)
                if acc.mode.is_consumer:
                    need = acc.mapped_region(chunk)
                    for sub, writer in nst.last_writers.query(need):
                        cmd.add_dependency(writer, DepKind.TRUE)
                    nst.last_readers.append((need, cmd))
                if acc.mode.is_producer:
                    wreg = acc.mapped_region(chunk)
                    for rreg, reader in nst.last_readers:
                        if reader is not cmd and rreg.overlaps(wreg):
                            cmd.add_dependency(reader, DepKind.ANTI)
                    for sub, writer in nst.last_writers.query(wreg):
                        cmd.add_dependency(writer, DepKind.OUTPUT)
                    nst.last_writers.update(wreg, cmd)
                    nst.last_readers = [(r, t) for r, t in nst.last_readers
                                        if not r.difference(wreg).is_empty() or t is cmd]
            if not cmd.dependencies and self._last_epoch[n] is not None:
                cmd.add_dependency(self._last_epoch[n], DepKind.SYNC)
            if self._last_horizon[n] is not None:
                cmd.add_dependency(self._last_horizon[n], DepKind.SYNC)
            self._add(n, cmd)
            new_cmds.append(cmd)

        # global ownership update: writers become exclusive owners
        for acc in task.accessors:
            if acc.mode.is_producer:
                own = self._ownership_map(acc.buffer)
                for n, chunk in node_chunks.items():
                    own.update(acc.mapped_region(chunk), frozenset([n]))

        # --- pass 4: reductions (N partials -> 1 replicated value) ---------
        if self.collectives:
            if task.reductions:
                self._queue_reductions(task, node_chunks, exec_cmds, new_cmds)
        else:
            for red in task.reductions:
                self._process_reduction(task, red, node_chunks, exec_cmds,
                                        new_cmds)
        return new_cmds

    # -- collective exchange detection (DESIGN.md §9) ---------------------
    def _exchange_buffer(self, task: Task, buf: VirtualBuffer,
                         node_chunks: dict[int, Box],
                         exec_cmds: dict[int, Command],
                         new_cmds: list[Command]) -> None:
        """Satisfy every node's reads of ``buf`` for this task — as ONE
        collective when the all-pairs picture matches a known topology,
        falling back to the historical per-accessor point-to-point path."""
        needs: dict[int, Region] = {}
        for n, chunk in node_chunks.items():
            r = Region.empty()
            for acc in task.accessors:
                if acc.buffer.bid == buf.bid and acc.mode.is_consumer:
                    r = r.union(acc.mapped_region(chunk))
            if not r.is_empty():
                needs[n] = r
        coll = self._classify_exchange(buf, needs)
        if coll is None:
            for n, chunk in node_chunks.items():
                cmd = exec_cmds[n]
                for acc in task.accessors:
                    if acc.buffer.bid == buf.bid and acc.mode.is_consumer:
                        self._fetch_missing(n, acc.buffer,
                                            acc.mapped_region(chunk), task,
                                            cmd, new_cmds)
            return
        self._emit_collective(task, buf, coll, needs, exec_cmds, new_cmds)

    def _classify_exchange(self, buf: VirtualBuffer,
                           needs: dict[int, Region]) -> Optional[dict]:
        """Classify the missing-data transfer matrix of one buffer.

        * ``allgather`` — >=2 single-owner pieces, every group member needs
          every piece it does not own (the replicated-exchange pattern);
        * ``broadcast`` — one source, >=2 destinations, identical region;
        * ``scatter`` — one source, >=2 destinations, pairwise-disjoint
          regions;
        * ``None`` — irregular / partial overlap: point-to-point path.
        """
        own = self._ownership_map(buf)
        srcmap: dict[int, dict[int, Region]] = {}
        for n, need in needs.items():
            for sub, owner in own.query(need):
                if owner is None:
                    continue  # uninitialized — TDAG already warned
                owners = (owner if isinstance(owner, frozenset)
                          else frozenset([owner]))
                if n in owners:
                    continue
                src = min(owners)
                dmap = srcmap.setdefault(src, {})
                dmap[n] = dmap.get(n, Region.empty()).union(sub)
        if not srcmap:
            return None
        sources = sorted(srcmap)
        dests = sorted({d for dmap in srcmap.values() for d in dmap})
        if len(sources) >= 2:
            group = tuple(sorted(set(sources) | set(dests)))
            blocks: dict[int, Region] = {}
            for s in sources:
                dmap = srcmap[s]
                if set(dmap) != set(group) - {s}:
                    return None
                regs = list(dmap.values())
                if any(r != regs[0] for r in regs[1:]):
                    return None
                blocks[s] = regs[0]
            return dict(kind="allgather", group=group, blocks=blocks,
                        root=None)
        s = sources[0]
        dmap = srcmap[s]
        if len(dmap) < 2:
            return None
        group = (s,) + tuple(sorted(dmap))
        regs = list(dmap.values())
        if all(r == regs[0] for r in regs[1:]):
            return dict(kind="broadcast", group=group, blocks={s: regs[0]},
                        root=s)
        ds = sorted(dmap)
        if all(not dmap[ds[i]].overlaps(dmap[ds[j]])
               for i in range(len(ds)) for j in range(i + 1, len(ds))):
            return dict(kind="scatter", group=group, blocks=dict(dmap),
                        root=s)
        return None

    def _emit_collective(self, task: Task, buf: VirtualBuffer, coll: dict,
                         needs: dict[int, Region],
                         exec_cmds: dict[int, Command],
                         new_cmds: list[Command]) -> None:
        kind, group, blocks, root = (coll["kind"], coll["group"],
                                     coll["blocks"], coll["root"])
        rounds = schedule_for(kind, group, contributors=tuple(sorted(blocks)),
                              root=root)
        ctype = {"allgather": CommandType.COLL_ALLGATHER,
                 "broadcast": CommandType.COLL_BROADCAST,
                 "scatter": CommandType.COLL_SCATTER}[kind]
        base_tid = (task.tid, buf.bid, 2)
        full_payload = Region.empty()
        for r in blocks.values():
            full_payload = full_payload.union(r)
        for n in group:
            if kind == "allgather":
                own_region = blocks.get(n, Region.empty())
            else:
                own_region = full_payload if n == root else Region.empty()
            recv_region = Region.empty()
            for msgs in rounds:
                for m in msgs:
                    if m.dst == n:
                        for b in m.blocks:
                            recv_region = recv_region.union(blocks[b])
            cmd = Command(ctype, node=n, task=task, buffer=buf,
                          region=own_region.union(recv_region),
                          transfer_id=base_tid, coll_group=group,
                          coll_blocks=blocks, coll_root=root)
            nst = self._node_buf(n, buf)
            if not own_region.is_empty():
                for sub, writer in nst.last_writers.query(own_region):
                    cmd.add_dependency(writer, DepKind.TRUE)
                nst.last_readers.append((own_region, cmd))
            if not recv_region.is_empty():
                # landing overwrites stale local data
                for sub, writer in nst.last_writers.query(recv_region):
                    cmd.add_dependency(writer, DepKind.ANTI)
                for rreg, reader in nst.last_readers:
                    if reader is not cmd and rreg.overlaps(recv_region):
                        cmd.add_dependency(reader, DepKind.ANTI)
                nst.last_writers.update(recv_region, cmd)
            if self._last_horizon[n] is not None:
                cmd.add_dependency(self._last_horizon[n], DepKind.SYNC)
            elif not cmd.dependencies and self._last_epoch[n] is not None:
                cmd.add_dependency(self._last_epoch[n], DepKind.SYNC)
            self._add(n, cmd)
            new_cmds.append(cmd)
            if n in needs:
                exec_cmds[n].add_dependency(cmd, DepKind.TRUE)
        # replicated ownership: every rank that lands a block (consumers AND
        # tree forwarders — both really hold the bytes) becomes up to date
        own = self._ownership_map(buf)
        for b, reg in blocks.items():
            receivers = {m.dst for msgs in rounds for m in msgs
                         if b in m.blocks}
            for sub, owner in own.query(reg):
                owners = (owner if isinstance(owner, frozenset)
                          else frozenset([owner]))
                own.update(sub, owners | receivers)

    # -- fused reduction exchange (DESIGN.md §9) --------------------------
    @staticmethod
    def _order_free(task: Task) -> bool:
        """Whether ALL of a task's reductions have an order-free combine
        (the reduce-scatter fold tree is not the canonical node order)."""
        return all(r.op.combine_order_free for r in task.reductions)

    def _queue_reductions(self, task: Task, node_chunks: dict[int, Box],
                          exec_cmds: dict[int, Command],
                          new_cmds: list[Command]) -> None:
        """Emit per-participant REDUCE_PARTIALs now; defer the exchange and
        the folds into the open fusion group (flushed when the chain
        breaks).  All reductions of one task always share the exchange."""
        participants = tuple(sorted(node_chunks))
        if self._open_red is None:
            self._open_red = dict(participants=participants, members=[],
                                  order_free=self._order_free(task))
        arx = self.allreduce and self._open_red["order_free"]
        for red in task.reductions:
            buf = red.buffer
            self._ownership_map(buf)               # register buffer
            rtid = (task.tid, buf.bid, 1)
            partials: dict[int, Command] = {}
            for n in participants:
                pc = Command(CommandType.REDUCE_PARTIAL, node=n, task=task,
                             buffer=buf, reduction=red,
                             region=buf.full_region, transfer_id=rtid,
                             participants=participants,
                             coll_group=tuple(range(self.num_nodes)),
                             collective=True, allreduce=arx)
                pc.add_dependency(exec_cmds[n], DepKind.TRUE)
                self._add(n, pc)
                new_cmds.append(pc)
                partials[n] = pc
            self._open_red["members"].append(
                dict(task=task, red=red, rtid=rtid, partials=partials))

    def _flush_reductions(self) -> list[Command]:
        """Emit the deferred exchange (one packed allgather for the whole
        fusion group) plus every member's REDUCE_GLOBAL fold."""
        group = self._open_red
        if group is None:
            return []
        self._open_red = None
        out: list[Command] = []
        members = group["members"]
        participants = group["participants"]
        arx = self.allreduce and group["order_free"]
        allnodes = tuple(range(self.num_nodes))
        first = members[0]
        base_tid = (first["task"].tid, first["red"].buffer.bid, 3)
        coll_members = tuple((m["rtid"], m["red"]) for m in members)
        ag_cmds: dict[int, Command] = {}
        if self.num_nodes > 1:
            xtype = (CommandType.COLL_ALLREDUCE if arx
                     else CommandType.COLL_ALLGATHER)
            for n in allnodes:
                ag = Command(xtype, node=n,
                             task=first["task"], buffer=first["red"].buffer,
                             reduction=first["red"], transfer_id=base_tid,
                             participants=participants, coll_group=allnodes,
                             coll_members=coll_members, collective=True,
                             allreduce=arx)
                for m in members:
                    pc = m["partials"].get(n)
                    if pc is not None:
                        ag.add_dependency(pc, DepKind.TRUE)
                if self._last_horizon[n] is not None:
                    ag.add_dependency(self._last_horizon[n], DepKind.SYNC)
                elif not ag.dependencies and self._last_epoch[n] is not None:
                    ag.add_dependency(self._last_epoch[n], DepKind.SYNC)
                self._add(n, ag)
                out.append(ag)
                ag_cmds[n] = ag
        for m in members:
            task, red, rtid = m["task"], m["red"], m["rtid"]
            buf = red.buffer
            full = buf.full_region
            global_cmds = {
                n: Command(CommandType.REDUCE_GLOBAL, node=n, task=task,
                           buffer=buf, reduction=red, region=full,
                           transfer_id=rtid, participants=participants,
                           coll_group=allnodes, collective=True,
                           allreduce=arx)
                for n in allnodes}
            if red.include_current_value:
                self._fetch_missing_grouped(task, buf,
                                            {n: full for n in allnodes},
                                            global_cmds, out)
            for n in allnodes:
                gc = global_cmds[n]
                nst = self._node_buf(n, buf)
                kind = (DepKind.TRUE if red.include_current_value
                        else DepKind.ANTI)
                for sub, writer in nst.last_writers.query(full):
                    gc.add_dependency(writer, kind)
                for rreg, reader in nst.last_readers:
                    gc.add_dependency(reader, DepKind.ANTI)
                if n in m["partials"]:
                    gc.add_dependency(m["partials"][n], DepKind.TRUE)
                if n in ag_cmds:
                    gc.add_dependency(ag_cmds[n], DepKind.TRUE)
                if self._last_horizon[n] is not None:
                    gc.add_dependency(self._last_horizon[n], DepKind.SYNC)
                elif not gc.dependencies and self._last_epoch[n] is not None:
                    gc.add_dependency(self._last_epoch[n], DepKind.SYNC)
                nst.last_writers.update(full, gc)
                nst.last_readers = []
                self._add(n, gc)
                out.append(gc)
            # the combined value is replicated on every node
            self._ownership_map(buf).update(full,
                                            frozenset(range(self.num_nodes)))
        return out

    # -- reductions ------------------------------------------------------
    def _process_reduction(self, task: Task, red: Reduction,
                           node_chunks: dict[int, Box],
                           exec_cmds: dict[int, Command],
                           new_cmds: list[Command]) -> None:
        """Emit per-node REDUCE_PARTIAL + replicated REDUCE_GLOBAL commands.

        The reduction dataflow intentionally violates the one-writer rule:
        every participating node produces a partial for the SAME full-buffer
        region, and every node (participating or not) writes the combined
        result.  Determinism holds because all nodes fold the partials in
        canonical node order and the replicated CDAG assigns identical
        participant sets everywhere.
        """
        buf = red.buffer
        self._ownership_map(buf)                   # register buffer
        rtid = (task.tid, buf.bid, 1)
        participants = tuple(sorted(node_chunks))
        full = buf.full_region

        # phase 1: command objects (no state reads yet)
        partial_cmds: dict[int, Command] = {}
        global_cmds: dict[int, Command] = {}
        for n in participants:
            pc = Command(CommandType.REDUCE_PARTIAL, node=n, task=task,
                         buffer=buf, reduction=red, region=full,
                         transfer_id=rtid, participants=participants,
                         targets=tuple(t for t in range(self.num_nodes)
                                       if t != n))
            pc.add_dependency(exec_cmds[n], DepKind.TRUE)
            partial_cmds[n] = pc
        for n in range(self.num_nodes):
            global_cmds[n] = Command(
                CommandType.REDUCE_GLOBAL, node=n, task=task, buffer=buf,
                reduction=red, region=full, transfer_id=rtid,
                participants=participants)

        # phase 2: include_current_value consumes the previous contents on
        # every node — fetch stale regions BEFORE the result overwrites them
        if red.include_current_value:
            for n in range(self.num_nodes):
                self._fetch_missing(n, buf, full, task, global_cmds[n],
                                    new_cmds)

        # phase 3: local deps + per-node state updates
        for n in range(self.num_nodes):
            gc = global_cmds[n]
            nst = self._node_buf(n, buf)
            kind = (DepKind.TRUE if red.include_current_value
                    else DepKind.ANTI)
            for sub, writer in nst.last_writers.query(full):
                gc.add_dependency(writer, kind)
            for rreg, reader in nst.last_readers:
                gc.add_dependency(reader, DepKind.ANTI)
            if n in partial_cmds:
                pc = partial_cmds[n]
                self._add(n, pc)
                new_cmds.append(pc)
                gc.add_dependency(pc, DepKind.TRUE)
            if self._last_horizon[n] is not None:
                gc.add_dependency(self._last_horizon[n], DepKind.SYNC)
            elif not gc.dependencies and self._last_epoch[n] is not None:
                gc.add_dependency(self._last_epoch[n], DepKind.SYNC)
            nst.last_writers.update(full, gc)
            nst.last_readers = []
            self._add(n, gc)
            new_cmds.append(gc)

        # the combined value is replicated on every node
        self._ownership_map(buf).update(full, frozenset(range(self.num_nodes)))


def generate_cdag(tdag: TaskGraph, num_nodes: int, *,
                  collectives: bool = False,
                  allreduce: bool = True) -> CommandGraphGenerator:
    gen = CommandGraphGenerator(num_nodes, collectives=collectives,
                                allreduce=allreduce)
    for task in tdag.tasks:
        if task.name == "init" and task.ttype == TaskType.EPOCH:
            continue
        gen.process(task)
    # a trailing open fusion group (stream ended without a sync) still
    # needs its exchange: flush it into the per-node command lists
    gen._flush_reductions()
    return gen
