"""Fault injection and failure vocabulary (DESIGN.md §10).

The scheduler core assumes nothing about the wire or its peers beyond what
this module models: a :class:`FaultPlan` is a *deterministic, seeded* chaos
schedule — drop/delay/duplicate/reorder decisions for pilots and payloads,
crash-rank-at-instruction-k and slow-rank — that the ``Communicator`` and
``Executor`` consult at their injection points.  Decisions are a pure hash
of ``(seed, kind, transfer_id, msg_id, attempt)``, all of which are fixed at
compile time, so a chaos schedule is replayable by seed regardless of thread
interleaving.  (The *crash* point counts issued instructions, so its exact
victim may shift between runs — recovery correctness never depends on it.)

The error taxonomy raised by the resilient transport and the watchdog also
lives here, as does :func:`run_with_restarts`, the bounded-restart
supervision loop shared by ``runtime.elastic.ElasticTrainer`` (macro JAX
loop) and ``Runtime.run_supervised`` (scheduler core).  Keeping it here —
dependency-free — lets the core supervise itself without importing the
jax-backed training stack.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Mapping, NamedTuple, Optional, Sequence

_M64 = (1 << 64) - 1


def _mix(*vals: int) -> int:
    """splitmix64-style avalanche over a tuple of ints (order-sensitive).

    Explicit integer mixing instead of Python ``hash()`` — the builtin is
    salted per process for strings and would break cross-run replay.
    """
    x = 0x9E3779B97F4A7C15
    for v in vals:
        v = (v & _M64) * 0xBF58476D1CE4E5B9 & _M64
        v ^= v >> 27
        x = (x ^ v) * 0x94D049BB133111EB & _M64
        x ^= x >> 31
    return x


def _u01(*vals: int) -> float:
    return _mix(*vals) / float(1 << 64)


class WireFate(NamedTuple):
    """The plan's verdict for one delivery attempt of one message."""
    drop: bool
    delay_s: float       # 0.0 = deliver immediately
    duplicate: bool


_OK = WireFate(False, 0.0, False)


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, replayable chaos schedule.

    Wire-fault probabilities apply per *delivery attempt* — a retransmit of a
    dropped message re-rolls with ``attempt+1``, so no message is dropped
    forever.  ``crash`` maps node -> 1-based issued-instruction index at
    which that rank fail-stops silently (no abort broadcast: peers must
    detect it via watchdog + heartbeat staleness).  ``slow`` maps node ->
    seconds added to every kernel/host-task execution on that rank.
    """

    seed: int = 0
    drop: float = 0.0            # P(payload attempt silently dropped)
    delay: float = 0.0           # P(payload delivery delayed)
    delay_s: float = 0.02        # max delay; actual is deterministic in [1/4, 1]x
    duplicate: float = 0.0       # P(an extra copy of the payload is delivered)
    reorder: float = 0.0         # P(payload held briefly so later sends pass it)
    reorder_s: float = 0.002
    pilot_drop: float = 0.0      # pilots are unacked metadata: dropped = lost
    crash: Mapping[int, int] = field(default_factory=dict)
    slow: Mapping[int, float] = field(default_factory=dict)

    # -- queries -------------------------------------------------------------
    def has_wire_faults(self) -> bool:
        return any(p > 0.0 for p in (self.drop, self.delay, self.duplicate,
                                     self.reorder, self.pilot_drop))

    def _key(self, transfer_id: Optional[Sequence], msg_id: Optional[int]) -> tuple:
        tid = tuple(-1 if v is None else int(v)
                    for v in (transfer_id or ()))
        return (self.seed, len(tid), *tid, -1 if msg_id is None else int(msg_id))

    def payload_fate(self, transfer_id, msg_id, attempt: int = 1) -> WireFate:
        if not self.has_wire_faults():
            return _OK
        k = self._key(transfer_id, msg_id) + (attempt,)
        drop = self.drop > 0.0 and _u01(*k, 1) < self.drop
        dup = self.duplicate > 0.0 and _u01(*k, 2) < self.duplicate
        delay_s = 0.0
        if self.delay > 0.0 and _u01(*k, 3) < self.delay:
            delay_s = self.delay_s * (0.25 + 0.75 * _u01(*k, 4))
        elif self.reorder > 0.0 and _u01(*k, 5) < self.reorder:
            delay_s = self.reorder_s
        if not (drop or dup or delay_s):
            return _OK
        return WireFate(drop, delay_s, dup)

    def pilot_dropped(self, transfer_id, msg_id) -> bool:
        return (self.pilot_drop > 0.0
                and _u01(*self._key(transfer_id, msg_id), 6) < self.pilot_drop)

    def crash_point(self, node: int) -> Optional[int]:
        return self.crash.get(node)

    def slow_s(self, node: int) -> float:
        return self.slow.get(node, 0.0)

    def survivors(self) -> "FaultPlan":
        """The plan for a restarted grid: crash faults already fired (they
        are one-shot, like ``ElasticTrainer``'s transient injection); wire
        and slow faults persist."""
        return replace(self, crash={})


# -- failure taxonomy ---------------------------------------------------------
class FaultError(RuntimeError):
    """Base of all transport/execution fault errors."""


class TransportError(FaultError):
    """A reliable send exhausted its retransmit budget without an ack."""


class InjectedCrash(FaultError):
    """Recorded locally by a rank fail-stopped by the fault plan.  Never
    broadcast — a crashed rank is silent; peers must *detect* it."""


class NodeFailure(FaultError):
    """Raised by the watchdog: progress stalled past the deadline.

    Carries the stuck instruction and the peers whose heartbeats went stale,
    so ``wait_epoch`` failures name a culprit instead of timing out blind.
    """

    def __init__(self, node: int, stuck: str, dead_peers: Sequence[int],
                 detail: str = ""):
        self.node = node
        self.stuck = stuck
        self.dead_peers = tuple(dead_peers)
        peers = (f"; suspect dead peer(s) {', '.join(f'N{p}' for p in self.dead_peers)}"
                 if self.dead_peers else "")
        super().__init__(
            f"watchdog on N{node}: no progress, stuck at {stuck}{peers}"
            + (f"; {detail}" if detail else ""))


class PeerAborted(FaultError):
    """Received an EPOCH_ABORT poison broadcast from a failing peer."""

    def __init__(self, node: int, origin: int, dead_peer: Optional[int],
                 instruction: str, cause: str):
        self.node = node
        self.origin = origin
        self.dead_peer = dead_peer
        self.instruction = instruction
        self.cause = cause
        dead = f" (dead peer N{dead_peer})" if dead_peer is not None else ""
        super().__init__(
            f"N{node}: epoch aborted by N{origin}{dead} at {instruction}: {cause}")


class EpochTimeoutError(TimeoutError):
    """``wait_epoch`` deadline expired; message carries the stall report."""


class ExecutionAborted(RuntimeError):
    """Raised by ``Runtime.sync`` on any executor failure.

    Aggregates the *first* error of every failed executor plus the
    communicator's pending-transfer state, so a CI failure is diagnosable
    from the exception text alone.
    """

    def __init__(self, summary: str, failures: Sequence[tuple[int, BaseException]]):
        self.failures = list(failures)
        lines = [summary]
        for node, err in self.failures:
            lines.append(f"  N{node}: {type(err).__name__}: {err}")
        super().__init__("\n".join(lines))


# -- bounded-restart supervision ---------------------------------------------
def run_with_restarts(attempt: Callable[[int], object],
                      on_failure: Callable[[BaseException, int], None],
                      *, max_restarts: int = 3,
                      recoverable: tuple = (RuntimeError, TimeoutError)):
    """Run ``attempt(restarts)`` until it returns, restarting on failure.

    ``on_failure(err, restarts)`` runs between attempts (shrink the grid,
    restore a snapshot, clear one-shot faults).  After ``max_restarts``
    failed recoveries the last error propagates.  Returns
    ``(result, restarts)``.
    """
    restarts = 0
    while True:
        try:
            return attempt(restarts), restarts
        except recoverable as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            on_failure(e, restarts)
