"""Executor: out-of-order instruction dispatch (paper §4.1).

The *out-of-order engine* receives the topologically-ordered instruction
stream from the scheduler together with completion events from the backend,
and selects the next instruction to issue:

* **direct** issue — all dependencies have completed;
* **eager** issue — all *incomplete* dependencies are already pending on the
  same single in-order backend queue; the queue's FIFO semantics then
  guarantee ordering without waiting for completion events.

Receive-type instructions are handed to the per-node ``ReceiveArbiter``
(§4.2) instead of a backend lane; the executor polls the arbiter in its main
loop.  The executor itself does no data processing — it only routes.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Optional

import numpy as np

from .allocation import Allocation
from .backend import Backend, InOrderQueue, WorkItem
from .buffer import AccessMode
from .communicator import Communicator, Payload, ReceiveArbiter
from .faults import (EpochTimeoutError, FaultPlan, InjectedCrash, NodeFailure,
                     PeerAborted)
from .instruction_graph import (AccessorBinding, EpochAbort, Instruction,
                                InstructionType)
from .observability import WAIT_CLASSES, WAIT_DEP, WAIT_OF, WAIT_QUEUE
from .region import Box, Region


class BoundsError(RuntimeError):
    """Raised after a kernel when accesses fell outside the declared region."""


class BufferView:
    """Kernel-facing accessor backed by one contiguous allocation (§3.2).

    Indexing is in *global buffer coordinates*; the view translates to the
    allocation's local frame.  With ``check_bounds`` the view records any
    access outside the range-mapper-declared region and the executor raises
    a :class:`BoundsError` with the offending bounding box after the kernel
    exits (paper §4.4 "Accessor Bounds Checking").
    """

    __slots__ = ("array", "offset", "region", "writable", "check_bounds",
                 "oob_min", "oob_max")

    def __init__(self, array: np.ndarray, alloc: Allocation,
                 binding: AccessorBinding, check_bounds: bool):
        self.array = array
        self.offset = alloc.box.min
        self.region = binding.region
        self.writable = binding.accessor.mode.is_producer
        self.check_bounds = check_bounds
        self.oob_min: Optional[list[int]] = None
        self.oob_max: Optional[list[int]] = None

    # -- box-level access (the fast path used by example kernels) ----------
    def get(self, box: Box) -> np.ndarray:
        self._check(box)
        sl = tuple(slice(a - o, b - o) for a, b, o in
                   zip(box.min, box.max, self.offset))
        return self.array[sl]

    def set(self, box: Box, values) -> None:
        if not self.writable:
            raise PermissionError("write through read-only accessor")
        self._check(box)
        sl = tuple(slice(a - o, b - o) for a, b, o in
                   zip(box.min, box.max, self.offset))
        self.array[sl] = values

    def _check(self, box: Box) -> None:
        if not self.check_bounds:
            return
        if not self.region.contains_box(box):
            if self.oob_min is None:
                self.oob_min, self.oob_max = list(box.min), list(box.max)
            else:
                self.oob_min = [min(a, b) for a, b in zip(self.oob_min, box.min)]
                self.oob_max = [max(a, b) for a, b in zip(self.oob_max, box.max)]

    # -- element access sugar ----------------------------------------------
    def __getitem__(self, idx):
        box = self._idx_box(idx)
        return self.get(box).reshape(self._idx_shape(idx, box))

    def __setitem__(self, idx, values):
        box = self._idx_box(idx)
        self.set(box, np.asarray(values).reshape(box.shape))

    def _idx_box(self, idx) -> Box:
        if not isinstance(idx, tuple):
            idx = (idx,)
        lo, hi = [], []
        for d, i in enumerate(idx):
            if isinstance(i, slice):
                start = 0 if i.start is None else i.start
                stop = (self.offset[d] + self.array.shape[d]) if i.stop is None else i.stop
                lo.append(start)
                hi.append(stop)
            else:
                lo.append(int(i))
                hi.append(int(i) + 1)
        return Box(tuple(lo), tuple(hi))

    @staticmethod
    def _idx_shape(idx, box: Box):
        if not isinstance(idx, tuple):
            idx = (idx,)
        shape = []
        for d, i in enumerate(idx):
            if isinstance(i, slice):
                shape.append(box.shape[d])
        return tuple(shape) if shape else ()


class ReductionView:
    """Kernel-facing reduction output (paper §2.2).

    Wraps the identity-filled accumulator scratch of one device chunk; the
    kernel calls :meth:`contribute` with per-item contribution values (for a
    scalar reduction: any array of contributions).  The runtime owns the
    partial/exchange/combine pipeline — the kernel never sees peer data.
    """

    __slots__ = ("acc", "op")

    def __init__(self, acc: np.ndarray, op):
        self.acc = acc
        self.op = op

    def contribute(self, values) -> None:
        self.op.contribute(self.acc, values)


class Executor:
    """Per-node executor thread harboring the out-of-order engine.

    The engine is a *dependency-counter ready queue*: an instruction moves to
    the ready deque exactly when its unmet-dependency counter hits zero, and
    eager-issue candidates are re-examined only when one of their
    dependencies is issued on a device queue or completes — there is no
    per-iteration rescan of a waiting list.  All wake-up sources (backend
    completions, scheduler submissions, inbound communicator traffic) set the
    completion-sink event, so the main loop blocks instead of polling.
    Completed instructions are retired when a later horizon/epoch completes,
    bounding tracking-structure memory on long runs (§3.5).
    """

    def __init__(self, node: int, num_devices: int, comm: Communicator,
                 *, queues_per_device: int = 2, host_threads: int = 4,
                 check_bounds: bool = False, tracer=None, metrics=None,
                 fault_plan: Optional[FaultPlan] = None,
                 watchdog_timeout: Optional[float] = None,
                 max_inflight_per_tenant: Optional[int] = None,
                 issue_width: Optional[int] = None):
        self.node = node
        # issue-width knob (DESIGN.md §13): cap untagged direct/eager issues
        # per drain pass so one burst cannot monopolize the loop before the
        # next completion/ingest poll; None = unbounded (historical)
        self.issue_width = issue_width
        self.comm = comm
        self.backend = Backend(num_devices, queues_per_device=queues_per_device,
                               host_threads=host_threads)
        self.store: dict[int, np.ndarray] = {}       # allocation id -> ndarray
        self.arbiter = ReceiveArbiter(node, comm, self.store)
        self.check_bounds = check_bounds
        self.tracer = tracer
        # observability (DESIGN.md §11): wait-state attribution + issue-path
        # histograms.  ``_obs`` gates every added stamp/record so that a
        # bare executor (tracer=None, metrics=None) pays nothing.
        self.metrics = metrics
        self._obs = tracer is not None or metrics is not None
        # duck-typed tracer doubles get per-instruction issue() callbacks;
        # the standard Tracer opts out via ``issue_events = False`` (one
        # less lock round-trip on the issue hot path)
        self._issue_tracer = tracer if (
            tracer is not None and getattr(tracer, "issue_events", True)) \
            else None
        # sampled (1-in-N) record capture: the keep/drop decision is a pure
        # function of the iid, so dropped records skip the tracer call
        # entirely — drops are counted locally (this executor's completion
        # path is single-threaded) and flushed at horizon boundaries
        self._rec_sample = (max(1, getattr(tracer, "record_sample", 1))
                            if tracer is not None else 1)
        self._drops_pending = 0
        if metrics is not None:
            p = f"executor.N{node}."
            self._h_issue = metrics.histogram(p + "issue_us")
            self._h_queue = metrics.histogram(p + "wait_queue_us")
            self._h_wait = {c: metrics.histogram(p + f"wait_{c}_us")
                            for c in WAIT_CLASSES if c != WAIT_QUEUE}
        else:
            self._h_issue = self._h_queue = None
            self._h_wait = {}
        self.errors: list[BaseException] = []
        # real materialized bytes per memory id, accounted at ALLOC/FREE
        # execution time (the compile-time model lives in the scheduler's
        # MemoryManager; this is the ground truth the budget must bound).
        # M0 is user-owned and lazily seeded — it has no ALLOC instructions
        # and is deliberately not tracked here.
        self.mem_used: dict[int, int] = {}
        self.mem_peak: dict[int, int] = {}
        self._mem_lock = threading.Lock()

        self._inbox: deque[Instruction] = deque()
        self._inbox_lock = threading.Lock()
        self._registered: dict[int, Instruction] = {}
        self._remaining: dict[int, int] = {}          # iid -> unmet dep count
        self._ready: deque[Instruction] = deque()     # counter hit zero
        self._blocked: dict[int, Instruction] = {}    # unmet deps remain
        self._recheck: deque[Instruction] = deque()   # eager-issue candidates
        self._retire_log: deque[Instruction] = deque()  # registration order
        self._peak_registered = 0
        self._retired_count = 0
        self._issued_on: dict[int, InOrderQueue] = {} # iid -> queue (devices)
        self._completed_epochs: set[int] = set()      # command ids of epochs
        self.horizons_done = 0                        # completed sync instrs
        self.horizon_event = threading.Event()        # set on each completion
        self._epoch_cv = threading.Condition()
        self._done_count = 0
        # ready->submitted dispatch latency; bounded so the stat itself does
        # not grow with program length (retirement bounds everything else)
        self._issue_latency: deque[float] = deque(maxlen=65536)
        # -- multi-tenant serving (core/memo.py, DESIGN.md §12) -----------
        # Instructions tagged with a tenant name are issued from per-tenant
        # ready queues in round-robin order (fair-share interleaving), with
        # ``max_inflight_per_tenant`` bounding how many one tenant may have
        # between admission and completion (admission control).  Untagged
        # instructions (tenant None) keep the original single-queue fast
        # path untouched.  Eager issue bypasses admission (it must follow
        # its in-order queue), so the bound is approximate under eager
        # cascades — acceptable: fairness is a scheduling policy, not a
        # correctness invariant.
        self.max_inflight_per_tenant = max_inflight_per_tenant
        self._tenant_ready: dict[str, deque[Instruction]] = {}
        self._tenant_rr: deque[str] = deque()      # round-robin rotation
        self._tenant_in_rr: set[str] = set()
        self._tenant_count = 0                     # total tenant-ready instrs
        self._tenant_inflight: dict[str, int] = {}
        self._tenant_deferred: dict[str, deque[Instruction]] = {}
        self._deferred_count = 0
        self.tenant_done: dict[str, int] = {}      # per-tenant completions
        # in-flight window tracking (DESIGN.md §13): windows with at least
        # one completed instruction whose closing epoch has not completed;
        # the peak set size is the pipelining depth ``bench_serve`` reports
        self._tenant_windows: dict[str, set[int]] = {}
        self.tenant_window_peak: dict[str, int] = {}
        self._queue_latency_ewma: dict[str, float] = {}
        self._qname_cache: dict[tuple, str] = {}
        self._dispatch = {
            InstructionType.ALLOC: self._exec_alloc,
            InstructionType.FREE: self._exec_free,
            InstructionType.COPY: self._exec_copy,
            InstructionType.SPILL: self._exec_copy,
            InstructionType.RELOAD: self._exec_copy,
            InstructionType.SEND: self._exec_send,
            InstructionType.COLL_SEND: self._exec_coll_send,
            InstructionType.FILL_IDENTITY: self._exec_fill_identity,
            InstructionType.LOCAL_REDUCE: self._exec_local_reduce,
            InstructionType.GLOBAL_REDUCE: self._exec_global_reduce,
            InstructionType.DEVICE_KERNEL: self._exec_kernel,
            InstructionType.HOST_TASK: self._exec_kernel,
        }
        # -- fault model (DESIGN.md §10) ----------------------------------
        self.fault_plan = fault_plan
        self.watchdog_timeout = watchdog_timeout
        self._crash_at = fault_plan.crash_point(node) if fault_plan else None
        self._slow_s = fault_plan.slow_s(node) if fault_plan else 0.0
        self._issued_count = 0
        self.crashed = False
        self.warnings: list[str] = []
        self.leaked_threads = 0
        self._abort = False             # force-exit flag (shutdown fallback)
        self._abort_sent = False        # at most one EPOCH_ABORT broadcast
        self._stop = False
        self._drained = threading.Event()
        comm.add_listener(node, self.backend.sink.event)
        self._thread = threading.Thread(target=self._run, name=f"exec-N{node}",
                                        daemon=True)
        self._thread.start()
        self._watch_stop = threading.Event()
        self._watchdog: Optional[threading.Thread] = None
        if watchdog_timeout is not None:
            self._wd_done = -1
            self._wd_mark = time.monotonic()
            self._watchdog = threading.Thread(
                target=self._watch, name=f"watchdog-N{node}", daemon=True)
            self._watchdog.start()

    # -- scheduler-facing API ----------------------------------------------
    def submit(self, instrs: list[Instruction]) -> None:
        with self._inbox_lock:
            self._inbox.extend(instrs)
        self.backend.sink.event.set()  # wake the loop

    def forget_epoch(self, cid: int) -> None:
        """Drop a completed epoch id once every waiter has seen it.

        A serving process completes an unbounded stream of epochs; the
        serving runtime calls this after its window handle resolves so the
        completed-epoch set stays bounded."""
        with self._epoch_cv:
            self._completed_epochs.discard(cid)

    def wait_epoch(self, cid: int, timeout: float = 60.0) -> None:
        deadline = time.monotonic() + timeout
        with self._epoch_cv:
            while cid not in self._completed_epochs:
                if self.errors:
                    e = self.errors[0]
                    raise RuntimeError(
                        f"executor N{self.node} failed: "
                        f"{type(e).__name__}: {e}") from e
                rem = deadline - time.monotonic()
                if rem <= 0:
                    raise EpochTimeoutError(
                        f"epoch C{cid} not reached on N{self.node}; "
                        + self.stall_report())
                self._epoch_cv.wait(min(rem, 0.05))

    def stall_report(self) -> str:
        """What this executor is stuck on — attached to timeout errors."""
        stuck = next((i for i in self._retire_log if i.state != "done"), None)
        dead = self.comm.stale_peers(self.node, self.watchdog_timeout or 1.0)
        deadtxt = (f"; stale peer heartbeats: {[f'N{p}' for p in dead]}"
                   if dead else "")
        return (f"{len(self._remaining)} instructions unfinished, oldest "
                f"{stuck!r}; arbiter: {self.arbiter.pending_report()}; "
                f"transport: {self.comm.transport_summary()}{deadtxt}")

    def shutdown(self, join_timeout: float = 10.0) -> int:
        """Stop the worker and backend lanes, accounting every thread.

        A failed/crashed executor skips the graceful drain (its blocked work
        would never complete) and takes the abort path directly.  Any thread
        still alive after its join deadline is counted in
        ``leaked_threads`` and recorded as a warning instead of being
        silently ignored.  Returns the leaked-thread count.
        """
        if self.errors or self.crashed:
            self._abort = True
        if (self._drops_pending and self.tracer is not None
                and hasattr(self.tracer, "note_sampled_out")):
            # account sampled-out records dropped after the last sync
            self.tracer.note_sampled_out(self._drops_pending)
            self._drops_pending = 0
        self._stop = True
        self._watch_stop.set()
        self.backend.sink.event.set()
        self._thread.join(timeout=2.0 if self._abort else join_timeout)
        if self._thread.is_alive():
            # graceful drain did not converge (e.g. poisoned dependencies):
            # abort — the loop discards blocked work at its next wake
            self._abort = True
            self.backend.sink.event.set()
            self._thread.join(timeout=2.0)
        leaked = 0
        if self._thread.is_alive():
            leaked += 1
            self.warnings.append(
                f"executor N{self.node}: worker thread failed to join "
                f"(stuck with {len(self._blocked)} blocked instructions)")
        backend_leaked = self.backend.shutdown(
            join_timeout=1.0 if self._abort else 5.0)
        if backend_leaked:
            leaked += backend_leaked
            self.warnings.append(
                f"executor N{self.node}: {backend_leaked} backend lane "
                f"thread(s) failed to join (kernel still running?)")
        if self._watchdog is not None:
            self._watchdog.join(timeout=2.0)
            if self._watchdog.is_alive():
                leaked += 1
                self.warnings.append(
                    f"executor N{self.node}: watchdog thread failed to join")
        self.leaked_threads = leaked
        return leaked

    # -- failure handling (DESIGN.md §10) -------------------------------------
    def _fail(self, err: BaseException, *, broadcast: bool = True,
              dead_peer: Optional[int] = None) -> None:
        """Record a failure, wake epoch waiters NOW, and poison peers."""
        self.errors.append(err)
        with self._epoch_cv:
            self._epoch_cv.notify_all()
        if broadcast and not self._abort_sent and self.comm.num_nodes > 1:
            self._abort_sent = True
            stuck = next((i for i in self._retire_log if i.state != "done"),
                         None)
            self.comm.post_abort(EpochAbort(
                origin=self.node, instruction=repr(stuck) if stuck else "?",
                cause=f"{type(err).__name__}: {err}", dead_peer=dead_peer))

    def _on_abort(self, ab: EpochAbort) -> None:
        """A peer poisoned the epoch: fail fast and drop in-flight receives."""
        if self.tracer is not None and hasattr(self.tracer, "instant"):
            self.tracer.instant(f"N{self.node}.ctrl", "peer_abort",
                                {"origin": ab.origin, "cause": ab.cause})
        self.arbiter.poison(f"abort from N{ab.origin}")
        if not self.errors:
            self._fail(PeerAborted(self.node, ab.origin, ab.dead_peer,
                                   ab.instruction, ab.cause),
                       broadcast=False)

    def _watch(self) -> None:
        """Watchdog: fire when instructions are stuck past the deadline.

        Progress is 'some instruction completed recently'; idle (nothing
        registered, nothing pending) resets the clock.  On fire it names the
        oldest unfinished instruction and the peers whose heartbeats went
        stale, then broadcasts the abort so the whole grid fails within ~1
        round trip instead of the epoch timeout.
        """
        period = max(0.01, min(self.watchdog_timeout / 4.0, 0.25))
        while not self._watch_stop.wait(period):
            if self._stop or self._abort or self.crashed or self.errors:
                continue
            now = time.monotonic()
            if self._done_count != self._wd_done:
                self._wd_done = self._done_count
                self._wd_mark = now
                continue
            busy = bool(self._remaining) or self.arbiter.has_pending()
            if not busy:
                self._wd_mark = now
                continue
            if now - self._wd_mark < self.watchdog_timeout:
                continue
            stuck = next((i for i in self._retire_log if i.state != "done"),
                         None)
            dead = self.comm.stale_peers(self.node, self.watchdog_timeout, now)
            err = NodeFailure(
                self.node, repr(stuck) if stuck else "?", dead,
                detail=(f"no completions for {now - self._wd_mark:.2f}s; "
                        f"arbiter: {self.arbiter.pending_report()}; "
                        f"transport: {self.comm.transport_summary()}"))
            if self.tracer is not None and hasattr(self.tracer, "instant"):
                self.tracer.instant(f"N{self.node}.ctrl", "watchdog_fire",
                                    {"stuck": err.stuck})
            self._fail(err, dead_peer=dead[0] if dead else None)
            return

    # -- main loop -----------------------------------------------------------
    def _run(self) -> None:
        completions: list[Instruction] = []
        comm, node = self.comm, self.node
        while True:
            if self._abort:
                # forced teardown: blocked/poisoned work is discarded
                self._drained.set()
                return
            comm.beat(node)
            progressed = False
            # 0. transport duty cycle: acks in, retransmits out, and any
            # cross-node abort poison (cheap lock-free gates)
            if comm.reliable and comm.has_transport_work(node):
                for terr in comm.pump(node):
                    self._fail(terr)
            if comm.ctrl_box[node]:
                for ab in comm.poll_ctrl(node):
                    self._on_abort(ab)
            # 1. ingest newly scheduled instructions
            with self._inbox_lock:
                fresh = list(self._inbox)
                self._inbox.clear()
            for instr in fresh:
                self._register(instr)
                progressed = True
            # 2. drain backend completions (unblocks ready/eager candidates)
            for tag, err, lat in self.backend.sink.drain():
                if err is not None:
                    self._fail(err)
                self._mark_done(tag, lat)
                progressed = True
            # 3. receive arbitration (woken by communicator listener); only
            # touch the mailbox locks when receives are in flight or inbound
            # traffic is visible
            if (self.arbiter.has_pending()
                    or self.comm.payload_box[self.node]
                    or self.comm.pilot_box[self.node]):
                completions.clear()
                self.arbiter.step(completions)
                for instr in completions:
                    self._mark_done(instr, 0.0)
                    progressed = True
            # 4. issue everything that became ready or eager-eligible
            if self._drain_ready():
                progressed = True
            if self.crashed:
                # fail-stop: no drain, no farewell — peers must detect it
                return
            if (self._stop and not self._ready and not self._tenant_count
                    and not self._deferred_count and not self._blocked
                    and not fresh):
                with self._inbox_lock:
                    empty = not self._inbox
                if empty:
                    self._drained.set()
                    return
            if not progressed:
                # every wake source (sink completions, submit, communicator
                # listener) sets this event; drain() clears it pre-swap
                self.backend.sink.event.wait(0.05)

    # -- registration and issue ----------------------------------------------
    def _register(self, instr: Instruction) -> None:
        unmet = 0
        for dep, _ in instr.dependencies:
            if dep.state != "done":
                unmet += 1
        self._registered[instr.iid] = instr
        if len(self._registered) > self._peak_registered:
            self._peak_registered = len(self._registered)
        self._retire_log.append(instr)
        self._remaining[instr.iid] = unmet
        if unmet == 0:
            t = time.perf_counter()
            if self._obs:
                instr._reg_t = t
            instr._ready_t = t
            if instr.tenant is None:
                self._ready.append(instr)
            else:
                self._enqueue_tenant(instr)
        else:
            if self._obs:
                instr._reg_t = time.perf_counter()
            self._blocked[instr.iid] = instr
            self._recheck.append(instr)     # deps may already sit on one queue

    def _enqueue_tenant(self, instr: Instruction) -> None:
        """Admit (or defer) one ready tenant-tagged instruction."""
        t = instr.tenant
        cap = self.max_inflight_per_tenant
        if cap is not None and self._tenant_inflight.get(t, 0) >= cap:
            self._tenant_deferred.setdefault(t, deque()).append(instr)
            self._deferred_count += 1
            return
        self._tenant_inflight[t] = self._tenant_inflight.get(t, 0) + 1
        instr._admitted = True
        q = self._tenant_ready.get(t)
        if q is None:
            q = self._tenant_ready[t] = deque()
        q.append(instr)
        self._tenant_count += 1
        if t not in self._tenant_in_rr:
            self._tenant_in_rr.add(t)
            self._tenant_rr.append(t)

    def _drain_tenant_ready(self) -> bool:
        """Issue tenant-ready instructions one per tenant per rotation."""
        issued_any = False
        rr = self._tenant_rr
        while self._tenant_count and rr:
            name = rr.popleft()
            q = self._tenant_ready.get(name)
            if not q:
                self._tenant_in_rr.discard(name)
                continue
            instr = q.popleft()
            self._tenant_count -= 1
            if q:
                rr.append(name)
            else:
                self._tenant_in_rr.discard(name)
            self._issue(instr)
            issued_any = True
        return issued_any

    def _drain_ready(self) -> bool:
        """Issue all ready instructions and cascade eager-issue candidates.

        With ``issue_width`` set, at most that many untagged direct/eager
        issues happen per pass; the main loop re-enters immediately (the
        pass reports progress) after polling completions and the inbox.
        Tenant-tagged issue is already self-limited by the round-robin
        rotation and admission control, so it is not charged against the
        width."""
        issued_any = False
        left = self.issue_width if self.issue_width is not None else -1
        while self._ready or self._tenant_count or self._recheck:
            if left == 0:
                break
            while self._ready:
                instr = self._ready.popleft()
                self._issue(instr)                       # direct issue
                issued_any = True
                if left > 0:
                    left -= 1
                    if left == 0:
                        break
            if left == 0:
                break
            if self._tenant_count:
                if self._drain_tenant_ready():
                    issued_any = True
            if self._recheck:
                instr = self._recheck.popleft()
                if instr.iid not in self._blocked:
                    continue
                eager_q = self._eager_queue(instr)
                if eager_q is not None:
                    del self._blocked[instr.iid]
                    instr._ready_t = time.perf_counter()
                    if self._obs:
                        # eager issue serializes behind its still-pending
                        # deps on one in-order queue: blame the last one
                        for dep, _ in instr.dependencies:
                            if dep.state != "done":
                                instr._blame_iid = dep.iid
                                instr._blame_it = dep.itype
                    self._issue(instr, queue=eager_q)    # eager issue
                    issued_any = True
                    if left > 0:
                        left -= 1
        return issued_any

    def _eager_queue(self, instr: Instruction) -> Optional[InOrderQueue]:
        """Eager-issue rule (§4.1): all incomplete deps pending on ONE
        in-order queue; instruction itself targets the same device."""
        if instr.queue[0] != "device":
            return None
        q: Optional[InOrderQueue] = None
        for dep, _ in instr.dependencies:
            if dep.state == "done":
                continue
            dq = self._issued_on.get(dep.iid)
            if dq is None:
                return None          # dep not yet submitted anywhere
            if q is None:
                q = dq
            elif q is not dq:
                return None          # spread over several queues
        if q is None:
            return None
        # same device required: queue name "D<d>.q<i>"
        if not q.name.startswith(f"D{instr.queue[1]}."):
            return None
        return q

    # -- issue routing ---------------------------------------------------------
    def _issue(self, instr: Instruction, queue: Optional[InOrderQueue] = None) -> None:
        if self.crashed:
            return                       # fail-stop: issue nothing further
        if self._crash_at is not None:
            self._issued_count += 1
            if self._issued_count >= self._crash_at:
                # injected fail-stop: recorded locally (for the supervisor),
                # never broadcast — a dead rank does not say goodbye
                self.crashed = True
                self._fail(InjectedCrash(
                    f"N{self.node} fail-stopped at issued instruction "
                    f"#{self._issued_count} ({instr!r})"), broadcast=False)
                return
        instr.state = "issued"
        if instr.tenant is not None and not getattr(instr, "_admitted", False):
            # eager issue skipped admission: account it now so the
            # per-tenant in-flight counter stays balanced at completion
            tn = instr.tenant
            self._tenant_inflight[tn] = self._tenant_inflight.get(tn, 0) + 1
            instr._admitted = True
        t = time.perf_counter()
        self._issue_latency.append(t - instr._ready_t)
        if self._issue_tracer is not None:
            # issue-time visibility (open span): lets live observers see
            # eager issue before the instruction completes; the standard
            # Tracer opts out (spans derive from completion records)
            self._issue_tracer.issue(self.node, instr)
        it = instr.itype
        if it in (InstructionType.RECEIVE, InstructionType.SPLIT_RECEIVE,
                  InstructionType.AWAIT_RECEIVE, InstructionType.GATHER_RECEIVE,
                  InstructionType.COLL_RECV):
            if self._obs:
                instr._start_t = t      # arbiter-handled: no lane dequeue
            self.arbiter.begin(instr)       # completion via arbiter polling
            return
        if it in (InstructionType.HORIZON, InstructionType.EPOCH):
            if self._obs:
                instr._start_t = t
            self._mark_done(instr, 0.0)     # pure graph-sync: complete inline
            return
        # with observability on, the lane thread stamps the dequeue time so
        # queue-wait (lane contention) separates from execution time
        fn = self._run_timed if self._obs else self._dispatch[it]
        item = WorkItem(fn=fn, tag=instr)
        if instr.queue[0] == "device":
            q = self.backend.pick_device_queue(instr.queue[1], preferred=queue)
            self._issued_on[instr.iid] = q
            q.submit(item)
            # dependents blocked only on instructions now pending on q may
            # eager-issue right away (FIFO ordering makes it safe)
            for dep in instr.dependents:
                if dep.iid in self._blocked:
                    self._recheck.append(dep)
        elif it == InstructionType.SEND:
            # comm lane: sends are tiny (mailbox post) — host pool is fine
            self.backend.host_pool.submit(item)
        else:
            self.backend.host_pool.submit(item)

    def _run_timed(self, instr: Instruction) -> None:
        """Backend-lane entry when observability is on: stamp dequeue time
        (start of execution) so queue-wait separates from execution."""
        instr._start_t = time.perf_counter()
        self._dispatch[instr.itype](instr)

    def _mark_done(self, instr: Instruction, latency: float) -> None:
        if instr.state == "done":
            return
        instr.state = "done"
        self._done_count += 1
        self._issued_on.pop(instr.iid, None)
        self._remaining.pop(instr.iid, None)
        qname = self._qname_cache.get(instr.queue)
        if qname is None:
            qname = self._qname_cache[instr.queue] = \
                ".".join(map(str, instr.queue))
        e = self._queue_latency_ewma.get(qname, latency)
        self._queue_latency_ewma[qname] = 0.9 * e + 0.1 * latency
        obs = self._obs
        if obs:
            self._obs_done(instr, qname)
        remaining, blocked = self._remaining, self._blocked
        it = instr.itype
        for dep in instr.dependents:
            rem = remaining.get(dep.iid)
            if rem is None:
                continue
            rem -= 1
            remaining[dep.iid] = rem
            if dep.iid in blocked:
                if rem == 0:
                    del blocked[dep.iid]
                    dep._ready_t = time.perf_counter()
                    if obs:
                        # last-arriving predecessor: scalar blame stamps only
                        # (an object reference would chain the whole history
                        # past retirement)
                        dep._blame_iid = instr.iid
                        dep._blame_it = it
                    if dep.tenant is None:
                        self._ready.append(dep)
                    else:
                        self._enqueue_tenant(dep)
                else:
                    self._recheck.append(dep)   # one fewer scattered dep
        tn = instr.tenant
        if tn is not None:
            self.tenant_done[tn] = self.tenant_done.get(tn, 0) + 1
            w = instr.window
            if w is not None:
                ws = self._tenant_windows.setdefault(tn, set())
                if it == InstructionType.EPOCH:
                    ws.discard(w)
                else:
                    ws.add(w)
                    if len(ws) > self.tenant_window_peak.get(tn, 0):
                        self.tenant_window_peak[tn] = len(ws)
            if getattr(instr, "_admitted", False):
                n = self._tenant_inflight.get(tn, 0) - 1
                self._tenant_inflight[tn] = n if n > 0 else 0
            dq = self._tenant_deferred.get(tn)
            if dq:
                cap = self.max_inflight_per_tenant
                while dq and (cap is None
                              or self._tenant_inflight.get(tn, 0) < cap):
                    self._deferred_count -= 1
                    self._enqueue_tenant(dq.popleft())
        if it == InstructionType.EPOCH and instr.command is not None:
            with self._epoch_cv:
                self._completed_epochs.add(instr.command.cid)
                self._epoch_cv.notify_all()
        if it in (InstructionType.HORIZON, InstructionType.EPOCH):
            self._retire_before(instr)
            self.horizons_done += 1
            if obs:
                self._sample_lag()
            self.horizon_event.set()    # unblock a throttled scheduler

    def _obs_done(self, instr: Instruction, qname: str) -> None:
        """Wait-state attribution at completion (DESIGN.md §11.2).

        ``t_reg -> t_ready -> t_start -> t_done``: the issue latency
        ``t_start - t_reg`` decomposes exactly into the classified pending
        wait plus the queue wait, so the per-instruction histograms sum to
        the measured latency by construction.
        """
        t_done = time.perf_counter()
        t_reg = getattr(instr, "_reg_t", None)
        if t_reg is None:
            return                       # submitted before this executor
        t_ready = getattr(instr, "_ready_t", t_reg)
        t_start = getattr(instr, "_start_t", t_ready)
        if t_start < t_ready:
            t_start = t_ready           # lane stamped before the drain raced
        cls = WAIT_OF.get(getattr(instr, "_blame_it", None), WAIT_DEP)
        if self.metrics is not None:
            pending = (t_ready - t_reg) * 1e6
            queue_w = (t_start - t_ready) * 1e6
            self._h_issue.observe(pending + queue_w)
            self._h_wait[cls].observe(pending)
            self._h_queue.observe(queue_w)
        if self.tracer is not None:
            rs = self._rec_sample
            if (rs > 1 and instr.iid % rs
                    and self._issue_tracer is None):
                # standard Tracer (no issue() events): nothing to close in
                # its open-span table, so the dropped record needs no call
                self._drops_pending += 1
                return
            lane = getattr(instr, "trace_lane", None) or f"N{self.node}.{qname}"
            self.tracer.record(
                self.node, instr, lane, t_reg=t_reg, t_ready=t_ready,
                t_start=t_start, t_done=t_done, wait_cls=cls,
                blame_iid=getattr(instr, "_blame_iid", None))

    def _sample_lag(self) -> None:
        """Scheduler-lag time series, sampled at each horizon/epoch: ready-
        queue depth, in-flight count and retirement progress as counter
        tracks (lookahead occupancy and horizon lag sample scheduler-side)."""
        n = self.node
        inflight = float(len(self._remaining))
        ready = float(len(self._ready))
        m = self.metrics
        if m is not None:
            m.gauge(f"executor.N{n}.inflight", inflight)
            m.gauge(f"executor.N{n}.ready_depth", ready)
            m.gauge(f"executor.N{n}.retired", float(self._retired_count))
        tr = self.tracer
        if tr is not None:
            tr.counter(f"executor.N{n}.inflight", inflight)
            tr.counter(f"executor.N{n}.ready_depth", ready)
            if self._drops_pending and hasattr(tr, "note_sampled_out"):
                tr.note_sampled_out(self._drops_pending)
                self._drops_pending = 0

    # -- horizon-based retirement (§3.5) --------------------------------------
    def _retire_before(self, sync_instr: Instruction) -> None:
        """Drop tracking state for everything registered before ``sync_instr``.

        A horizon/epoch instruction transitively depends on every instruction
        submitted before it, so its completion proves all of them are done.
        Clearing their dependency lists breaks the chain of references that
        would otherwise keep the whole execution history alive.
        """
        log = self._retire_log
        while log and log[0] is not sync_instr and log[0].state == "done":
            old = log.popleft()
            self._registered.pop(old.iid, None)
            self._remaining.pop(old.iid, None)
            self._retired_count += 1
            old.dependencies = []
            old.dependents = []

    # -- instruction semantics ---------------------------------------------------
    def _arr(self, alloc: Allocation) -> np.ndarray:
        """Backing array; lazily seeds M0 allocations with user init data."""
        arr = self.store.get(alloc.aid)
        if arr is None:
            init = getattr(alloc, "initial_data", None)
            if init is None:
                raise KeyError(f"allocation {alloc} not materialized on N{self.node}")
            arr = self.store[alloc.aid] = np.array(init, copy=True)
        return arr

    def _account(self, mid: int, delta: int) -> None:
        with self._mem_lock:
            n = self.mem_used.get(mid, 0) + delta
            self.mem_used[mid] = n
            if n > self.mem_peak.get(mid, 0):
                self.mem_peak[mid] = n
        if self.tracer is not None:
            self.tracer.counter(f"N{self.node}.M{mid}.bytes", float(n))

    def _exec_alloc(self, instr: Instruction) -> None:
        a = instr.allocation
        arr = np.empty(a.box.shape, dtype=np.dtype(a.dtype))
        self.store[a.aid] = arr
        self._account(a.mid, arr.nbytes)

    def _exec_free(self, instr: Instruction) -> None:
        a = instr.allocation
        arr = self.store.pop(a.aid, None)
        if arr is not None:
            self._account(a.mid, -arr.nbytes)

    def _exec_copy(self, instr: Instruction) -> None:
        src, dst, box = instr.src_alloc, instr.dst_alloc, instr.copy_box
        sarr, darr = self._arr(src), self._arr(dst)
        ssl = tuple(slice(a - o, b - o) for a, b, o in
                    zip(box.min, box.max, src.box.min))
        dsl = tuple(slice(a - o, b - o) for a, b, o in
                    zip(box.min, box.max, dst.box.min))
        darr[dsl] = sarr[ssl]

    def _exec_send(self, instr: Instruction) -> None:
        alloc, box = instr.recv_alloc, instr.send_box
        arr = self._arr(alloc)
        sl = tuple(slice(a - o, b - o) for a, b, o in
                   zip(box.min, box.max, alloc.box.min))
        self.comm.isend(instr.dest, Payload(
            source=self.node, msg_id=instr.msg_id,
            transfer_id=instr.transfer_id, box=box, data=arr[sl].copy()))

    def _exec_coll_send(self, instr: Instruction) -> None:
        """One packed collective round message: every fragment is copied out
        of its source allocation and shipped in a single payload, so the
        message count of a round is what the schedule says it is (real byte
        accounting happens in ``Communicator.isend``)."""
        frags: list[tuple] = []
        for f in instr.coll_frags:
            arr = self._arr(f.alloc)
            if f.box is not None:
                sl = tuple(slice(a - o, b - o) for a, b, o in
                           zip(f.box.min, f.box.max, f.alloc.box.min))
                frags.append((f.key, arr[sl].copy()))
            elif f.srange is not None:       # allreduce slot-range fragment
                lo, hi = f.srange
                frags.append((f.key, arr[lo:hi].copy()))
            else:
                frags.append((f.key, arr[f.slot].copy()))
        self.comm.isend(instr.dest, Payload(
            source=self.node, msg_id=instr.msg_id,
            transfer_id=instr.transfer_id, fragments=frags))

    def _exec_fill_identity(self, instr: Instruction) -> None:
        red = instr.reduction
        arr = self._arr(instr.allocation)
        arr[...] = red.op.identity_acc(arr.shape, red.buffer.dtype)

    def _exec_local_reduce(self, instr: Instruction) -> None:
        """Fold the device partials into this node's partial accumulator.

        Models a fused D2H + combine step; on a real backend this is a small
        device reduction kernel plus one staging copy (Celerity folds on
        device 0) — the combine-tree shape is identical.
        """
        red = instr.reduction
        op = red.op
        if instr.slot_range is not None:
            # allreduce fold-on-receive: fold the landed slot-range
            # fragment into the flat accumulator in place (the combine is
            # order-free, so the halving tree never changes a bit)
            lo, hi = instr.slot_range
            dst = self._arr(instr.dst_alloc)
            src = self._arr(instr.reduce_srcs[0])
            dst[lo:hi] = op.combine(dst[lo:hi], src) if instr.accumulate \
                else src
            return
        acc = None
        for src in instr.reduce_srcs:
            arr = self._arr(src)
            acc = arr.copy() if acc is None else op.combine(acc, arr)
        if acc is None:
            acc = op.identity_acc(red.buffer.shape, red.buffer.dtype)
        if instr.dst_slot is not None:   # collective mode: own staging slot
            self._arr(instr.dst_alloc)[instr.dst_slot] = acc
        else:
            # destination may be the buffer-shaped node partial or the
            # allreduce-mode flat slot-space accumulator
            darr = self._arr(instr.dst_alloc)
            darr[...] = acc.reshape(darr.shape)

    def _exec_global_reduce(self, instr: Instruction) -> None:
        """Fold all rank partials in canonical node order into the buffer.

        ``participants`` is the replicated-deterministic fold order; with the
        exact-sum accumulator the result is additionally partition
        independent (see reduction.py).  ``include_current`` lifts the
        buffer's previous (replicated) contents into accumulator space and
        folds them in exactly once, after the partials.
        """
        red = instr.reduction
        op, buf = red.op, red.buffer
        gather_arr = (self._arr(instr.src_alloc)
                      if instr.src_alloc is not None else None)
        if instr.prefolded:
            # allreduce mode: the flat accumulator already holds the fully
            # folded value for every slot — lift/finalize only
            acc = gather_arr.reshape(buf.shape)
        else:
            own = (self._arr(instr.reduce_srcs[0])
                   if instr.reduce_srcs else None)
            acc = None
            for s in instr.participants:
                if instr.slot_all:      # collective mode: own slot included
                    part = gather_arr[s]
                else:
                    part = own if s == self.node else gather_arr[s]
                acc = part.copy() if acc is None else op.combine(acc, part)
            if acc is None:                  # no participants: identity
                acc = op.identity_acc(buf.shape, buf.dtype)
        dst = instr.dst_alloc
        darr = self._arr(dst)
        box = buf.full_box
        sl = tuple(slice(a - o, b - o) for a, b, o in
                   zip(box.min, box.max, dst.box.min))
        if instr.include_current:
            acc = op.combine(acc, op.lift(darr[sl], buf.dtype))
        darr[sl] = op.finalize(acc, buf.dtype)

    def _exec_kernel(self, instr: Instruction) -> None:
        if self._slow_s:
            time.sleep(self._slow_s)     # injected straggler (fault plan)
        views = []
        for b in instr.bindings:
            arr = self._arr(b.allocation)
            views.append(BufferView(arr, b.allocation, b, self.check_bounds))
        for rb in instr.red_bindings:
            views.append(ReductionView(self._arr(rb.allocation),
                                       rb.reduction.op))
        if instr.kernel_fn is not None:
            instr.kernel_fn(instr.chunk, *views)
        if self.check_bounds:
            for v, b in zip(views, instr.bindings):
                if v.oob_min is not None:
                    raise BoundsError(
                        f"kernel '{instr.name}' accessed "
                        f"{Box(tuple(v.oob_min), tuple(v.oob_max))} outside "
                        f"declared region {b.region} of buffer "
                        f"{b.accessor.buffer.name}")

    # -- introspection -------------------------------------------------------
    def straggler_report(self) -> dict[str, float]:
        """Per-queue EWMA completion latency (straggler mitigation input)."""
        return dict(self._queue_latency_ewma)
