"""Schedule sanitizer: static verification of lowered instruction graphs.

Every hazard the runtime must respect is an explicit edge in the IDAG, so
race freedom, lifetime safety, communication matching, deadlock freedom and
the compile-time budget model are all decidable by pure graph analysis —
before, or concurrently with, execution (DESIGN.md §14).

The verifier consumes *snapshots* of instruction windows taken at submit
time (the executor rebinds ``dependencies`` when it retires instructions,
so the dependency lists must be copied before submission).  Four check
families run over the snapshots:

``race``
    Every conflicting access pair (at least one producer, overlapping
    regions, same allocation) must be ordered by a happens-before path.
    Reachability is computed with per-partition bitsets (Python ints), so
    the pair check is one AND.  Reduction ("red") accesses are mutually
    exempt — the one-writer exception for commutative accumulation.
``lifetime``
    Accesses fall inside their allocation's [ALLOC, FREE] interval on a
    happens-before path; no double-free; no free-before-alloc; every
    scratch ALLOC is balanced by a FREE (leak detection).  The check
    naturally covers recycled free-pool physicals: renaming reuses the
    *same* ``Allocation`` object, so hazard wiring between lives is
    verified as ordinary same-allocation conflict ordering.
``comm``
    Per-node streams are merged on transfer ids: every push SEND matches
    exactly one RECEIVE/SPLIT_RECEIVE whose region contains the sent box,
    gather SENDs match GATHER_RECEIVE source slots 1:1, COLL_SEND /
    COLL_RECV pair 1:1 per (transfer id, source, dest) with equal fragment
    key sets, pilots biject with sends, and the merged graph plus
    send→receive wait edges is acyclic (Kahn; a residual cycle is reported
    with its member instructions).
``budget``
    An emission-order replay of ALLOC/FREE byte deltas must reproduce the
    peak the compile-time :class:`MemoryManager` model promised, and a
    FREE emitted before an ALLOC in the same budgeted memory must be on a
    happens-before path to it (the eager-reuse ordering PR 9's drain bug
    violated).

Partitioning: streams are split at sync instructions (every instruction
happens-before the next HORIZON/EPOCH because sync collects the whole
undominated frontier, and every later instruction happens-after it through
the producer re-anchoring at compaction), so cross-partition pairs are
ordered by construction and only intra-partition pairs need bitsets.

A verifier that passes vacuously is worse than none, so this module also
ships the mutation self-test harness (:func:`mutate_one`,
:func:`run_mutation_campaign`): a seeded fuzzer plants exactly one defect
in a known-good graph — deleted/retargeted dependency edge, unbalanced
ALLOC/FREE, duplicated FREE, dropped collective fragment key, retargeted
send, dropped pilot — and the campaign asserts the sanitizer reports it
*and* names the mutated instruction.
"""

from __future__ import annotations

import random
import threading
import time
from bisect import bisect_left, bisect_right
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional, Sequence

from .instructions import Instruction, InstructionType, Pilot
from .region import Region
from .task_graph import DepKind

_IT = InstructionType
_RECV_TYPES = (_IT.RECEIVE, _IT.SPLIT_RECEIVE)
_SYNC_TYPES = (_IT.HORIZON, _IT.EPOCH)


def _conflict(m1: str, m2: str) -> bool:
    """Two access modes conflict unless both read or both reduce."""
    if m1 == "r" and m2 == "r":
        return False
    if m1 == "red" and m2 == "red":
        return False
    return True


@dataclass(frozen=True)
class VerificationIssue:
    """One invariant violation, naming the instructions involved."""

    kind: str                     # race | lifetime | leak | comm | deadlock | budget
    node: Optional[int]           # node the defect was observed on (None: cross-node)
    instrs: tuple[int, ...]       # iids of the instructions involved
    detail: str

    def __str__(self) -> str:
        where = f"N{self.node}" if self.node is not None else "cross-node"
        who = ",".join(f"I{i}" for i in self.instrs) or "-"
        return f"[{self.kind}] {where} {who}: {self.detail}"


@dataclass
class VerificationReport:
    """Aggregate result of a verification pass."""

    issues: list[VerificationIssue] = field(default_factory=list)
    instructions: int = 0
    windows: int = 0
    pairs_checked: int = 0
    elapsed_us: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.issues

    def check(self) -> None:
        if self.issues:
            raise VerificationError(self.issues)


class VerificationError(RuntimeError):
    """Raised when verification finds invariant violations."""

    def __init__(self, issues: Sequence[VerificationIssue]):
        self.issues = list(issues)
        head = "; ".join(str(i) for i in self.issues[:3])
        more = f" (+{len(self.issues) - 3} more)" if len(self.issues) > 3 else ""
        super().__init__(
            f"schedule verification failed, {len(self.issues)} issue(s): {head}{more}")


class _Snap:
    """Submit-time snapshot of one instruction (deps copied before submit)."""

    __slots__ = ("instr", "deps", "_acc")

    def __init__(self, instr: Instruction):
        self.instr = instr
        self.deps = [(d.iid, k) for d, k in instr.dependencies]
        self._acc = None

    def accesses(self):
        if self._acc is None:
            self._acc = self.instr.accesses()
        return self._acc

    def __repr__(self):
        return f"snap({self.instr!r})"


class ScheduleVerifier:
    """Incremental verifier over captured instruction windows.

    ``mode="final"`` runs every check family at :meth:`finalize` (called at
    each sync point), partitioned at sync boundaries so reachability
    bitsets stay small.  ``mode="window"`` additionally runs the bitset
    race/lifetime check per submitted window, concurrently with its
    execution, on a dedicated verifier worker thread (the scheduler thread
    only pays for the capture — finalize barriers on the worker); finalize
    covers the linear cross-window lifetime checks plus comm/deadlock/
    budget.  Window mode
    does not check cross-window races within one sync partition — that gap
    is closed by final mode and documented in DESIGN.md §14.

    Captured snapshots pin instructions (and their closures) for the run's
    lifetime, which defeats executor-side retirement; verification is a
    debugging/CI configuration, not a production default.
    """

    def __init__(self, num_nodes: int, *, mode: str = "final",
                 metrics=None, budgets: Optional[dict] = None):
        if mode not in ("final", "window"):
            raise ValueError(f"verify mode must be 'final' or 'window', got {mode!r}")
        self.num_nodes = num_nodes
        self.mode = mode
        self.metrics = metrics
        self.budgets = dict(budgets or {})
        self._lock = threading.Lock()
        self.streams: list[list[_Snap]] = [[] for _ in range(num_nodes)]
        self.pilots: list[Pilot] = []
        self.issues: list[VerificationIssue] = []
        self.windows = 0
        self.pairs_checked = 0
        # persistent per-node lifetime / budget state (advanced at finalize)
        self._cursor = [0] * num_nodes
        self._pilot_cursor = 0
        self._alloc_seen: list[dict] = [dict() for _ in range(num_nodes)]
        self._freed: list[dict] = [dict() for _ in range(num_nodes)]
        self._used: list[dict] = [dict() for _ in range(num_nodes)]
        self._replay_peak: list[dict] = [dict() for _ in range(num_nodes)]
        # window mode: checks run on a dedicated worker thread so the
        # scheduler thread only pays for the capture — otherwise the next
        # window's lowering serializes behind the previous window's
        # verification and the check lands on the issue critical path.  The
        # worker is event-driven over per-node cursors (set() on an already
        # -set Event is a flag check, so a burst of windows costs one wake)
        self._wv_event: Optional[threading.Event] = None
        self._wv_cursor = [0] * num_nodes
        self._wv_flush: list[threading.Event] = []
        if mode == "window":
            self._wv_event = threading.Event()
            threading.Thread(target=self._window_worker,
                             name="verify-window", daemon=True).start()

    # ---------------------------------------------------------------- capture

    def capture(self, node: int, instrs: Sequence[Instruction]) -> tuple[int, int]:
        """Snapshot a window before it is handed to the executor."""
        with self._lock:
            stream = self.streams[node]
            lo = len(stream)
            stream.extend(_Snap(i) for i in instrs)
            self.windows += 1
            return (lo, len(stream))

    def capture_pilots(self, pilots: Iterable[Pilot]) -> None:
        with self._lock:
            self.pilots.extend(pilots)

    # ---------------------------------------------------------- window checks

    def verify_window(self, node: int, span: tuple[int, int]) -> None:
        """Mark one submitted window for race/lifetime checking (window
        mode).  Runs asynchronously on the verifier worker thread; issues
        surface at the next :meth:`finalize`/:meth:`check`."""
        if self._wv_event is not None:
            self._wv_event.set()
        else:
            self._verify_window_sync(node, span)

    def _window_worker(self) -> None:
        while True:
            self._wv_event.wait()
            self._wv_event.clear()
            with self._lock:
                spans = [(n, self._wv_cursor[n], len(self.streams[n]))
                         for n in range(self.num_nodes)]
                for n, _lo, hi in spans:
                    self._wv_cursor[n] = hi
                flush = self._wv_flush
                self._wv_flush = []
            for n, lo, hi in spans:
                if hi > lo:
                    # backlogged windows per node are contiguous in stream
                    # order, so checking the whole unverified range widens the
                    # partition — a superset of the pairs the individual
                    # per-window checks would cover
                    self._verify_window_sync(n, (lo, hi))
            for ev in flush:
                ev.set()

    def _flush_windows(self) -> None:
        """Wait until every captured window has been checked (finalize
        barrier)."""
        if self._wv_event is None:
            return
        done = threading.Event()
        with self._lock:
            self._wv_flush.append(done)
        self._wv_event.set()
        done.wait(timeout=120.0)

    def _verify_window_sync(self, node: int, span: tuple[int, int]) -> None:
        t0 = time.perf_counter()
        issues = self._span_hb_checks(node, span[0], span[1])
        self.issues.extend(issues)
        dt = (time.perf_counter() - t0) * 1e6
        if self.metrics is not None:
            self.metrics.observe("verify.window_us", dt)
            self.metrics.counter("verify.windows")
            if issues:
                self.metrics.counter("verify.issues", len(issues))

    # ------------------------------------------------------------- final pass

    def finalize(self, peaks: Optional[Sequence[dict]] = None) -> VerificationReport:
        """Verify everything captured since the previous finalize.

        ``peaks`` is the per-node compile-time peak model
        (``IdagGenerator.mem.peak``) to replay against; omit it when the
        captured stream is not charged to a fresh model (memo replay).
        """
        self._flush_windows()
        t0 = time.perf_counter()
        new: list[VerificationIssue] = []
        with self._lock:
            spans = [(n, self._cursor[n], len(self.streams[n]))
                     for n in range(self.num_nodes)]
            pilots = self.pilots[self._pilot_cursor:]
            self._pilot_cursor = len(self.pilots)
            for n, lo, hi in spans:
                self._cursor[n] = hi
        for n, lo, hi in spans:
            if self.mode == "final":
                new.extend(self._span_hb_checks(n, lo, hi))
            new.extend(self._lifetime_linear(n, lo, hi))
        if peaks is not None:
            new.extend(self._budget_compare(peaks))
        wait_edges = self._comm_matching(spans, pilots, new)
        new.extend(self._deadlock(spans, wait_edges))
        self.issues.extend(new)
        dt = (time.perf_counter() - t0) * 1e6
        if self.metrics is not None:
            self.metrics.observe("verify.final_us", dt)
            if new:
                self.metrics.counter("verify.issues", len(new))
        total = sum(len(s) for s in self.streams)
        return VerificationReport(issues=list(self.issues), instructions=total,
                                  windows=self.windows,
                                  pairs_checked=self.pairs_checked, elapsed_us=dt)

    def check(self) -> None:
        """Raise :class:`VerificationError` if any issue has been found."""
        if self.issues:
            raise VerificationError(self.issues)

    # ----------------------------------------------------- happens-before core

    @staticmethod
    def _reach(snaps: Sequence[_Snap]) -> tuple[dict, list[int]]:
        """Ancestor bitsets over one partition (deps point backwards)."""
        pos = {s.instr.iid: i for i, s in enumerate(snaps)}
        reach: list[int] = []
        for i, s in enumerate(snaps):
            r = 1 << i
            for diid, _k in s.deps:
                j = pos.get(diid)
                if j is not None and j < i:
                    r |= reach[j]
            reach.append(r)
        return pos, reach

    def _span_hb_checks(self, node: int, lo: int, hi: int) -> list[VerificationIssue]:
        """Race + intra-partition lifetime ordering over ``stream[lo:hi]``.

        Dependencies on instructions outside the span are treated as
        satisfied (they point at earlier partitions, which are ordered
        before everything here by the sync-barrier construction).
        """
        snaps = self.streams[node][lo:hi]
        if not snaps:
            return []
        issues: list[VerificationIssue] = []
        pos, reach = self._reach(snaps)
        bit = [1 << i for i in range(len(snaps))]

        def hb(a: int, b: int) -> bool:
            return bool(reach[b] & bit[a]) if a <= b else False

        # group accesses by allocation; an aid may have several [ALLOC, FREE]
        # *lives* within one span (memo replay re-opens template allocations
        # once per replayed window), so ALLOC/FREE indices are kept as lists
        by_alloc: dict[int, list] = {}
        allocs: dict[int, list[int]] = {}     # aid -> snap indices of ALLOCs
        frees: dict[int, list[int]] = {}      # aid -> snap indices of FREEs
        alloc_objs: dict[int, object] = {}
        for i, s in enumerate(snaps):
            it = s.instr.itype
            if it is _IT.ALLOC:
                allocs.setdefault(s.instr.allocation.aid, []).append(i)
                alloc_objs[s.instr.allocation.aid] = s.instr.allocation
            elif it is _IT.FREE:
                frees.setdefault(s.instr.allocation.aid, []).append(i)
                alloc_objs[s.instr.allocation.aid] = s.instr.allocation
            else:
                for a, reg, m in s.accesses():
                    by_alloc.setdefault(a.aid, []).append((i, reg, m))
                    alloc_objs[a.aid] = a

        # race freedom: conflicting overlapping pairs need a path.  Access
        # lists are in snap-index order, so for a pair (x, y) with x before
        # y only hb(x, y) can hold (deps point backwards) — one bitset AND,
        # checked before the (expensive) region-overlap test.  The only
        # non-conflicting mode pairs are r/r and red/red (the one-writer
        # reduction exception); everything else has a producer.
        pairs = 0
        for aid, accs in by_alloc.items():
            if len(accs) < 2:
                continue
            for y, (iy, ry, my) in enumerate(accs):
                benign = my if (my == "r" or my == "red") else None
                ry_overlaps = ry.overlaps
                reach_y = reach[iy]
                for ix, rx, mx in accs[:y]:
                    if ix == iy or mx == benign:
                        continue
                    pairs += 1
                    if reach_y & bit[ix]:
                        continue
                    if not ry_overlaps(rx):
                        continue
                    a, b = snaps[ix].instr, snaps[iy].instr
                    issues.append(VerificationIssue(
                        "race", node, (a.iid, b.iid),
                        f"unordered {mx}/{my} overlap on {alloc_objs[aid]!r}: "
                        f"{a!r} vs {b!r} — missing happens-before edge "
                        f"I{a.iid}->I{b.iid}"))
        self.pairs_checked += pairs

        # lifetime ordering within the partition: every access must be on a
        # path after the nearest preceding ALLOC of its aid and before the
        # nearest following FREE; consecutive lives must be serialized
        # (memo replay windows share template Allocation objects, so window
        # k+1's re-ALLOC must not overtake window k's FREE)
        for aid in set(by_alloc) | set(frees):
            al = allocs.get(aid, [])
            fl = frees.get(aid, [])
            for i, _reg, _m in by_alloc.get(aid, ()):
                j = bisect_right(al, i) - 1
                if j >= 0 and not hb(al[j], i):
                    issues.append(VerificationIssue(
                        "lifetime", node,
                        (snaps[al[j]].instr.iid, snaps[i].instr.iid),
                        f"access {snaps[i].instr!r} not ordered after ALLOC "
                        f"of {alloc_objs[aid]!r}"))
                j = bisect_left(fl, i)
                if j < len(fl) and not hb(i, fl[j]):
                    issues.append(VerificationIssue(
                        "lifetime", node,
                        (snaps[i].instr.iid, snaps[fl[j]].instr.iid),
                        f"use-after-free: {snaps[i].instr!r} not ordered "
                        f"before FREE of {alloc_objs[aid]!r}"))
            for fi in fl:
                j = bisect_right(al, fi) - 1
                if j >= 0 and not hb(al[j], fi):
                    issues.append(VerificationIssue(
                        "lifetime", node,
                        (snaps[al[j]].instr.iid, snaps[fi].instr.iid),
                        f"FREE not ordered after ALLOC of {alloc_objs[aid]!r}"))
            for ai in al:
                j = bisect_left(fl, ai) - 1
                if j >= 0 and not hb(fl[j], ai):
                    issues.append(VerificationIssue(
                        "lifetime", node,
                        (snaps[fl[j]].instr.iid, snaps[ai].instr.iid),
                        f"re-allocation {snaps[ai].instr!r} not ordered after "
                        f"previous life's FREE of {alloc_objs[aid]!r}"))

        # budget ordering: an eager-reuse FREE emitted before a later ALLOC in
        # the same budgeted memory must be on a path to it (else the model's
        # peak is a lie at runtime — the PR 9 drain-ordering bug shape)
        if self.budgets:
            free_by_mid: dict = {}
            for aid, fl in frees.items():
                mid = alloc_objs[aid].mid
                if mid in self.budgets:
                    free_by_mid.setdefault(mid, []).extend(fl)
            for aid, al in allocs.items():
                mid = alloc_objs[aid].mid
                for ai in al:
                    for fi in free_by_mid.get(mid, ()):
                        if fi < ai and not hb(fi, ai):
                            issues.append(VerificationIssue(
                                "budget", node,
                                (snaps[fi].instr.iid, snaps[ai].instr.iid),
                                f"eager reuse unordered: FREE "
                                f"{snaps[fi].instr!r} must happen-before "
                                f"ALLOC {snaps[ai].instr!r} in budgeted "
                                f"memory {mid}"))
        return issues

    # -------------------------------------------------- linear lifetime pass

    def _lifetime_linear(self, node: int, lo: int, hi: int) -> list[VerificationIssue]:
        """Cross-partition lifetime + budget replay (O(n), persistent maps).

        Emission order is a topological order, so life alternation is
        checkable linearly: an aid is *live* between ALLOC and FREE, may be
        re-opened by a later ALLOC (memo replay re-opens template
        allocations once per window — the hb ordering of re-opens is
        checked in :meth:`_span_hb_checks`), and any FREE or access while
        closed is a double-free / use-after-free no edge can repair (edges
        only point backwards).
        """
        issues: list[VerificationIssue] = []
        live = self._alloc_seen[node]     # aid -> (alloc_iid, persistent, a)
        closed = self._freed[node]        # aid -> iid of the FREE that closed it
        used = self._used[node]
        peak = self._replay_peak[node]
        for s in self.streams[node][lo:hi]:
            i = s.instr
            it = i.itype
            if it is _IT.ALLOC:
                a = i.allocation
                if a.aid in live:
                    issues.append(VerificationIssue(
                        "lifetime", node, (live[a.aid][0], i.iid),
                        f"duplicate ALLOC for live {a!r}"))
                closed.pop(a.aid, None)   # re-opened: a new life begins
                live[a.aid] = (i.iid, bool(i.persistent), a)
                used[a.mid] = used.get(a.mid, 0) + a.nbytes()
                if used[a.mid] > peak.get(a.mid, 0):
                    peak[a.mid] = used[a.mid]
            elif it is _IT.FREE:
                a = i.allocation
                if a.aid in live:
                    live.pop(a.aid)
                    closed[a.aid] = i.iid
                    used[a.mid] = used.get(a.mid, 0) - a.nbytes()
                elif a.aid in closed:
                    issues.append(VerificationIssue(
                        "lifetime", node, (closed[a.aid], i.iid),
                        f"double-free of {a!r}"))
                else:
                    issues.append(VerificationIssue(
                        "lifetime", node, (i.iid,),
                        f"FREE of never-allocated {a!r}"))
            else:
                for a, _reg, _m in s.accesses():
                    if a.aid in closed:
                        issues.append(VerificationIssue(
                            "lifetime", node, (closed[a.aid], i.iid),
                            f"use-after-free: {i!r} emitted after FREE of "
                            f"{a!r}"))
        # leak check: every scratch ALLOC must be balanced by now — scratch
        # lifetime never crosses a sync partition (plain Runtime) or a
        # drained window (serving replay)
        for aid in list(live):
            alloc_iid, persistent, a = live[aid]
            if not persistent:
                issues.append(VerificationIssue(
                    "leak", node, (alloc_iid,),
                    f"scratch {a!r} allocated but never freed"))
                live.pop(aid)            # report once
        return issues

    def _budget_compare(self, peaks: Sequence[dict]) -> list[VerificationIssue]:
        issues = []
        for n in range(self.num_nodes):
            promised = peaks[n] if n < len(peaks) else {}
            replay = self._replay_peak[n]
            for mid in sorted(set(promised) | set(replay), key=str):
                if promised.get(mid, 0) != replay.get(mid, 0):
                    issues.append(VerificationIssue(
                        "budget", n, (),
                        f"peak replay mismatch in {mid}: model promised "
                        f"{promised.get(mid, 0)}B, replay saw {replay.get(mid, 0)}B"))
        return issues

    # ------------------------------------------------------- comm + deadlock

    def _comm_matching(self, spans, pilots, out: list[VerificationIssue]):
        """Cross-node transfer matching; returns send→receive wait edges."""
        sends, gsends, csends = [], [], []
        recvs: dict = {}
        gathers, crecvs = [], {}
        for n, lo, hi in spans:
            for s in self.streams[n][lo:hi]:
                i = s.instr
                it = i.itype
                if it is _IT.SEND:
                    (gsends if len(i.transfer_id) == 3 else sends).append((n, s))
                elif it in _RECV_TYPES:
                    recvs.setdefault((n, i.transfer_id), []).append(s)
                elif it is _IT.GATHER_RECEIVE:
                    gathers.append((n, s))
                elif it is _IT.COLL_SEND:
                    csends.append((n, s))
                elif it is _IT.COLL_RECV:
                    key = (n, i.transfer_id, i.coll_source)
                    crecvs.setdefault(key, []).append(s)
        wait_edges: list[tuple[int, int]] = []
        matched_boxes: dict[int, list] = {}
        # all push sends per transfer id regardless of dest: when a receive
        # starves, the culprit is usually a send mis-aimed at another node,
        # so the issue names every send on the same tid for attribution
        sends_by_tid: dict = {}
        for n, s in sends:
            sends_by_tid.setdefault(s.instr.transfer_id, []).append(s.instr.iid)

        for n, s in sends:
            i = s.instr
            cands = recvs.get((i.dest, i.transfer_id), [])
            inside = [r for r in cands
                      if r.instr.recv_region.contains_box(i.send_box)]
            if len(inside) != 1:
                out.append(VerificationIssue(
                    "comm", n, (i.iid,),
                    f"push send {i!r} matches {len(inside)} receives on "
                    f"N{i.dest} for tid {i.transfer_id}"))
            else:
                r = inside[0]
                wait_edges.append((i.iid, r.instr.iid))
                matched_boxes.setdefault(id(r), []).append(i.send_box)
        for (n, tid), rlist in recvs.items():
            peers = tuple(sends_by_tid.get(tid, ()))
            for r in rlist:
                boxes = matched_boxes.get(id(r), [])
                if not boxes:
                    out.append(VerificationIssue(
                        "comm", n, (r.instr.iid,) + peers,
                        f"orphan receive {r.instr!r}: no send targets tid {tid}"))
                    continue
                landed = Region.empty()
                for b in boxes:
                    landed = landed.union(Region.from_box(b))
                if not r.instr.recv_region.difference(landed).is_empty():
                    out.append(VerificationIssue(
                        "comm", n, (r.instr.iid,) + peers,
                        f"receive {r.instr!r} region not covered by its sends "
                        f"— the executor would wait forever"))

        gmatched = set()
        for n, s in gathers:
            g = s.instr
            for src in g.gather_sources:
                related = [ss for sn, ss in gsends
                           if sn == src and ss.instr.transfer_id == g.transfer_id]
                hits = [(src, ss) for ss in related if ss.instr.dest == n]
                if len(hits) != 1:
                    out.append(VerificationIssue(
                        "comm", n,
                        (g.iid,) + tuple(ss.instr.iid for ss in related),
                        f"gather {g!r} expects exactly 1 partial from rank "
                        f"{src}, saw {len(hits)}"))
                for _sn, ss in hits:
                    gmatched.add(id(ss))
                    wait_edges.append((ss.instr.iid, g.iid))
        for n, s in gsends:
            if id(s) not in gmatched:
                out.append(VerificationIssue(
                    "comm", n, (s.instr.iid,),
                    f"gather send {s.instr!r} has no expecting GATHER_RECEIVE"))

        cmatched = set()
        for n, s in csends:
            i = s.instr
            rlist = crecvs.get((i.dest, i.transfer_id, n), [])
            if len(rlist) != 1:
                out.append(VerificationIssue(
                    "comm", n, (i.iid,),
                    f"collective send {i!r} matches {len(rlist)} COLL_RECVs "
                    f"on N{i.dest}"))
                continue
            r = rlist[0]
            cmatched.add(id(r))
            wait_edges.append((i.iid, r.instr.iid))
            sent = set(f.key for f in i.coll_frags)
            expect = set(r.instr.coll_expect)
            if sent != expect:
                out.append(VerificationIssue(
                    "comm", n, (i.iid, r.instr.iid),
                    f"fragment keys mismatch: {i!r} packs {sorted(map(str, sent))}"
                    f" but {r.instr!r} expects {sorted(map(str, expect))}"))
        for (n, tid, src), rlist in crecvs.items():
            for r in rlist:
                if id(r) not in cmatched:
                    out.append(VerificationIssue(
                        "comm", n, (r.instr.iid,),
                        f"orphan COLL_RECV {r.instr!r}: no COLL_SEND from "
                        f"N{src} for tid {tid}"))

        # pilots ↔ sends bijection on (source, transfer_id, msg_id)
        send_keys: dict = {}
        for n, s in sends + gsends + csends:
            send_keys.setdefault((n, s.instr.transfer_id, s.instr.msg_id),
                                 []).append(s)
        pilot_keys: dict = {}
        for p in pilots:
            pilot_keys.setdefault((p.source, p.transfer_id, p.msg_id),
                                  []).append(p)
        for key, plist in pilot_keys.items():
            hits = send_keys.get(key, [])
            if len(hits) != len(plist):
                out.append(VerificationIssue(
                    "comm", key[0], tuple(s.instr.iid for s in hits),
                    f"{len(plist)} pilot(s) for tid {key[1]} msg {key[2]} but "
                    f"{len(hits)} send(s)"))
        for key, slist in send_keys.items():
            if len(pilot_keys.get(key, [])) != len(slist):
                out.append(VerificationIssue(
                    "comm", key[0], tuple(s.instr.iid for s in slist),
                    f"send(s) for tid {key[1]} msg {key[2]} posted "
                    f"{len(pilot_keys.get(key, []))} pilot(s), expected "
                    f"{len(slist)}"))
        return wait_edges

    def _deadlock(self, spans, wait_edges) -> list[VerificationIssue]:
        """Kahn's algorithm over the merged chunk + wait edges.

        Fast path: emission order is a topological order for an honest
        stream, so if every in-chunk dependency points backwards and there
        are no cross-node wait edges, the chunk is acyclic by construction
        and the full Kahn pass is skipped (the single-node common case).
        """
        if not wait_edges:
            order: dict[int, int] = {}
            k = 0
            for n, lo, hi in spans:
                for s in self.streams[n][lo:hi]:
                    order[s.instr.iid] = k
                    k += 1
            if all(order.get(diid, -1) < order[s.instr.iid]
                   for n, lo, hi in spans
                   for s in self.streams[n][lo:hi]
                   for diid, _k in s.deps):
                return []
        snaps: dict[int, _Snap] = {}
        node_of: dict[int, int] = {}
        for n, lo, hi in spans:
            for s in self.streams[n][lo:hi]:
                snaps[s.instr.iid] = s
                node_of[s.instr.iid] = n
        preds: dict[int, list[int]] = {iid: [] for iid in snaps}
        succs: dict[int, list[int]] = {iid: [] for iid in snaps}
        for iid, s in snaps.items():
            for diid, _k in s.deps:
                if diid in snaps:
                    preds[iid].append(diid)
                    succs[diid].append(iid)
        for src, dst in wait_edges:
            if src in snaps and dst in snaps:
                preds[dst].append(src)
                succs[src].append(dst)
        indeg = {iid: len(p) for iid, p in preds.items()}
        queue = [iid for iid, d in indeg.items() if d == 0]
        done = 0
        while queue:
            iid = queue.pop()
            done += 1
            for t in succs[iid]:
                indeg[t] -= 1
                if indeg[t] == 0:
                    queue.append(t)
        if done == len(snaps):
            return []
        residual = {iid for iid, d in indeg.items() if d > 0}
        # walk predecessors inside the residual set until we revisit: a cycle
        path, seen_at = [], {}
        cur = next(iter(residual))
        while cur not in seen_at:
            seen_at[cur] = len(path)
            path.append(cur)
            cur = next(p for p in preds[cur] if p in residual)
        cycle = path[seen_at[cur]:]
        names = ", ".join(repr(snaps[i].instr) for i in cycle[:6])
        return [VerificationIssue(
            "deadlock", None, tuple(cycle),
            f"dependency/wait cycle of {len(cycle)} instruction(s): {names}")]


# ------------------------------------------------------------------ one-shot


def verify_graph(node_instrs: Sequence[Sequence[Instruction]], *,
                 pilots: Iterable[Pilot] = (),
                 budgets: Optional[dict] = None,
                 peaks: Optional[Sequence[dict]] = None) -> VerificationReport:
    """Verify fully-lowered (not yet executed) per-node instruction streams."""
    v = ScheduleVerifier(len(node_instrs), mode="final", budgets=budgets)
    for n, instrs in enumerate(node_instrs):
        v.capture(n, instrs)
    v.capture_pilots(list(pilots))
    return v.finalize(peaks=peaks)


# ------------------------------------------------------- mutation self-tests


@dataclass
class Mutation:
    """One planted defect; ``targets`` are the iids attribution must name."""

    op: str
    node: int
    targets: tuple[int, ...]
    detail: str


@dataclass
class MutantResult:
    mutation: Mutation
    detected: bool
    attributed: bool
    issues: tuple[VerificationIssue, ...]


@dataclass
class CampaignResult:
    results: list[MutantResult] = field(default_factory=list)
    skipped: int = 0

    @property
    def total(self) -> int:
        return len(self.results)

    @property
    def detected(self) -> int:
        return sum(1 for r in self.results if r.detected)

    @property
    def attributed(self) -> int:
        return sum(1 for r in self.results if r.attributed)

    def by_op(self) -> dict:
        out: dict = {}
        for r in self.results:
            d = out.setdefault(r.mutation.op, [0, 0])
            d[0] += 1
            d[1] += 1 if r.attributed else 0
        return out

    def misses(self) -> list[MutantResult]:
        return [r for r in self.results if not r.attributed]


def _edge_bearing(si: _Snap, sj: _Snap, budgets: Optional[dict]) -> bool:
    """Does edge ``si -> sj`` (si depends on sj) carry a checked invariant?"""
    ii, ij = si.instr, sj.instr
    if ij.itype is _IT.ALLOC:
        a = ij.allocation
        if ii.itype is _IT.FREE and ii.allocation is a:
            return True
        if any(al is a for al, _r, _m in si.accesses()):
            return True
    if ii.itype is _IT.FREE:
        a = ii.allocation
        if any(al is a for al, _r, _m in sj.accesses()):
            return True
    if (ij.itype is _IT.FREE and ii.itype is _IT.ALLOC and budgets
            and ii.allocation.mid == ij.allocation.mid
            and ii.allocation.mid in budgets):
        return True
    for a1, r1, m1 in si.accesses():
        for a2, r2, m2 in sj.accesses():
            if a1 is a2 and _conflict(m1, m2) and r1.overlaps(r2):
                return True
    return False


def _still_reaches(src: Instruction, dst: Instruction) -> bool:
    """Is ``dst`` (still) an ancestor of ``src``?  Called post-removal."""
    seen = set()
    work = [src]
    while work:
        cur = work.pop()
        for d, _k in cur.dependencies:
            if d is dst:
                return True
            if d.iid not in seen:
                seen.add(d.iid)
                work.append(d)
    return False


def _index_of(stream: list[Instruction], instr: Instruction) -> int:
    """Identity scan (list.index would deep-compare dataclass fields)."""
    for i, x in enumerate(stream):
        if x is instr:
            return i
    return -1


def _remove_edge(instr: Instruction, dep: Instruction) -> Optional[DepKind]:
    """Drop the dep edge ``instr -> dep`` by identity (never Instruction ==,
    which is a deep dataclass comparison)."""
    for i, (d, k) in enumerate(instr.dependencies):
        if d is dep:
            del instr.dependencies[i]
            return k
    return None


def mutate_one(node_instrs: Sequence[list[Instruction]],
               pilots: list[Pilot], rng: random.Random, *,
               budgets: Optional[dict] = None) -> Optional[Mutation]:
    """Plant exactly one random defect in a lowered graph, in place.

    Returns the planted :class:`Mutation` (or ``None`` if no operator
    applies).  Operators are chosen in random order and all guarantee a
    non-equivalent mutant: edge deletions/retargets are restricted to
    invariant-bearing, non-redundant edges, so an honest verifier must
    flag every mutant this function produces.
    """
    num_nodes = len(node_instrs)
    ops = ["drop-edge", "retarget-edge", "cycle-edge", "drop-free",
           "double-free", "drop-alloc", "drop-frag", "retarget-send",
           "drop-pilot"]
    rng.shuffle(ops)
    snaps_cache: dict[int, list[_Snap]] = {}

    def snaps_of(n: int) -> list[_Snap]:
        if n not in snaps_cache:
            snaps_cache[n] = [_Snap(i) for i in node_instrs[n]]
        return snaps_cache[n]

    for op in ops:
        m = _try_op(op, node_instrs, pilots, rng, budgets, snaps_of, num_nodes)
        if m is not None:
            return m
    return None


def _try_op(op, node_instrs, pilots, rng, budgets, snaps_of, num_nodes):
    order = list(range(num_nodes))
    rng.shuffle(order)
    if op in ("drop-edge", "retarget-edge"):
        for n in order:
            stream = node_instrs[n]
            snaps = snaps_of(n)
            idx_of = {s.instr.iid: i for i, s in enumerate(snaps)}
            edges = [(i, d, k) for i, s in enumerate(snaps)
                     for d, k in s.instr.dependencies if d.iid in idx_of]
            rng.shuffle(edges)
            for i, d, k in edges[:400]:
                si, sj = snaps[i], snaps[idx_of[d.iid]]
                if not _edge_bearing(si, sj, budgets):
                    continue
                _remove_edge(si.instr, d)
                if _still_reaches(si.instr, d):
                    si.instr.dependencies.append((d, k))   # redundant: restore
                    continue
                if op == "retarget-edge":
                    si.instr.dependencies.append((stream[0], k))
                    detail = (f"retargeted dep {si.instr!r} -> {d!r} onto "
                              f"{stream[0]!r}")
                else:
                    detail = f"deleted dep edge {si.instr!r} -> {d!r}"
                return Mutation(op, n, (si.instr.iid, d.iid), detail)
    elif op == "cycle-edge":
        for n in order:
            snaps = snaps_of(n)
            if len(snaps) < 3:
                continue
            i = rng.randrange(len(snaps) - 1)
            anchor = snaps[i].instr
            desc = {anchor.iid}
            pool = []
            for s in snaps[i + 1:]:
                if any(d.iid in desc for d, _k in s.instr.dependencies):
                    desc.add(s.instr.iid)
                    pool.append(s.instr)
            if not pool:
                continue
            d = rng.choice(pool)
            anchor.dependencies.append((d, DepKind.SYNC))
            return Mutation("cycle-edge", n, (anchor.iid, d.iid),
                            f"cyclic dep {anchor!r} -> descendant {d!r}")
    elif op in ("drop-free", "double-free", "drop-alloc"):
        for n in order:
            stream = node_instrs[n]
            alloc_of = {i.allocation.aid: i for i in stream
                        if i.itype is _IT.ALLOC}
            frees = [i for i in stream if i.itype is _IT.FREE
                     and i.allocation.aid in alloc_of
                     and alloc_of[i.allocation.aid].persistent is False]
            if not frees:
                continue
            f = rng.choice(frees)
            a = alloc_of[f.allocation.aid]
            if op == "drop-free":
                del stream[_index_of(stream, f)]
                return Mutation(op, n, (f.iid, a.iid),
                                f"deleted {f!r} balancing {a!r}")
            if op == "drop-alloc":
                del stream[_index_of(stream, a)]
                return Mutation(op, n, (a.iid, f.iid),
                                f"deleted {a!r} freed by {f!r}")
            dup = Instruction(_IT.FREE, node=n, queue=f.queue,
                              allocation=f.allocation, name="free (dup)")
            dup.add_dependency(f, DepKind.SYNC)
            stream.insert(_index_of(stream, f) + 1, dup)
            return Mutation(op, n, (f.iid, dup.iid), f"duplicated {f!r}")
    elif op == "drop-frag":
        cands = [(n, i) for n in order for i in node_instrs[n]
                 if i.itype is _IT.COLL_SEND and len(i.coll_frags) >= 1]
        if cands:
            n, i = rng.choice(cands)
            k = rng.randrange(len(i.coll_frags))
            dropped = i.coll_frags[k]
            i.coll_frags = i.coll_frags[:k] + i.coll_frags[k + 1:]
            return Mutation("drop-frag", n, (i.iid,),
                            f"dropped fragment {dropped.key!r} from {i!r}")
    elif op == "retarget-send" and num_nodes > 1:
        cands = [(n, i) for n in order for i in node_instrs[n]
                 if i.itype in (_IT.SEND, _IT.COLL_SEND)]
        if cands:
            n, i = rng.choice(cands)
            old = i.dest
            i.dest = (i.dest + 1) % num_nodes
            return Mutation("retarget-send", n, (i.iid,),
                            f"retargeted {i!r} from N{old} to N{i.dest}")
    elif op == "drop-pilot":
        if pilots:
            k = rng.randrange(len(pilots))
            p = pilots.pop(k)
            key = (p.source, p.transfer_id, p.msg_id)
            for i in node_instrs[p.source]:
                if (i.itype in (_IT.SEND, _IT.COLL_SEND)
                        and (p.source, i.transfer_id, i.msg_id) == key):
                    return Mutation("drop-pilot", p.source, (i.iid,),
                                    f"dropped pilot for {i!r}")
            pilots.insert(k, p)   # no matching send: not a usable candidate
    return None


def run_mutation_campaign(build: Callable[[], tuple], *, mutants: int,
                          seed: int) -> CampaignResult:
    """Fuzz ``mutants`` single-defect graphs and score detection/attribution.

    ``build()`` must return a fresh ``(node_instrs, pilots, budgets, peaks)``
    tuple per call (``budgets``/``peaks`` may be ``None``); each mutant gets
    its own lowering so defects never compound.
    """
    out = CampaignResult()
    for k in range(mutants):
        rng = random.Random(seed * 1_000_003 + k)
        node_instrs, pilots, budgets, peaks = build()
        node_instrs = [list(s) for s in node_instrs]
        pilots = list(pilots)
        mut = mutate_one(node_instrs, pilots, rng, budgets=budgets)
        if mut is None:
            out.skipped += 1
            continue
        rep = verify_graph(node_instrs, pilots=pilots, budgets=budgets,
                           peaks=peaks)
        targets = set(mut.targets)
        att = any(targets & set(iss.instrs) for iss in rep.issues)
        out.results.append(MutantResult(mut, bool(rep.issues), att,
                                        tuple(rep.issues)))
    return out
