"""Range mappers: declare the buffer region a kernel chunk accesses.

A range mapper is a function ``chunk -> Region`` mapping a *chunk* of the
kernel index space (a Box) to the buffer region touched by the work items in
that chunk.  This is the metadata that makes Celerity's implicit dataflow
analysis possible (paper §2.1/§2.2).
"""

from __future__ import annotations

from typing import Callable, Sequence

from .region import Box, Region

RangeMapper = Callable[[Box, tuple[int, ...]], Region]
# signature: (kernel_chunk, buffer_shape) -> Region


def one_to_one() -> RangeMapper:
    """Kernel and buffer index space are identical."""

    def rm(chunk: Box, buffer_shape: tuple[int, ...]) -> Region:
        return Region.from_box(chunk.clamp(Box.full(buffer_shape)))

    rm.__name__ = "one_to_one"
    return rm


def all_range() -> RangeMapper:
    """Every chunk accesses the entire buffer (paper's ``access::all``)."""

    def rm(chunk: Box, buffer_shape: tuple[int, ...]) -> Region:
        return Region.from_box(Box.full(buffer_shape))

    rm.__name__ = "all"
    return rm


def fixed(region: Region | Box) -> RangeMapper:
    """Every chunk accesses a fixed subregion."""
    reg = Region.from_box(region) if isinstance(region, Box) else region

    def rm(chunk: Box, buffer_shape: tuple[int, ...]) -> Region:
        return reg.intersect_box(Box.full(buffer_shape))

    rm.__name__ = "fixed"
    return rm


def neighborhood(border: Sequence[int]) -> RangeMapper:
    """One-to-one widened by ``border`` elements per dimension (stencils)."""
    border = tuple(int(b) for b in border)

    def rm(chunk: Box, buffer_shape: tuple[int, ...]) -> Region:
        lo = tuple(a - b for a, b in zip(chunk.min, border))
        hi = tuple(a + b for a, b in zip(chunk.max, border))
        return Region.from_box(Box(lo, hi).clamp(Box.full(buffer_shape)))

    rm.__name__ = f"neighborhood{border}"
    return rm


def slice_dim(dim: int) -> RangeMapper:
    """One-to-one in ``dim``, full extent in all other dimensions."""

    def rm(chunk: Box, buffer_shape: tuple[int, ...]) -> Region:
        lo = [0] * len(buffer_shape)
        hi = list(buffer_shape)
        lo[dim], hi[dim] = chunk.min[dim], chunk.max[dim]
        return Region.from_box(Box(tuple(lo), tuple(hi)).clamp(Box.full(buffer_shape)))

    rm.__name__ = f"slice_dim({dim})"
    return rm


def rows_upto(row_of: Callable[[Box], int]) -> RangeMapper:
    """Access rows ``[0, row_of(chunk))`` — RSim's growing read pattern."""

    def rm(chunk: Box, buffer_shape: tuple[int, ...]) -> Region:
        n = row_of(chunk)
        hi = (min(n, buffer_shape[0]),) + tuple(buffer_shape[1:])
        lo = (0,) * len(buffer_shape)
        return Region.from_box(Box(lo, hi))

    rm.__name__ = "rows_upto"
    return rm


def fixed_row(row_of: Callable[[Box], int]) -> RangeMapper:
    """Access exactly row ``row_of(chunk)`` — RSim's appending write."""

    def rm(chunk: Box, buffer_shape: tuple[int, ...]) -> Region:
        n = row_of(chunk)
        lo = (n,) + (0,) * (len(buffer_shape) - 1)
        hi = (n + 1,) + tuple(buffer_shape[1:])
        return Region.from_box(Box(lo, hi).clamp(Box.full(buffer_shape)))

    rm.__name__ = "fixed_row"
    return rm
