"""Scheduler lookahead and resize elision (paper §4.3).

Commands are generated eagerly, but instruction-graph generation is
heuristically postponed while changing memory-allocation patterns are
observed:

* a freshly generated command is queried with ``would_allocate`` (cheap
  region query) and marked *allocating* if compiling it now would emit an
  ``alloc`` instruction;
* as long as no allocating command is queued, commands pass straight
  through;
* once an allocating command is queued, the queue holds until **two
  horizons** pass with no further allocating command (or an epoch forces a
  flush) — indicative of the task chain reaching an allocation steady state;
* on flush, every queued command's allocation requirements are merged into
  per-(buffer, memory) *widening hints* so the first ``alloc`` already covers
  everything observed in the window — eliding the resize chains of fig. 3.

The RSim growing-row pattern keeps re-arming the heuristic, so its whole
command graph is queued before the first instruction is emitted — exactly
the behaviour the paper reports (§4.3, fig. 7).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from .command_graph import Command, CommandType
from .instruction_graph import IdagGenerator, Instruction
from .region import Region


@dataclass
class LookaheadStats:
    commands_seen: int = 0
    commands_queued_peak: int = 0
    flushes: int = 0
    allocating_commands: int = 0


class LookaheadScheduler:
    """Command queue between CDAG generation and IDAG compilation."""

    def __init__(self, idag: IdagGenerator, *, enabled: bool = True,
                 horizon_flush: int = 2, retire_compiled: bool = False,
                 metrics=None, tracer=None):
        self.idag = idag
        self.enabled = enabled
        self.horizon_flush = horizon_flush
        # observability (DESIGN.md §11): window occupancy sampled as a
        # counter track whenever the held-back queue changes size
        self.metrics = metrics
        self.tracer = tracer
        self._depth_metric = f"lookahead.N{idag.node}.queued"
        # ``retire_compiled`` (runtime mode): clear a command's dependency
        # lists once it is lowered, so retired CDAG prefixes are not kept
        # alive through inter-command edges (O(window) scheduler memory).
        # Structural tests that inspect command graphs leave this off.
        self.retire_compiled = retire_compiled
        self.queue: list[Command] = []
        self._horizons_since_alloc = 0
        self._have_allocating = False
        # requirements of already-queued commands: compiling a new command
        # "right away" means compiling it *after* the queued window, so a
        # requirement covered by the pending window is not newly allocating.
        self._pending: dict[tuple[int, int], Region] = {}
        self.stats = LookaheadStats()

    # ------------------------------------------------------------------
    def _compile(self, cmd: Command) -> list[Instruction]:
        out = self.idag.compile(cmd)
        if self.retire_compiled:
            # the command is fully lowered; its backward edges are no longer
            # consulted — clearing them breaks the reference chain that
            # would keep retired CDAG prefixes alive.  Dependents stay: the
            # sync frontier scan (`not c.dependents`) relies on them to add
            # SYNC edges only to graph leaves, and forward references die
            # with the command when its window is trimmed.
            cmd.dependencies.clear()
        return out

    # ------------------------------------------------------------------
    def _is_allocating(self, cmd: Command) -> bool:
        # REDUCE_PARTIAL only touches one-shot scratch (never widened);
        # REDUCE_GLOBAL writes the buffer's host backing and participates,
        # as do region collectives (their landing/staging region lives in
        # the buffer's pinned-host backing)
        if cmd.ctype not in (CommandType.EXECUTION, CommandType.PUSH,
                             CommandType.AWAIT_PUSH,
                             CommandType.REDUCE_GLOBAL,
                             CommandType.COLL_ALLGATHER,
                             CommandType.COLL_BROADCAST,
                             CommandType.COLL_SCATTER):
            return False
        out = False
        for (bid, mid), region in self.idag.allocation_requirements(cmd).items():
            bb = region.bounding_box()
            covered = not self.idag.would_allocate_box(bid, mid, bb)
            pend = self._pending.get((bid, mid))
            if not covered and pend is not None:
                covered = pend.bounding_box().contains(bb)
            if not covered:
                out = True
            key = (bid, mid)
            self._pending[key] = self._pending.get(key, Region.empty()).union(region)
        return out

    def push(self, cmd: Command) -> list[Instruction]:
        """Feed one command; returns any instructions that became ready."""
        self.stats.commands_seen += 1
        if not self.enabled:
            return self._compile(cmd)

        allocating = self._is_allocating(cmd)
        if allocating:
            self.stats.allocating_commands += 1

        if not self._have_allocating and not allocating:
            # steady state: pass through immediately (no latency added)
            return self._compile(cmd)

        self.queue.append(cmd)
        self.stats.commands_queued_peak = max(self.stats.commands_queued_peak,
                                              len(self.queue))
        self._sample_depth()
        if allocating:
            self._have_allocating = True
            self._horizons_since_alloc = 0
        elif cmd.ctype == CommandType.HORIZON:
            self._horizons_since_alloc += 1
            if self._horizons_since_alloc >= self.horizon_flush:
                return self.flush()
        if cmd.ctype == CommandType.EPOCH:
            return self.flush()   # user synchronization: cannot hold back
        return []

    # ------------------------------------------------------------------
    def flush(self) -> list[Instruction]:
        """Compile all queued commands with widened allocation hints.

        The merged window requirements go to the memory layer as
        *reservations* (``MemoryManager.reserve``): they widen the first
        ``alloc`` to cover everything observed — eliding the fig.-3 resize
        chains — AND protect those regions from budget eviction, so the
        lookahead and the eviction policy cooperate instead of fighting
        (evicting a region the window is about to touch would guarantee a
        spill/reload round-trip).
        """
        if not self.queue:
            return []
        self.stats.flushes += 1
        # merge allocation requirements of the whole window into hints;
        # the widening hints accumulate across flushes, but only THIS
        # window's requirements become eviction-protection reservations
        hints: dict[tuple[int, int], Region] = dict(self.idag.mem.hints)
        window: dict[tuple[int, int], Region] = {}
        for cmd in self.queue:
            for key, region in self.idag.allocation_requirements(cmd).items():
                hints[key] = hints.get(key, Region.empty()).union(region)
                window[key] = window.get(key, Region.empty()).union(region)
        self.idag.mem.reserve(hints, window=window)
        out: list[Instruction] = []
        # spill-aware reload prefetch: the window's spilled device regions
        # start their copy back BEFORE the commands that first touch them
        # compile, hiding reload latency behind the preceding execution
        out.extend(self.idag.mem.prefetch_reloads(window))
        for cmd in self.queue:
            out.extend(self._compile(cmd))
        self.queue.clear()
        self._pending.clear()
        self._have_allocating = False
        self._horizons_since_alloc = 0
        self._sample_depth()
        return out

    def _sample_depth(self) -> None:
        """Lookahead window occupancy (scheduler-lag time series)."""
        if self.metrics is None and self.tracer is None:
            return
        depth = float(len(self.queue))
        if self.metrics is not None:
            self.metrics.gauge(self._depth_metric, depth)
        if self.tracer is not None:
            self.tracer.counter(self._depth_metric, depth)
