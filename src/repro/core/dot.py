"""Graphviz DOT export for the three scheduling IRs (DESIGN.md §14.5).

``tdag_to_dot`` / ``cdag_to_dot`` / ``idag_to_dot`` render the task,
command and instruction graphs; ``idag_to_dot`` accepts the per-node
streams of the whole grid and draws one cluster per node with dashed
cross-node wait edges (send -> matching receive, merged on transfer id).
Verification failures from the schedule sanitizer (core/verify.py) can be
passed in to highlight the offending instructions in red — so a flagged
pair is debuggable visually instead of by iid archaeology.
"""

from __future__ import annotations

from typing import Iterable, Sequence

from .instructions import Instruction, InstructionType
from .task_graph import DepKind

_DEP_STYLE = {
    DepKind.TRUE: "solid",
    DepKind.ANTI: "dashed",
    DepKind.OUTPUT: "dotted",
    DepKind.SYNC: "bold",
}

_ITYPE_FILL = {
    InstructionType.ALLOC: "#d5e8d4",
    InstructionType.FREE: "#f8cecc",
    InstructionType.SPILL: "#ffe6cc",
    InstructionType.RELOAD: "#ffe6cc",
    InstructionType.SEND: "#dae8fc",
    InstructionType.RECEIVE: "#dae8fc",
    InstructionType.SPLIT_RECEIVE: "#dae8fc",
    InstructionType.AWAIT_RECEIVE: "#dae8fc",
    InstructionType.COLL_SEND: "#dae8fc",
    InstructionType.COLL_RECV: "#dae8fc",
    InstructionType.GATHER_RECEIVE: "#dae8fc",
    InstructionType.HORIZON: "#e1d5e7",
    InstructionType.EPOCH: "#e1d5e7",
}


def _esc(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"')


def tdag_to_dot(tdag, *, title: str = "TDAG") -> str:
    """Render a :class:`~repro.core.task_graph.TaskGraph`."""
    out = [f'digraph "{_esc(title)}" {{', '  rankdir=TB;',
           '  node [shape=box, style=filled, fillcolor="#ffffff"];']
    for t in tdag.tasks:
        label = f"T{t.tid} {t.name}\\n{t.ttype.name.lower()}"
        out.append(f'  t{t.tid} [label="{_esc(label)}"];')
    for t in tdag.tasks:
        for d, k in t.dependencies:
            out.append(f'  t{d.tid} -> t{t.tid} '
                       f'[style={_DEP_STYLE.get(k, "solid")}];')
    out.append("}")
    return "\n".join(out) + "\n"


def cdag_to_dot(commands, *, title: str = "CDAG") -> str:
    """Render a command list (one node-cluster per rank)."""
    out = [f'digraph "{_esc(title)}" {{', '  rankdir=TB;',
           '  node [shape=box, style=filled, fillcolor="#ffffff"];']
    by_node: dict[int, list] = {}
    for c in commands:
        by_node.setdefault(c.node, []).append(c)
    for n in sorted(by_node):
        out.append(f'  subgraph cluster_n{n} {{ label="N{n}";')
        for c in by_node[n]:
            t = f" T{c.task.tid}" if c.task is not None else ""
            label = f"C{c.cid} {c.ctype.value}{t}"
            out.append(f'    c{c.cid} [label="{_esc(label)}"];')
        out.append("  }")
    for c in commands:
        for d, k in c.dependencies:
            out.append(f'  c{d.cid} -> c{c.cid} '
                       f'[style={_DEP_STYLE.get(k, "solid")}];')
    out.append("}")
    return "\n".join(out) + "\n"


def idag_to_dot(node_instrs: Sequence[Sequence[Instruction]], *,
                issues: Iterable = (), title: str = "IDAG",
                max_label: int = 48) -> str:
    """Render merged per-node instruction streams, one cluster per rank.

    ``issues`` is an iterable of
    :class:`~repro.core.verify.VerificationIssue`; every instruction an
    issue names is filled red and annotated with the issue kind, and
    cross-node send/receive pairs are linked with dashed wait edges so a
    flagged ordering hole shows up as a visibly unconnected pair.
    """
    flagged: dict[int, str] = {}
    for iss in issues:
        for iid in iss.instrs:
            flagged.setdefault(iid, iss.kind)
    out = [f'digraph "{_esc(title)}" {{', '  rankdir=TB;',
           '  node [shape=box, style=filled, fillcolor="#ffffff"];']
    present: set[int] = set()
    recv_by_tid: dict[tuple, list[Instruction]] = {}
    for n, instrs in enumerate(node_instrs):
        out.append(f'  subgraph cluster_n{n} {{ label="N{n}";')
        for i in instrs:
            present.add(i.iid)
            label = f"I{i.iid} {i.itype.value}"
            if i.name:
                label += f"\\n{i.name[:max_label]}"
            attrs = [f'label="{_esc(label)}"']
            kind = flagged.get(i.iid)
            if kind is not None:
                attrs.append('fillcolor="#ff9999"')
                attrs.append(f'xlabel="{_esc(kind)}"')
            else:
                fill = _ITYPE_FILL.get(i.itype)
                if fill:
                    attrs.append(f'fillcolor="{fill}"')
            out.append(f'    i{i.iid} [{", ".join(attrs)}];')
            if i.itype in (InstructionType.RECEIVE,
                           InstructionType.SPLIT_RECEIVE,
                           InstructionType.GATHER_RECEIVE,
                           InstructionType.COLL_RECV):
                recv_by_tid.setdefault((n, i.transfer_id), []).append(i)
        out.append("  }")
    for instrs in node_instrs:
        for i in instrs:
            for d, k in i.dependencies:
                if d.iid in present:
                    out.append(f'  i{d.iid} -> i{i.iid} '
                               f'[style={_DEP_STYLE.get(k, "solid")}];')
    # cross-node wait edges: send -> every receive candidate on the target
    for instrs in node_instrs:
        for i in instrs:
            if i.itype not in (InstructionType.SEND,
                               InstructionType.COLL_SEND):
                continue
            for r in recv_by_tid.get((i.dest, i.transfer_id), ()):
                out.append(f'  i{i.iid} -> i{r.iid} '
                           f'[style=dashed, color="#3366cc", '
                           f'constraint=false];')
    out.append("}")
    return "\n".join(out) + "\n"


def write_dot(path: str, text: str) -> str:
    """Write DOT ``text`` to ``path`` and return the path (CLI helper)."""
    with open(path, "w") as f:
        f.write(text)
    return path


__all__ = ["tdag_to_dot", "cdag_to_dot", "idag_to_dot", "write_dot"]
