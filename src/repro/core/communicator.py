"""Communicator: peer-to-peer transfers + pilot messages (paper §3.4/§4.2).

Faithfully models the MPI-level protocol: senders transmit *pilot messages*
(source, transfer id, box, message id) ahead of the payload; the receiver's
*receive arbitration* state machine matches pilots against pending
``receive`` / ``split receive`` instructions and "posts the Irecv" — here,
registers the landing slice — as soon as source and geometry are known.  An
``await receive`` completes when its subregion is fully covered by landed
payloads, regardless of inbound geometry (cases a/b/c in §3.4).

The wire is an in-process thread-safe mailbox (one real CPU; see DESIGN.md
§2).  On a real deployment the same interface maps to MPI/ICI transports.

Resilient transport (DESIGN.md §10): with ``reliable=True`` every payload is
sequence-numbered per (source, target) channel and kept in the sender's
retransmit queue until the receiver acks it.  ``pump`` — called from each
executor's main loop — drains inbound acks and retransmits overdue entries
with exponential backoff; after ``max_retries`` unacked attempts it reports
a :class:`TransportError`.  The receiver side (``ReceiveArbiter``) acks every
delivered copy and suppresses duplicates by (channel, seq), so landing is
idempotent and any non-crash fault schedule is invisible to the program.
A :class:`FaultPlan` is consulted at the delivery points; the control plane
(acks, EPOCH_ABORT, heartbeats) is deliberately not faulted.
"""

from __future__ import annotations

import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .faults import FaultPlan, TransportError
from .instruction_graph import EpochAbort, Instruction, InstructionType, Pilot
from .region import Box, Region


@dataclass
class Payload:
    source: int
    msg_id: int
    # (task id, buffer id) for push traffic; (task id, buffer id, 1) for
    # reduction-gather traffic; round-tagged (tid, bid, 2|3, round) for
    # collective rounds (see instruction_graph.Pilot / DESIGN.md §9)
    transfer_id: tuple
    box: Optional[Box] = None
    data: Optional[np.ndarray] = None
    # collective rounds ship ONE packed message of (key, ndarray) fragments:
    # key = (member, slot) for reduction partials, a buffer-space Box for
    # region blocks — matching what the peer's COLL_RECV expects
    fragments: Optional[list[tuple]] = None
    # reliable-transport sequence number within the (source, target) channel;
    # None on an unreliable wire (assigned by ``Communicator.isend``)
    seq: Optional[int] = None

    def nbytes(self) -> int:
        if self.fragments is not None:
            return sum(d.nbytes for _, d in self.fragments)
        return self.data.nbytes if self.data is not None else 0


@dataclass
class _TxEntry:
    """One unacked reliable send awaiting ack or retransmission."""
    target: int
    payload: Payload
    attempts: int
    next_t: float                      # monotonic deadline for retransmit


class Communicator:
    """Shared mailbox fabric between in-process ranks."""

    def __init__(self, num_nodes: int, *, reliable: bool = True,
                 fault_plan: Optional[FaultPlan] = None,
                 retransmit_timeout: float = 0.05, max_retries: int = 12,
                 tracer=None, metrics=None):
        self.num_nodes = num_nodes
        self.reliable = reliable
        self.plan = fault_plan
        # observability (DESIGN.md §11): transport stall events mirrored into
        # the unified registry under ``comm.*`` (these are the events the
        # executor's transport-wait attribution points at)
        self.metrics = metrics
        if fault_plan is not None and fault_plan.has_wire_faults() and not reliable:
            raise ValueError("wire faults require the reliable transport "
                             "(reliable=True), else delivery is not guaranteed")
        self.retransmit_timeout = retransmit_timeout
        self.max_retries = max_retries
        self.tracer = tracer
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self.pilot_box: list[list[Pilot]] = [[] for _ in range(num_nodes)]
        self.payload_box: list[list[Payload]] = [[] for _ in range(num_nodes)]
        self._listeners: list[list[threading.Event]] = [[] for _ in range(num_nodes)]
        self.bytes_sent = 0
        self.num_messages = 0
        # collective-round accounting (DESIGN.md §9): packed round messages
        # and their real payload bytes, split out from point-to-point pushes;
        # reduce-exchange rounds (transfer ids tagged 3) counted separately
        # so fusion wins are observable next to region-collective traffic
        self.coll_messages = 0
        self.coll_bytes = 0
        self.red_messages = 0
        self.red_bytes = 0
        # reliable-transport state: per-channel next seq, per-sender unacked
        # entries keyed (target, seq), and per-sender inbound ack mailbox of
        # (receiver, seq).  Recovery traffic is accounted separately from the
        # logical counters above so fault-free byte ratios stay exact.
        self._next_seq: dict[tuple[int, int], int] = {}
        self._outstanding: list[dict[tuple[int, int], _TxEntry]] = \
            [{} for _ in range(num_nodes)]
        self.ack_box: list[list[tuple[int, int]]] = [[] for _ in range(num_nodes)]
        self.ctrl_box: list[list[EpochAbort]] = [[] for _ in range(num_nodes)]
        self._delayed: list[tuple[float, int, Payload]] = []
        self.retries = 0
        self.retry_bytes = 0
        self.acks = 0                  # acks posted by receivers
        self.aborts = 0                # EPOCH_ABORT broadcasts
        self.fault_counts = {"drop": 0, "delay": 0, "dup": 0, "pilot_drop": 0}
        # heartbeat bus: each executor loop stamps its slot; watchdogs read
        # peers' staleness to attribute failures (in-process deviation from a
        # real gossip/ping channel — see DESIGN.md §10)
        now = time.monotonic()
        self._beats: list[float] = [now] * num_nodes

    def add_listener(self, node: int, event: threading.Event) -> None:
        """Register an event set whenever traffic arrives for ``node``.

        Lets the executor block on its completion-sink event instead of
        polling the mailbox for inbound pilots/payloads.
        """
        with self._cv:
            self._listeners[node].append(event)

    def _notify(self, node: int) -> None:
        for ev in self._listeners[node]:
            ev.set()

    # -- sender side -------------------------------------------------------
    def post_pilot(self, pilot: Pilot) -> None:
        if (self.plan is not None
                and self.plan.pilot_dropped(pilot.transfer_id, pilot.msg_id)):
            with self._cv:
                self.fault_counts["pilot_drop"] += 1
            if self.metrics is not None:
                self.metrics.counter("comm.pilot_drops")
            if self.tracer is not None:
                self.tracer.instant(f"wire.N{pilot.target}", "pilot_drop",
                                    {"tid": str(pilot.transfer_id)})
            return      # pilots are unacked metadata; the payload carries geometry
        with self._cv:
            self.pilot_box[pilot.target].append(pilot)
            self._cv.notify_all()
            self._notify(pilot.target)

    def isend(self, target: int, payload: Payload) -> None:
        now = time.monotonic()
        with self._cv:
            if self.reliable and payload.source is not None:
                ch = (payload.source, target)
                seq = self._next_seq.get(ch, 0) + 1
                self._next_seq[ch] = seq
                payload.seq = seq
                self._outstanding[payload.source][(target, seq)] = _TxEntry(
                    target=target, payload=payload, attempts=1,
                    next_t=now + self.retransmit_timeout)
            self.bytes_sent += payload.nbytes()
            self.num_messages += 1
            if payload.fragments is not None:
                self.coll_messages += 1
                self.coll_bytes += payload.nbytes()
                tid = payload.transfer_id
                if len(tid) == 4 and tid[2] == 3:
                    self.red_messages += 1
                    self.red_bytes += payload.nbytes()
            self._deliver_locked(target, payload, attempt=1, now=now)
            self._cv.notify_all()
            self._notify(target)

    def _deliver_locked(self, target: int, payload: Payload, attempt: int,
                        now: float) -> None:
        """One delivery attempt through the (possibly faulty) wire."""
        if self.plan is not None:
            fate = self.plan.payload_fate(payload.transfer_id, payload.msg_id,
                                          attempt)
            if fate.duplicate:
                self.fault_counts["dup"] += 1
                self.payload_box[target].append(payload)
            if fate.drop:
                # the retransmit entry stays outstanding; a later attempt
                # re-rolls its fate
                self.fault_counts["drop"] += 1
                if self.metrics is not None:
                    self.metrics.counter("comm.drops")
                if self.tracer is not None:
                    self.tracer.instant(
                        f"wire.N{target}", "drop",
                        {"tid": str(payload.transfer_id), "seq": payload.seq,
                         "attempt": attempt})
                return
            if fate.delay_s > 0.0:
                self.fault_counts["delay"] += 1
                self._delayed.append((now + fate.delay_s, target, payload))
                return
        self.payload_box[target].append(payload)

    def _release_delayed_locked(self, now: float) -> None:
        if not self._delayed:
            return
        keep = []
        for rel, tgt, p in self._delayed:
            if rel <= now:
                self.payload_box[tgt].append(p)
                self._notify(tgt)
            else:
                keep.append((rel, tgt, p))
        self._delayed = keep

    # -- reliable transport --------------------------------------------------
    def has_transport_work(self, node: int) -> bool:
        """Lock-free hint for the executor loop: pump only when needed."""
        return bool(self.ack_box[node] or self._outstanding[node]
                    or self._delayed)

    def pump(self, node: int) -> list[TransportError]:
        """Drain ``node``'s acks, retransmit overdue sends with exponential
        backoff, and mature delayed deliveries.  Returns the sends that
        exhausted their retry budget."""
        now = time.monotonic()
        failures: list[TransportError] = []
        with self._cv:
            self._release_delayed_locked(now)
            acks, self.ack_box[node] = self.ack_box[node], []
            out = self._outstanding[node]
            for key in acks:
                out.pop(key, None)       # dup-acks (from dup deliveries) are fine
            for key, e in list(out.items()):
                if now < e.next_t:
                    continue
                if e.attempts > self.max_retries:
                    del out[key]
                    failures.append(TransportError(
                        f"N{node}->N{e.target}: tid={e.payload.transfer_id} "
                        f"msg={e.payload.msg_id} seq={e.payload.seq} unacked "
                        f"after {e.attempts} attempts"))
                    continue
                e.attempts += 1
                e.next_t = now + self.retransmit_timeout * (1 << (e.attempts - 1))
                self.retries += 1
                self.retry_bytes += e.payload.nbytes()
                if self.metrics is not None:
                    self.metrics.counter("comm.retransmits")
                    self.metrics.counter("comm.retry_bytes",
                                         e.payload.nbytes())
                if self.tracer is not None:
                    self.tracer.instant(
                        f"wire.N{node}", "retransmit",
                        {"tid": str(e.payload.transfer_id), "seq": e.payload.seq,
                         "attempt": e.attempts})
                self._deliver_locked(e.target, e.payload, e.attempts, now)
                self._notify(e.target)
        return failures

    def post_acks(self, receiver: int, acks: list[tuple[int, int]]) -> None:
        """Receiver-side: ack delivered (source, seq) pairs back to senders."""
        if not acks:
            return
        with self._cv:
            for src, seq in acks:
                self.ack_box[src].append((receiver, seq))
                self.acks += 1
            for src in {s for s, _ in acks}:
                self._notify(src)
            self._cv.notify_all()

    def unacked(self, node: int) -> int:
        return len(self._outstanding[node])

    def transport_summary(self) -> str:
        pend = {n: len(out) for n, out in enumerate(self._outstanding) if out}
        return (f"unacked sends per node: {pend or 'none'}; "
                f"delayed in flight: {len(self._delayed)}; "
                f"retries={self.retries} acks={self.acks}")

    # -- control plane (failure propagation + heartbeats) ---------------------
    def post_abort(self, abort: EpochAbort) -> None:
        """Broadcast an EPOCH_ABORT poison to every peer of the origin."""
        with self._cv:
            self.aborts += 1
            for n in range(self.num_nodes):
                if n != abort.origin:
                    self.ctrl_box[n].append(abort)
                    self._notify(n)
            self._cv.notify_all()
        if self.metrics is not None:
            self.metrics.counter("comm.aborts")
        if self.tracer is not None:
            self.tracer.instant(f"wire.N{abort.origin}", "epoch_abort",
                                {"cause": abort.cause})

    def poll_ctrl(self, node: int) -> list[EpochAbort]:
        if not self.ctrl_box[node]:
            return []
        with self._cv:
            out, self.ctrl_box[node] = self.ctrl_box[node], []
            return out

    def beat(self, node: int) -> None:
        self._beats[node] = time.monotonic()

    def last_beat(self, node: int) -> float:
        return self._beats[node]

    def stale_peers(self, node: int, timeout: float,
                    now: Optional[float] = None) -> list[int]:
        """Peers of ``node`` whose heartbeat is older than ``timeout``."""
        now = time.monotonic() if now is None else now
        return [p for p in range(self.num_nodes)
                if p != node and now - self._beats[p] > timeout]

    # -- receiver side -----------------------------------------------------
    def poll(self, node: int) -> tuple[list[Pilot], list[Payload]]:
        with self._cv:
            self._release_delayed_locked(time.monotonic())
            pilots, self.pilot_box[node] = self.pilot_box[node], []
            payloads, self.payload_box[node] = self.payload_box[node], []
            return pilots, payloads

    def wait_any(self, node: int, timeout: float = 0.001) -> None:
        with self._cv:
            if not self.pilot_box[node] and not self.payload_box[node]:
                self._cv.wait(timeout)


@dataclass
class _PendingReceive:
    instr: Instruction                 # RECEIVE or SPLIT_RECEIVE
    remaining: Region                  # region still to be covered
    awaits: list[Instruction] = field(default_factory=list)  # AWAIT_RECEIVE children


@dataclass
class _PendingColl:
    """A COLL_RECV: exactly one packed round message from one peer (§9).

    Collective rounds are fully determined by the replicated schedule, so
    the receiver knows the source rank AND the exact fragment keys it will
    land: ``(member, slot)`` pairs for reduction partials, buffer-space
    boxes for region blocks.  Completion requires every expected key.
    """
    instr: Instruction                 # COLL_RECV
    remaining: set                     # fragment keys still outstanding


@dataclass
class _PendingGather:
    """A GATHER_RECEIVE: one fixed-stride slot per expected peer (§2.2).

    Unlike push traffic, gather payloads are addressed by their *source*
    rank — every peer sends the same buffer-space box (a reduction partial),
    and the arbiter lands payload ``p`` at ``arr[p.source]`` of the gather
    staging allocation.  Completion requires one payload from every source.
    """
    instr: Instruction                 # GATHER_RECEIVE
    remaining: set                     # source ranks still outstanding


class _SeenSeqs:
    """Per-channel duplicate filter with watermark compaction.

    Seqs are per (source, target) channel and every seq of the channel is
    eventually delivered here (reliable transport), so the contiguous
    watermark advances and ``extra`` stays bounded by the in-flight window.
    """

    __slots__ = ("contig", "extra")

    def __init__(self) -> None:
        self.contig = 0                 # all seqs <= contig already seen
        self.extra: set[int] = set()

    def admit(self, seq: int) -> bool:
        """True if ``seq`` is new (and mark it seen); False for a duplicate."""
        if seq <= self.contig or seq in self.extra:
            return False
        self.extra.add(seq)
        while self.contig + 1 in self.extra:
            self.contig += 1
            self.extra.discard(self.contig)
        return True


class ReceiveArbiter:
    """Per-node receive-arbitration state machine (paper §4.2).

    Matches inbound pilots/payloads to receive instructions by transfer id,
    writes landed payloads into the destination allocation, and reports
    instruction completions.

    Resilience duties (DESIGN.md §10): every sequence-numbered payload is
    acked on delivery and deduplicated by (source channel, seq) BEFORE any
    matching — landing is idempotent, so retransmits and injected duplicates
    can never corrupt a landed region or touch a freed one-shot staging
    allocation.  Transfer ids tombstoned by :meth:`poison` (an aborted
    epoch) are rejected — and still acked, since the transport did deliver.
    """

    def __init__(self, node: int, comm: Communicator, store):
        self.node = node
        self.comm = comm
        self.store = store                      # allocation id -> ndarray
        self.pending: dict[tuple, list[_PendingReceive]] = defaultdict(list)
        self.pending_gathers: dict[tuple, list[_PendingGather]] = defaultdict(list)
        self.pending_colls: dict[tuple, list[_PendingColl]] = defaultdict(list)
        self.early_payloads: dict[tuple, list[Payload]] = defaultdict(list)
        self.received: dict[tuple, Region] = defaultdict(Region.empty)
        self._seen: dict[int, _SeenSeqs] = defaultdict(_SeenSeqs)
        self._stale_tids: set[tuple] = set()
        # pilot announcements: tid -> sender ranks, kept while the transfer
        # is in flight so a stuck receive can name the peer that owed data
        self.announced: dict[tuple, set[int]] = defaultdict(set)
        self.dups_suppressed = 0
        self.stale_rejected = 0

    def has_pending(self) -> bool:
        """Whether any receive is in flight (executor gates polling on this)."""
        return (any(self.pending.values())
                or any(self.pending_gathers.values())
                or any(self.pending_colls.values())
                or any(self.early_payloads.values()))

    def begin(self, instr: Instruction) -> None:
        if instr.itype == InstructionType.COLL_RECV:
            pc = _PendingColl(instr=instr, remaining=set(instr.coll_expect))
            self.pending_colls[instr.transfer_id].append(pc)
        elif instr.itype == InstructionType.GATHER_RECEIVE:
            pg = _PendingGather(instr=instr,
                                remaining=set(instr.gather_sources))
            self.pending_gathers[instr.transfer_id].append(pg)
        elif instr.itype in (InstructionType.RECEIVE, InstructionType.SPLIT_RECEIVE):
            pr = _PendingReceive(instr=instr, remaining=instr.recv_region)
            self.pending[instr.transfer_id].append(pr)
        elif instr.itype == InstructionType.AWAIT_RECEIVE:
            for pr in self.pending.get(instr.transfer_id, []):
                if pr.instr is instr.split_parent:
                    pr.awaits.append(instr)
                    return
            # parent may already be fully received
            self.pending[instr.transfer_id].append(
                _PendingReceive(instr=instr.split_parent, remaining=Region.empty(),
                                awaits=[instr]))

    def _land(self, pr: _PendingReceive, payload: Payload) -> None:
        alloc = pr.instr.recv_alloc
        arr = self.store[alloc.aid]
        off = alloc.offset_of(payload.box)
        slices = tuple(slice(o, o + s) for o, s in zip(off, payload.box.shape))
        arr[slices] = payload.data

    def _land_gather(self, pg: _PendingGather, payload: Payload) -> None:
        """Land a reduction partial at its source rank's fixed-stride slot."""
        arr = self.store[pg.instr.recv_alloc.aid]
        arr[payload.source] = payload.data.reshape(arr.shape[1:])

    def _land_coll(self, pc: _PendingColl, payload: Payload) -> None:
        """Land every fragment of one packed collective round message."""
        instr = pc.instr
        if instr.coll_land:
            # allreduce slot-range fragments: the landing map names the
            # target allocation and flat range per expected key
            lmap = {f.key: f for f in instr.coll_land}
            for key, data in payload.fragments:
                f = lmap.get(key)
                if f is None:
                    continue
                lo, hi = f.srange
                self.store[f.alloc.aid][lo:hi] = data
                pc.remaining.discard(key)
            return
        for key, data in payload.fragments:
            if isinstance(key, Box):    # buffer-space region fragment
                alloc = instr.coll_allocs[0]
                arr = self.store[alloc.aid]
                off = alloc.offset_of(key)
                slices = tuple(slice(o, o + s)
                               for o, s in zip(off, key.shape))
                arr[slices] = data
            else:                       # (member, slot) partial fragment
                member, slot = key
                arr = self.store[instr.coll_allocs[member].aid]
                arr[slot] = data.reshape(arr.shape[1:])
            pc.remaining.discard(key)

    def poison(self, reason: str = "epoch aborted") -> int:
        """Abort every in-flight receive: tombstone their transfer ids and
        drop buffered traffic.  Late/retransmitted payloads for a poisoned
        tid are counted in ``stale_rejected`` and never land (the epoch they
        belonged to is gone; its allocations may be too).  Returns the number
        of tombstoned transfer ids."""
        tids: set[tuple] = set()
        for m in (self.pending, self.pending_gathers, self.pending_colls,
                  self.early_payloads):
            tids.update(m.keys())
            m.clear()
        self._stale_tids.update(tids)
        self.received.clear()
        self.announced.clear()
        return len(tids)

    def pending_report(self) -> str:
        """One-line stall diagnosis: what is owed, and by whom (per pilots)."""
        parts = []
        for kind, m in (("recv", self.pending), ("gather", self.pending_gathers),
                        ("coll", self.pending_colls)):
            for tid, entries in m.items():
                if not entries:
                    continue
                src = sorted(self.announced.get(tid, ()))
                owed = f" announced by N{src}" if src else " (no pilot seen)"
                parts.append(f"{kind} tid={tid}{owed}")
        return "; ".join(parts) if parts else "no receives pending"

    def _admit(self, payloads: list[Payload]) -> list[Payload]:
        """Transport ingress: ack every sequenced copy, suppress duplicates,
        reject tombstoned transfer ids."""
        acks: list[tuple[int, int]] = []
        fresh: list[Payload] = []
        for p in payloads:
            if p.seq is not None:
                acks.append((p.source, p.seq))
                if not self._seen[p.source].admit(p.seq):
                    self.dups_suppressed += 1
                    continue
            if p.transfer_id in self._stale_tids:
                self.stale_rejected += 1
                continue
            fresh.append(p)
        if acks:
            self.comm.post_acks(self.node, acks)
        return fresh

    def step(self, completions: list[Instruction]) -> None:
        """Drain mailboxes; append completed instructions to ``completions``."""
        pilots, payloads = self.comm.poll(self.node)
        # pilots tell us geometry early; with the mailbox transport the
        # payload itself carries geometry, so pilots feed accounting and
        # stall attribution (who owes a stuck receive data)
        for pl in pilots:
            if pl.transfer_id not in self._stale_tids:
                self.announced[pl.transfer_id].add(pl.source)
        for p in self._admit(payloads):
            self.early_payloads[p.transfer_id].append(p)
        # collective rounds: match by (round-tagged transfer id, source);
        # one packed message lands all expected fragments at once
        for tid, plist in list(self.early_payloads.items()):
            pcs = self.pending_colls.get(tid)
            if not pcs:
                continue
            still: list[Payload] = []
            for payload in plist:
                landed = False
                if payload.fragments is not None:
                    for pc in pcs:
                        if payload.source == pc.instr.coll_source:
                            self._land_coll(pc, payload)
                            landed = True
                            break
                if not landed:
                    still.append(payload)
            self.early_payloads[tid] = still
        for tid, pcs in list(self.pending_colls.items()):
            done = [pc for pc in pcs
                    if not pc.remaining and pc.instr.state == "issued"]
            for pc in done:
                completions.append(pc.instr)
                pcs.remove(pc)
            if not pcs:
                del self.pending_colls[tid]
                self.announced.pop(tid, None)
        # gather receives: match by (transfer id, source), complete when every
        # expected peer landed exactly once
        for tid, plist in list(self.early_payloads.items()):
            pgs = self.pending_gathers.get(tid)
            if not pgs:
                continue
            still: list[Payload] = []
            for payload in plist:
                landed = False
                for pg in pgs:
                    if payload.source in pg.remaining:
                        self._land_gather(pg, payload)
                        pg.remaining.discard(payload.source)
                        landed = True
                        break
                if not landed:
                    still.append(payload)
            self.early_payloads[tid] = still
        for tid, pgs in list(self.pending_gathers.items()):
            done = [pg for pg in pgs
                    if not pg.remaining and pg.instr.state == "issued"]
            for pg in done:
                completions.append(pg.instr)
                pgs.remove(pg)
            if not pgs:
                del self.pending_gathers[tid]
                self.announced.pop(tid, None)
                self.received.pop(tid, None)
        for tid, plist in list(self.early_payloads.items()):
            prs = self.pending.get(tid, [])
            if not prs:
                continue
            still: list[Payload] = []
            for payload in plist:
                landed = False
                for pr in prs:
                    if pr.remaining.is_empty():
                        continue
                    inter = pr.remaining.intersect(Region.from_box(payload.box))
                    if inter.is_empty():
                        continue
                    self._land(pr, payload)
                    pr.remaining = pr.remaining.difference(Region.from_box(payload.box))
                    self.received[tid] = self.received[tid].union(Region.from_box(payload.box))
                    landed = True
                    break
                if not landed:
                    still.append(payload)
            self.early_payloads[tid] = still
        # completion checks
        for tid, prs in list(self.pending.items()):
            done_prs = []
            for pr in prs:
                if pr.remaining.is_empty() and pr.instr.state == "issued":
                    if pr.instr.itype == InstructionType.RECEIVE:
                        completions.append(pr.instr)
                        done_prs.append(pr)
                    elif pr.instr.itype == InstructionType.SPLIT_RECEIVE:
                        completions.append(pr.instr)
                        # keep entry for awaits
                # await-receive: complete when its subregion is covered.  A
                # parent in state "done" was fully received, which covers any
                # await — this keeps late-registered awaits correct even
                # after the coverage map below has been dropped.
                cov = self.received.get(tid)
                for aw in list(pr.awaits):
                    if aw.state == "issued" and (
                            (cov is not None and cov.contains(aw.recv_region))
                            or (pr.instr is not None
                                and pr.instr.state == "done")):
                        completions.append(aw)
                        pr.awaits.remove(aw)
                if (pr.remaining.is_empty() and not pr.awaits
                        and pr.instr.state == "done"):
                    done_prs.append(pr)
            for pr in done_prs:
                if pr in prs:
                    prs.remove(pr)
            if not prs:
                self.announced.pop(tid, None)
                # drop the coverage map with the last receive: transfer ids
                # are never reused, so nothing can consult it again, and a
                # long-running serving process must not accumulate one
                # Region per transfer forever
                self.received.pop(tid, None)
