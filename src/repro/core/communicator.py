"""Communicator: peer-to-peer transfers + pilot messages (paper §3.4/§4.2).

Faithfully models the MPI-level protocol: senders transmit *pilot messages*
(source, transfer id, box, message id) ahead of the payload; the receiver's
*receive arbitration* state machine matches pilots against pending
``receive`` / ``split receive`` instructions and "posts the Irecv" — here,
registers the landing slice — as soon as source and geometry are known.  An
``await receive`` completes when its subregion is fully covered by landed
payloads, regardless of inbound geometry (cases a/b/c in §3.4).

The wire is an in-process thread-safe mailbox (one real CPU; see DESIGN.md
§2).  On a real deployment the same interface maps to MPI/ICI transports.
"""

from __future__ import annotations

import threading
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .instruction_graph import Instruction, InstructionType, Pilot
from .region import Box, Region


@dataclass
class Payload:
    source: int
    msg_id: int
    # (task id, buffer id) for push traffic; (task id, buffer id, 1) for
    # reduction-gather traffic; round-tagged (tid, bid, 2|3, round) for
    # collective rounds (see instruction_graph.Pilot / DESIGN.md §9)
    transfer_id: tuple
    box: Optional[Box] = None
    data: Optional[np.ndarray] = None
    # collective rounds ship ONE packed message of (key, ndarray) fragments:
    # key = (member, slot) for reduction partials, a buffer-space Box for
    # region blocks — matching what the peer's COLL_RECV expects
    fragments: Optional[list[tuple]] = None

    def nbytes(self) -> int:
        if self.fragments is not None:
            return sum(d.nbytes for _, d in self.fragments)
        return self.data.nbytes if self.data is not None else 0


class Communicator:
    """Shared mailbox fabric between in-process ranks."""

    def __init__(self, num_nodes: int):
        self.num_nodes = num_nodes
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self.pilot_box: list[list[Pilot]] = [[] for _ in range(num_nodes)]
        self.payload_box: list[list[Payload]] = [[] for _ in range(num_nodes)]
        self._listeners: list[list[threading.Event]] = [[] for _ in range(num_nodes)]
        self.bytes_sent = 0
        self.num_messages = 0
        # collective-round accounting (DESIGN.md §9): packed round messages
        # and their real payload bytes, split out from point-to-point pushes;
        # reduce-exchange rounds (transfer ids tagged 3) counted separately
        # so fusion wins are observable next to region-collective traffic
        self.coll_messages = 0
        self.coll_bytes = 0
        self.red_messages = 0
        self.red_bytes = 0

    def add_listener(self, node: int, event: threading.Event) -> None:
        """Register an event set whenever traffic arrives for ``node``.

        Lets the executor block on its completion-sink event instead of
        polling the mailbox for inbound pilots/payloads.
        """
        with self._cv:
            self._listeners[node].append(event)

    def _notify(self, node: int) -> None:
        for ev in self._listeners[node]:
            ev.set()

    # -- sender side -------------------------------------------------------
    def post_pilot(self, pilot: Pilot) -> None:
        with self._cv:
            self.pilot_box[pilot.target].append(pilot)
            self._cv.notify_all()
            self._notify(pilot.target)

    def isend(self, target: int, payload: Payload) -> None:
        with self._cv:
            self.payload_box[target].append(payload)
            self.bytes_sent += payload.nbytes()
            self.num_messages += 1
            if payload.fragments is not None:
                self.coll_messages += 1
                self.coll_bytes += payload.nbytes()
                tid = payload.transfer_id
                if len(tid) == 4 and tid[2] == 3:
                    self.red_messages += 1
                    self.red_bytes += payload.nbytes()
            self._cv.notify_all()
            self._notify(target)

    # -- receiver side -----------------------------------------------------
    def poll(self, node: int) -> tuple[list[Pilot], list[Payload]]:
        with self._cv:
            pilots, self.pilot_box[node] = self.pilot_box[node], []
            payloads, self.payload_box[node] = self.payload_box[node], []
            return pilots, payloads

    def wait_any(self, node: int, timeout: float = 0.001) -> None:
        with self._cv:
            if not self.pilot_box[node] and not self.payload_box[node]:
                self._cv.wait(timeout)


@dataclass
class _PendingReceive:
    instr: Instruction                 # RECEIVE or SPLIT_RECEIVE
    remaining: Region                  # region still to be covered
    awaits: list[Instruction] = field(default_factory=list)  # AWAIT_RECEIVE children


@dataclass
class _PendingColl:
    """A COLL_RECV: exactly one packed round message from one peer (§9).

    Collective rounds are fully determined by the replicated schedule, so
    the receiver knows the source rank AND the exact fragment keys it will
    land: ``(member, slot)`` pairs for reduction partials, buffer-space
    boxes for region blocks.  Completion requires every expected key.
    """
    instr: Instruction                 # COLL_RECV
    remaining: set                     # fragment keys still outstanding


@dataclass
class _PendingGather:
    """A GATHER_RECEIVE: one fixed-stride slot per expected peer (§2.2).

    Unlike push traffic, gather payloads are addressed by their *source*
    rank — every peer sends the same buffer-space box (a reduction partial),
    and the arbiter lands payload ``p`` at ``arr[p.source]`` of the gather
    staging allocation.  Completion requires one payload from every source.
    """
    instr: Instruction                 # GATHER_RECEIVE
    remaining: set                     # source ranks still outstanding


class ReceiveArbiter:
    """Per-node receive-arbitration state machine (paper §4.2).

    Matches inbound pilots/payloads to receive instructions by transfer id,
    writes landed payloads into the destination allocation, and reports
    instruction completions.
    """

    def __init__(self, node: int, comm: Communicator, store):
        self.node = node
        self.comm = comm
        self.store = store                      # allocation id -> ndarray
        self.pending: dict[tuple, list[_PendingReceive]] = defaultdict(list)
        self.pending_gathers: dict[tuple, list[_PendingGather]] = defaultdict(list)
        self.pending_colls: dict[tuple, list[_PendingColl]] = defaultdict(list)
        self.early_payloads: dict[tuple, list[Payload]] = defaultdict(list)
        self.received: dict[tuple, Region] = defaultdict(Region.empty)

    def has_pending(self) -> bool:
        """Whether any receive is in flight (executor gates polling on this)."""
        return (any(self.pending.values())
                or any(self.pending_gathers.values())
                or any(self.pending_colls.values())
                or any(self.early_payloads.values()))

    def begin(self, instr: Instruction) -> None:
        if instr.itype == InstructionType.COLL_RECV:
            pc = _PendingColl(instr=instr, remaining=set(instr.coll_expect))
            self.pending_colls[instr.transfer_id].append(pc)
        elif instr.itype == InstructionType.GATHER_RECEIVE:
            pg = _PendingGather(instr=instr,
                                remaining=set(instr.gather_sources))
            self.pending_gathers[instr.transfer_id].append(pg)
        elif instr.itype in (InstructionType.RECEIVE, InstructionType.SPLIT_RECEIVE):
            pr = _PendingReceive(instr=instr, remaining=instr.recv_region)
            self.pending[instr.transfer_id].append(pr)
        elif instr.itype == InstructionType.AWAIT_RECEIVE:
            for pr in self.pending.get(instr.transfer_id, []):
                if pr.instr is instr.split_parent:
                    pr.awaits.append(instr)
                    return
            # parent may already be fully received
            self.pending[instr.transfer_id].append(
                _PendingReceive(instr=instr.split_parent, remaining=Region.empty(),
                                awaits=[instr]))

    def _land(self, pr: _PendingReceive, payload: Payload) -> None:
        alloc = pr.instr.recv_alloc
        arr = self.store[alloc.aid]
        off = alloc.offset_of(payload.box)
        slices = tuple(slice(o, o + s) for o, s in zip(off, payload.box.shape))
        arr[slices] = payload.data

    def _land_gather(self, pg: _PendingGather, payload: Payload) -> None:
        """Land a reduction partial at its source rank's fixed-stride slot."""
        arr = self.store[pg.instr.recv_alloc.aid]
        arr[payload.source] = payload.data.reshape(arr.shape[1:])

    def _land_coll(self, pc: _PendingColl, payload: Payload) -> None:
        """Land every fragment of one packed collective round message."""
        instr = pc.instr
        if instr.coll_land:
            # allreduce slot-range fragments: the landing map names the
            # target allocation and flat range per expected key
            lmap = {f.key: f for f in instr.coll_land}
            for key, data in payload.fragments:
                f = lmap.get(key)
                if f is None:
                    continue
                lo, hi = f.srange
                self.store[f.alloc.aid][lo:hi] = data
                pc.remaining.discard(key)
            return
        for key, data in payload.fragments:
            if isinstance(key, Box):    # buffer-space region fragment
                alloc = instr.coll_allocs[0]
                arr = self.store[alloc.aid]
                off = alloc.offset_of(key)
                slices = tuple(slice(o, o + s)
                               for o, s in zip(off, key.shape))
                arr[slices] = data
            else:                       # (member, slot) partial fragment
                member, slot = key
                arr = self.store[instr.coll_allocs[member].aid]
                arr[slot] = data.reshape(arr.shape[1:])
            pc.remaining.discard(key)

    def step(self, completions: list[Instruction]) -> None:
        """Drain mailboxes; append completed instructions to ``completions``."""
        pilots, payloads = self.comm.poll(self.node)
        # pilots tell us geometry early; with the mailbox transport the
        # payload itself carries geometry, so pilots only update accounting.
        for p in payloads:
            self.early_payloads[p.transfer_id].append(p)
        # collective rounds: match by (round-tagged transfer id, source);
        # one packed message lands all expected fragments at once
        for tid, plist in list(self.early_payloads.items()):
            pcs = self.pending_colls.get(tid)
            if not pcs:
                continue
            still: list[Payload] = []
            for payload in plist:
                landed = False
                if payload.fragments is not None:
                    for pc in pcs:
                        if payload.source == pc.instr.coll_source:
                            self._land_coll(pc, payload)
                            landed = True
                            break
                if not landed:
                    still.append(payload)
            self.early_payloads[tid] = still
        for tid, pcs in list(self.pending_colls.items()):
            done = [pc for pc in pcs
                    if not pc.remaining and pc.instr.state == "issued"]
            for pc in done:
                completions.append(pc.instr)
                pcs.remove(pc)
            if not pcs:
                del self.pending_colls[tid]
        # gather receives: match by (transfer id, source), complete when every
        # expected peer landed exactly once
        for tid, plist in list(self.early_payloads.items()):
            pgs = self.pending_gathers.get(tid)
            if not pgs:
                continue
            still: list[Payload] = []
            for payload in plist:
                landed = False
                for pg in pgs:
                    if payload.source in pg.remaining:
                        self._land_gather(pg, payload)
                        pg.remaining.discard(payload.source)
                        landed = True
                        break
                if not landed:
                    still.append(payload)
            self.early_payloads[tid] = still
        for tid, pgs in list(self.pending_gathers.items()):
            done = [pg for pg in pgs
                    if not pg.remaining and pg.instr.state == "issued"]
            for pg in done:
                completions.append(pg.instr)
                pgs.remove(pg)
            if not pgs:
                del self.pending_gathers[tid]
        for tid, plist in list(self.early_payloads.items()):
            prs = self.pending.get(tid, [])
            if not prs:
                continue
            still: list[Payload] = []
            for payload in plist:
                landed = False
                for pr in prs:
                    if pr.remaining.is_empty():
                        continue
                    inter = pr.remaining.intersect(Region.from_box(payload.box))
                    if inter.is_empty():
                        continue
                    self._land(pr, payload)
                    pr.remaining = pr.remaining.difference(Region.from_box(payload.box))
                    self.received[tid] = self.received[tid].union(Region.from_box(payload.box))
                    landed = True
                    break
                if not landed:
                    still.append(payload)
            self.early_payloads[tid] = still
        # completion checks
        for tid, prs in list(self.pending.items()):
            done_prs = []
            for pr in prs:
                if pr.remaining.is_empty() and pr.instr.state == "issued":
                    if pr.instr.itype == InstructionType.RECEIVE:
                        completions.append(pr.instr)
                        done_prs.append(pr)
                    elif pr.instr.itype == InstructionType.SPLIT_RECEIVE:
                        completions.append(pr.instr)
                        # keep entry for awaits
                # await-receive: complete when its subregion is covered
                for aw in list(pr.awaits):
                    if aw.state == "issued" and self.received[tid].contains(aw.recv_region):
                        completions.append(aw)
                        pr.awaits.remove(aw)
                if (pr.remaining.is_empty() and not pr.awaits
                        and pr.instr.state == "done"):
                    done_prs.append(pr)
            for pr in done_prs:
                if pr in prs:
                    prs.remove(pr)
