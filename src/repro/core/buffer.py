"""Virtualized buffers and accessors (paper §2.2).

A ``VirtualBuffer`` has a global index space but no storage of its own —
storage materializes as per-memory backing *allocations* managed by the
instruction-graph generator.  ``Accessor`` bundles a buffer, an access mode
and a range mapper; it is the sole way kernels interact with buffers.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .range_mapper import RangeMapper
from .reduction import Reduction, reduction  # noqa: F401 — re-export: kernels
# bind reductions next to accessors, so both descriptors live in one namespace
from .region import Box, Region

_buffer_ids = itertools.count()


class AccessMode(enum.Enum):
    READ = "read"
    WRITE = "write"           # discard-write: previous contents dead
    READ_WRITE = "read_write"

    @property
    def is_producer(self) -> bool:
        return self in (AccessMode.WRITE, AccessMode.READ_WRITE)

    @property
    def is_consumer(self) -> bool:
        return self in (AccessMode.READ, AccessMode.READ_WRITE)


@dataclass
class VirtualBuffer:
    shape: tuple[int, ...]
    dtype: np.dtype = field(default_factory=lambda: np.dtype(np.float64))
    name: str = ""
    bid: int = field(default_factory=lambda: next(_buffer_ids))
    # host-side initial contents (optional); region initialized from user data
    initial_value: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        self.shape = tuple(int(s) for s in self.shape)
        self.dtype = np.dtype(self.dtype)
        if not self.name:
            self.name = f"B{self.bid}"
        if self.initial_value is not None:
            iv = np.asarray(self.initial_value, dtype=self.dtype)
            if iv.shape != self.shape:
                raise ValueError(f"initial value shape {iv.shape} != {self.shape}")
            self.initial_value = iv

    @property
    def full_box(self) -> Box:
        return Box.full(self.shape)

    @property
    def full_region(self) -> Region:
        return Region.from_box(self.full_box)

    def elem_bytes(self) -> int:
        return self.dtype.itemsize

    def __hash__(self) -> int:
        return self.bid

    def __repr__(self) -> str:
        return f"VirtualBuffer({self.name}, shape={self.shape}, dtype={self.dtype})"


@dataclass(frozen=True)
class Accessor:
    buffer: VirtualBuffer
    mode: AccessMode
    range_mapper: RangeMapper

    def mapped_region(self, chunk: Box) -> Region:
        return self.range_mapper(chunk, self.buffer.shape)


def read(buffer: VirtualBuffer, rm: RangeMapper) -> Accessor:
    return Accessor(buffer, AccessMode.READ, rm)


def write(buffer: VirtualBuffer, rm: RangeMapper) -> Accessor:
    return Accessor(buffer, AccessMode.WRITE, rm)


def read_write(buffer: VirtualBuffer, rm: RangeMapper) -> Accessor:
    return Accessor(buffer, AccessMode.READ_WRITE, rm)
