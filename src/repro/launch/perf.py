import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimbing harness.

Three cells (chosen from the baseline roofline table — see EXPERIMENTS.md):

  * qwen2_1_5b   x train_4k     — canonical 6ND train step (represents the
                                  framework's main workload)
  * minitron_4b  x prefill_32k  — most collective-bound baseline
  * granite_moe_3b_a800m x train_4k — worst roofline fraction (MFU 0.005)

For each cell the harness lowers a sequence of variants (baseline first) on
the single-pod mesh and records the three roofline terms per variant into
``artifacts/perf/<cell>.json``.  The hypothesis -> change -> measure log
lives in EXPERIMENTS.md §Perf.

Run: PYTHONPATH=src python -m repro.launch.perf [cell ...]
"""

import dataclasses
import json
import sys
import time
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import _compile_one
from repro.launch.hloanalysis import analyze
from repro.launch.mesh import make_production_mesh

ART = Path(__file__).resolve().parents[3] / "artifacts" / "perf"

PEAK, HBM, ICI = 197e12, 819e9, 4 * 50e9


def terms(stats: dict, rec_extra: dict) -> dict:
    coll = sum(stats["coll"].values())
    c, m, n = stats["flops"] / PEAK, stats["bytes"] / HBM, coll / ICI
    step = max(c, m, n)
    out = dict(compute=c, memory=m, collective=n, step_time=step,
               dominant=max(("compute", c), ("memory", m),
                            ("collective", n), key=lambda kv: kv[1])[0],
               flops=stats["flops"], bytes=stats["bytes"],
               coll_bytes=coll, **rec_extra)
    return out


def _flash_kernel_traffic(cfg, spec, *, train: bool, dp: int = 16) -> float:
    """Analytic per-device HBM traffic of the FUSED Pallas flash kernel:
    q/k/v/o (+grads) cross HBM once per pass; block intermediates live in
    VMEM scratch.  Used to project the TPU-kernel memory term from the
    attention-ablated compile (see EXPERIMENTS.md §Perf methodology)."""
    if cfg.num_heads == 0:
        return 0.0
    b_loc = spec["global_batch"] / dp
    S = spec["seq_len"]
    e = 2  # bf16
    q_sz = b_loc * S * cfg.num_heads * cfg.hd * e
    kv_sz = b_loc * S * cfg.num_kv_heads * cfg.hd * e
    lse = b_loc * S * cfg.num_heads * 4
    fwd = q_sz + 2 * kv_sz + q_sz + lse                  # r q,k,v; w o,lse
    bwd = (2 * q_sz + 2 * kv_sz + lse) + (q_sz + 2 * kv_sz)  # r + w grads
    per_layer = fwd + (fwd + bwd if train else 0.0)      # remat recompute
    n_attn = (cfg.num_layers if cfg.family in ("dense", "moe", "vlm")
              else cfg.num_layers // cfg.attn_every if cfg.family == "hybrid"
              else cfg.num_layers)
    return per_layer * n_attn


def run_variants(arch: str, shape: str, variants: list[tuple[str, dict]],
                 *, project_kernel_from: str | None = None):
    spec = SHAPES[shape]
    mesh = make_production_mesh()
    base_cfg = get_config(arch)
    results = []
    model_flops = None

    def report(t):
        print(f"[perf] {arch}/{shape} {t['variant']:28s} "
              f"dom={t['dominant']:10s} step={t['step_time']:8.3f}s "
              f"c={t['compute']:.3f} m={t['memory']:.3f} "
              f"n={t['collective']:.3f} mfu={t['mfu']:.4f}", flush=True)

    for name, overrides in variants:
        overrides = dict(overrides)
        vmesh = mesh
        if "_mesh" in overrides:
            import jax
            d, m = overrides.pop("_mesh")
            vmesh = jax.make_mesh((d, m), ("data", "model"))
        cfg = dataclasses.replace(base_cfg, **overrides)
        t0 = time.time()
        compiled, _ = _compile_one(cfg, spec, vmesh)
        stats = analyze(compiled.as_text())
        if model_flops is None:
            # train: 6ND (fwd+bwd); prefill/decode: 2ND (fwd only)
            mult = 6 if spec["kind"] == "train" else 2
            D = (spec["seq_len"] * spec["global_batch"]
                 if spec["kind"] != "decode" else spec["global_batch"])
            model_flops = mult * cfg.param_count(active_only=True) * D
        t = terms(stats, {"variant": name, "overrides": overrides,
                          "mesh_shape": tuple(vmesh.devices.shape),
                          "compile_s": round(time.time() - t0, 1)})
        t["mfu"] = model_flops / (256 * PEAK * t["step_time"])
        results.append(t)
        report(t)

    if project_kernel_from is not None:
        # lower the attention-ablated variant -> non-attention floor, then
        # add the analytic fused-kernel traffic
        src = next(r for r in results if r["variant"] == project_kernel_from)
        import jax
        pmesh = (mesh if tuple(src["mesh_shape"]) == tuple(mesh.devices.shape)
                 else jax.make_mesh(tuple(src["mesh_shape"]),
                                    ("data", "model")))
        cfg = dataclasses.replace(base_cfg, ablate_attention=True,
                                  **src["overrides"])
        compiled, _ = _compile_one(cfg, spec, pmesh)
        floor = analyze(compiled.as_text())
        ktraffic = _flash_kernel_traffic(base_cfg, spec,
                                         train=spec["kind"] == "train",
                                         dp=src["mesh_shape"][0])
        m = (floor["bytes"] + ktraffic) / HBM
        c, n = src["compute"], src["collective"]
        step = max(c, m, n)
        t = dict(compute=c, memory=m, collective=n, step_time=step,
                 dominant=max(("compute", c), ("memory", m),
                              ("collective", n), key=lambda kv: kv[1])[0],
                 flops=src["flops"], bytes=floor["bytes"] + ktraffic,
                 coll_bytes=src["coll_bytes"],
                 variant="+pallas_fused(projected)",
                 overrides={"note": "attention-ablated compile + analytic "
                                    "fused-kernel traffic"},
                 mfu=model_flops / (256 * PEAK * step))
        results.append(t)
        report(t)

    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{arch}__{shape}.json").write_text(json.dumps(results, indent=1))
    return results


CELLS = {
    "qwen_train": lambda: run_variants("qwen2_1_5b", "train_4k", [
        ("baseline", {}),
        ("+flash_attention", dict(flash_attention=True)),
        ("+bf16_params", dict(flash_attention=True, param_dtype="bfloat16")),
        ("+no_remat", dict(flash_attention=True, param_dtype="bfloat16",
                           remat=False)),
        ("+mesh_32x8", dict(flash_attention=True, param_dtype="bfloat16",
                            _mesh=(32, 8))),
        ("+mesh_64x4", dict(flash_attention=True, param_dtype="bfloat16",
                            _mesh=(64, 4))),
        ("+mesh_128x2", dict(flash_attention=True, param_dtype="bfloat16",
                             _mesh=(128, 2))),
        ("+mesh_256x1_pure_dp", dict(flash_attention=True,
                                     param_dtype="bfloat16", _mesh=(256, 1))),
    ], project_kernel_from="+mesh_128x2"),
    "minitron_prefill": lambda: run_variants("minitron_4b", "prefill_32k", [
        ("baseline", {}),
        ("+flash_attention", dict(flash_attention=True)),
        ("+bf16_params", dict(flash_attention=True, param_dtype="bfloat16")),
        ("+mesh_32x8", dict(flash_attention=True, param_dtype="bfloat16",
                            _mesh=(32, 8))),
    ], project_kernel_from="+mesh_32x8"),
    "granite_train": lambda: run_variants("granite_moe_3b_a800m", "train_4k", [
        ("baseline", {}),
        ("+flash_attention", dict(flash_attention=True)),
        ("+bf16_params", dict(flash_attention=True, param_dtype="bfloat16")),
        ("+moe_group_2048", dict(flash_attention=True,
                                 param_dtype="bfloat16", moe_group=2048)),
        ("+mesh_32x8_ep8", dict(flash_attention=True, param_dtype="bfloat16",
                                _mesh=(32, 8))),
        ("+mesh_64x4_ep4", dict(flash_attention=True, param_dtype="bfloat16",
                                _mesh=(64, 4))),
    ], project_kernel_from="+mesh_32x8_ep8"),
}


def main():
    names = sys.argv[1:] or list(CELLS)
    for n in names:
        CELLS[n]()


if __name__ == "__main__":
    main()
