"""Step functions (train / prefill / decode) shared by the dry-run harness,
the training driver and the serving driver."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import ArchConfig, build_model
from repro.optim import adamw_init, adamw_update


def make_train_step(model, *, lr: float = 3e-4):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, lr=lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(model, cfg: ArchConfig, max_len: int):
    fam = cfg.family

    if fam == "audio":
        def prefill_step(params, batch):
            enc = model.encode(params, batch["frames"])
            logits = model.decode_train(params, enc, batch["tokens"])
            return logits[:, -1]
        return prefill_step

    if fam == "vlm":
        def prefill_step(params, batch):
            logits, cache = model.prefill(params, batch["vis"],
                                          batch["tokens"], max_len)
            return logits, cache
        return prefill_step

    def prefill_step(params, batch):
        return model.prefill(params, batch["tokens"], max_len)

    return prefill_step


def make_decode_step(model, cfg: ArchConfig):
    fam = cfg.family

    if fam == "audio":
        def decode_step(params, cache, ids, enc_out):
            return model.decode_step(params, cache, ids, enc_out)
        return decode_step

    if fam == "vlm":
        def decode_step(params, cache, ids):
            return model.decode_step(params, cache, ids)
        return decode_step

    def decode_step(params, cache, ids):
        return model.decode_step(params, cache, ids)

    return decode_step
