"""Loop-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which makes
it useless for scan-over-layers programs.  This module re-derives the three
roofline inputs directly from the post-optimization HLO:

  * flops            — dot flops (2 * result_elems * contracted_dim), rolled
                       up through fusions/calls, with while bodies multiplied
                       by their trip count (parsed from the loop condition);
  * hbm bytes        — operand + result bytes of top-level instructions
                       (post-opt top level ≈ fusion boundaries ≈ HBM traffic);
  * collective bytes — operand bytes per collective op, same loop scaling.

Validated against a fully unrolled compile in tests/test_dryrun.py.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
_ARGS_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that do not touch HBM at the top level
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "while",
             "conditional", "call", "custom-call", "domain",
             "opt-barrier"}


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            total += _elems(dims) * _DTYPE_BYTES[dt]
    return total


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: defaultdict(float))
    coll_count: dict = field(default_factory=lambda: defaultdict(int))

    def add(self, other: "Costs", times: float = 1.0) -> None:
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k, v in other.coll.items():
            self.coll[k] += v * times
        for k, v in other.coll_count.items():
            self.coll_count[k] += int(v * times)


@dataclass
class _Instr:
    name: str
    op: str
    type_str: str        # result type text
    rest: str            # everything after '=' (op + args + attrs)


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[_Instr]] = {}
        self.shapes: dict[str, str] = {}        # instr/param name -> type text
        self._parse(hlo_text)
        self._memo: dict[str, Costs] = {}

    # -- parsing -------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: list[_Instr] | None = None
        for raw in text.splitlines():
            line = raw.strip()
            if cur is None:
                m = _HEADER_RE.match(line)
                if m:
                    name, params = m.group(1), m.group(2)
                    cur = []
                    self.comps[name] = cur
                    # header params: "param_0.2: s32[], param_1.4: bf16[...]"
                    for pm in re.finditer(r"([\w.\-]+)\s*:\s*([^,]+)", params):
                        self.shapes[pm.group(1)] = pm.group(2)
                continue
            if line.startswith("}"):
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rest = m.group(1), m.group(2)
            # cut metadata/backend_config (may contain parens inside strings)
            cut = rest.find(", metadata=")
            body = rest if cut < 0 else rest[:cut]
            om = _OP_RE.search(" " + body)
            op = om.group(1) if om else ""
            # result type = text before the op token
            if om:
                idx = (" " + body).find(f" {op}(")
                type_str = body[:max(idx - 1, 0) + 1]
            else:
                type_str = body
            self.shapes[name] = type_str
            cur.append(_Instr(name, op, type_str, body))

    # -- helpers ---------------------------------------------------------------
    def _operand_names(self, instr: _Instr) -> list[str]:
        inner = instr.rest
        i = inner.find(f"{instr.op}(")
        if i < 0:
            return []
        inner = inner[i + len(instr.op) + 1:]
        # stop at closing paren of the call (args are flat %refs + literals)
        depth = 1
        out = []
        buf = []
        for ch in inner:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf.append(ch)
        return _ARGS_RE.findall("".join(buf))

    def _operand_bytes(self, instr: _Instr) -> int:
        total = 0
        for nm in self._operand_names(instr):
            t = self.shapes.get(nm)
            if t:
                total += _type_bytes(t)
        return total

    def _dot_flops(self, instr: _Instr) -> float:
        result = 0
        m = _SHAPE_RE.search(instr.type_str)
        if m:
            result = _elems(m.group(2))
        ops = self._operand_names(instr)
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
        contracted = 1
        if mc and ops:
            lhs_t = self.shapes.get(ops[0], "")
            sm = _SHAPE_RE.search(lhs_t)
            if sm:
                lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
                for ci in mc.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        contracted *= lhs_dims[int(ci)]
        return 2.0 * result * contracted

    def _trip_count(self, cond_name: str) -> int:
        best = 1
        for instr in self.comps.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", instr.rest):
                best = max(best, int(m.group(1)))
        return best

    # -- rollup -----------------------------------------------------------------
    def cost_of(self, comp_name: str) -> Costs:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = Costs()
        self._memo[comp_name] = total
        for instr in self.comps.get(comp_name, []):
            op = instr.op
            if op == "dot":
                total.flops += self._dot_flops(instr)
            base = op.replace("-start", "")
            if base in COLLECTIVES and not op.endswith("-done"):
                total.coll[base] += self._operand_bytes(instr)
                total.coll_count[base] += 1
            if op == "while":
                calls = dict(re.findall(r"(body|condition)=%?([\w.\-]+)",
                                        instr.rest))
                trip = self._trip_count(calls.get("condition", ""))
                total.add(self.cost_of(calls.get("body", "")), times=trip)
                total.bytes += _type_bytes(instr.type_str)  # loop state r/w
                continue
            # roll up called computations (compute + collectives; bytes stay
            # at the call site granularity via operands below)
            for attr in ("calls", "to_apply"):
                for cm in re.finditer(attr + r"=%?([\w.\-]+)", instr.rest):
                    callee = cm.group(1)
                    if callee in self.comps and callee != comp_name:
                        sub = self.cost_of(callee)
                        total.flops += sub.flops
                        for k, v in sub.coll.items():
                            total.coll[k] += v
                        for k, v in sub.coll_count.items():
                            total.coll_count[k] += v
            if op and op not in _FREE_OPS:
                if op == "dynamic-slice":
                    # reads only the slice (= result), not the big operand
                    total.bytes += 2 * _type_bytes(instr.type_str)
                elif op == "dynamic-update-slice":
                    # read-modify-write of the update region only
                    ops_n = self._operand_names(instr)
                    upd = self.shapes.get(ops_n[1], "") if len(ops_n) > 1 else ""
                    total.bytes += 2 * _type_bytes(upd)
                elif op == "fusion":
                    total.bytes += self._fusion_io_bytes(instr)
                else:
                    total.bytes += _type_bytes(instr.type_str)
                    total.bytes += self._operand_bytes(instr)
        return total

    def _fusion_io_bytes(self, instr: _Instr) -> float:
        """HBM traffic of one fusion: an operand that is only dynamic-sliced
        inside the fused computation counts as the slice, not the whole
        buffer (scan-over-layers reads ONE layer of the stacked params per
        iteration); an in-place dynamic-update-slice root writes only the
        update region."""
        callee = None
        for cm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", instr.rest):
            if cm.group(1) in self.comps:
                callee = cm.group(1)
                break
        operands = self._operand_names(instr)
        if callee is None:
            return float(_type_bytes(instr.type_str)
                         + sum(_type_bytes(self.shapes.get(o, ""))
                               for o in operands))
        body = self.comps[callee]
        params: dict[int, str] = {}
        for bi in body:
            if bi.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", bi.rest)
                if m:
                    params[int(m.group(1))] = bi.name
        read = 0.0
        for idx, opnd in enumerate(operands):
            pname = params.get(idx)
            full = float(_type_bytes(self.shapes.get(opnd, "")))
            if pname is None:
                read += full
                continue
            uses = [bi for bi in body if bi.name != pname
                    and re.search(rf"%{re.escape(pname)}\b", bi.rest)]
            if uses and all(u.op == "dynamic-slice" for u in uses):
                read += sum(_type_bytes(u.type_str) for u in uses)
            elif uses and all(
                    u.op == "dynamic-update-slice"
                    and (self._operand_names(u) or [""])[0] == pname
                    for u in uses):
                read += 0.0   # in-place-updated buffer: no full read
            else:
                read += full
        root = body[-1] if body else None
        if root is not None and root.op == "dynamic-update-slice":
            upd_ops = self._operand_names(root)
            upd = self.shapes.get(upd_ops[1], "") if len(upd_ops) > 1 else ""
            write = 2.0 * _type_bytes(upd)
        else:
            write = float(_type_bytes(instr.type_str))
        return read + write

    def entry(self) -> Costs:
        for name in self.comps:
            if "main" in name:
                return self.cost_of(name)
        name = max(self.comps, key=lambda n: len(self.comps[n]))
        return self.cost_of(name)


def analyze(hlo_text: str) -> dict:
    c = HloAnalysis(hlo_text).entry()
    return {"flops": c.flops, "bytes": c.bytes,
            "coll": {k: int(v) for k, v in c.coll.items()},
            "coll_count": dict(c.coll_count)}
