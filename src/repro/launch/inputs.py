"""Model inputs: real batches for smoke tests, ShapeDtypeStruct stand-ins for
the dry-run (weak-type-correct, shardable, no device allocation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ArchConfig, build_model
from repro.models.internvl import D_VIS


def train_batch(cfg: ArchConfig, batch: int, seq: int, *, rng=None):
    """A real (host) training batch for smoke tests / CPU training."""
    rng = rng or np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(batch, seq)).astype(np.int32)
    out = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.enc_frames, cfg.d_model)), cfg.adt)
    if cfg.family == "vlm":
        out["vis"] = jnp.asarray(
            rng.normal(size=(batch, cfg.vis_tokens, D_VIS)), cfg.adt)
    return out


def train_batch_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStruct stand-ins for every train_step input."""
    sds = jax.ShapeDtypeStruct
    out = {"tokens": sds((batch, seq), jnp.int32),
           "labels": sds((batch, seq), jnp.int32)}
    if cfg.family == "audio":
        out["frames"] = sds((batch, cfg.enc_frames, cfg.d_model), cfg.adt)
    if cfg.family == "vlm":
        out["vis"] = sds((batch, cfg.vis_tokens, D_VIS), cfg.adt)
    return out


def param_specs(cfg: ArchConfig):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    model = build_model(cfg)
    return model, jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def decode_ids_specs(batch: int):
    return jax.ShapeDtypeStruct((batch, 1), jnp.int32)
