import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with ShapeDtypeStruct inputs (no allocation).

For each cell this records:
  * memory_analysis()  — proves the sharded program fits per-device HBM
  * cost_analysis()    — HLO FLOPs / bytes for the roofline terms
  * collective bytes   — parsed from the compiled HLO text per collective op

Artifacts are written as JSON under ``artifacts/dryrun/`` and consumed by
``benchmarks.run`` (§Roofline) and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--jobs N]
"""

import argparse
import json
import math
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, ARCHITECTURES, LONG_CONTEXT_OK, SHAPES, get_config
from repro.launch.inputs import (cache_specs, decode_ids_specs, param_specs,
                                 train_batch_specs)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models.internvl import D_VIS
from repro.optim import adamw_init
from repro.sharding import batch_shardings, cache_shardings, param_shardings
from repro.optim.adamw import zero1_shardings
from jax.sharding import NamedSharding, PartitionSpec as P

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")



def _compile_one(cfg, shape_spec, mesh, *, zero1=True, donate=True):
    """Lower + compile one step program; returns (compiled, elapsed)."""
    seq, gbs, kind = (shape_spec["seq_len"], shape_spec["global_batch"],
                      shape_spec["kind"])
    model, pspecs = param_specs(cfg)
    pshard = param_shardings(pspecs, mesh)
    t0 = time.time()
    with mesh:
        if kind == "train":
            ostate_specs = jax.eval_shape(adamw_init, pspecs)
            oshard = (zero1_shardings(pspecs, mesh) if zero1
                      else {"m": pshard, "v": pshard,
                            "step": NamedSharding(mesh, P())})
            bspecs = train_batch_specs(cfg, gbs, seq)
            bshard = batch_shardings(bspecs, mesh)
            step = make_train_step(model)
            jitted = jax.jit(step,
                             in_shardings=(pshard, oshard, bshard),
                             out_shardings=(pshard, oshard,
                                            NamedSharding(mesh, P())),
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(pspecs, ostate_specs, bspecs)
        elif kind == "prefill":
            bspecs = train_batch_specs(cfg, gbs, seq)
            bspecs.pop("labels")
            bshard = batch_shardings(bspecs, mesh)
            step = make_prefill_step(model, cfg, max_len=seq)
            jitted = jax.jit(step, in_shardings=(pshard, bshard))
            lowered = jitted.lower(pspecs, bspecs)
        else:  # decode
            if cfg.family == "audio":
                cspecs = cache_specs(cfg, gbs, seq)
                cshard = cache_shardings(cspecs, mesh)
                enc_spec = jax.ShapeDtypeStruct(
                    (gbs, cfg.enc_frames, cfg.d_model), cfg.adt)
                enc_shard = batch_shardings(enc_spec, mesh)
                step = make_decode_step(model, cfg)
                jitted = jax.jit(step,
                                 in_shardings=(pshard, cshard,
                                               batch_shardings(
                                                   decode_ids_specs(gbs), mesh),
                                               enc_shard),
                                 donate_argnums=(1,) if donate else ())
                lowered = jitted.lower(pspecs, cspecs, decode_ids_specs(gbs),
                                       enc_spec)
            else:
                cspecs = cache_specs(cfg, gbs, seq)
                cshard = cache_shardings(cspecs, mesh)
                step = make_decode_step(model, cfg)
                jitted = jax.jit(step,
                                 in_shardings=(pshard, cshard,
                                               batch_shardings(
                                                   decode_ids_specs(gbs), mesh)),
                                 donate_argnums=(1,) if donate else ())
                lowered = jitted.lower(pspecs, cspecs, decode_ids_specs(gbs))

        compiled = lowered.compile()
    return compiled, time.time() - t0


def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               zero1: bool = True, donate: bool = True, cfg=None):
    """Lower + compile one (arch x shape x mesh) cell; returns the record.

    Per-device FLOPs / HBM bytes / collective bytes come from the loop-aware
    HLO analysis (launch/hloanalysis.py) — XLA's own cost_analysis counts
    scan bodies once (validated against an unrolled compile, see
    tests/test_dryrun.py).
    """
    from repro.launch.hloanalysis import analyze

    cfg = cfg or get_config(arch)
    spec = SHAPES[shape]
    seq, gbs, kind = spec["seq_len"], spec["global_batch"], spec["kind"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    nchips = math.prod(mesh.devices.shape)

    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if shape == "long_500k" and mod_name not in LONG_CONTEXT_OK:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "skipped": "full-attention arch; O(seq) KV cache infeasible "
                           "at 500k (DESIGN.md §Arch-applicability)"}

    compiled, dt = _compile_one(cfg, spec, mesh, zero1=zero1, donate=donate)
    mem = compiled.memory_analysis()
    stats = analyze(compiled.as_text())
    raw = compiled.cost_analysis()
    if isinstance(raw, (list, tuple)):       # older JAX returns [dict]
        raw = raw[0] if raw else {}

    rec = {
        "arch": arch, "shape": shape,
        "multi_pod": multi_pod, "chips": nchips,
        "seq_len": seq, "global_batch": gbs, "kind": kind,
        "compile_s": round(dt, 1),
        # per-device totals (loop-aware)
        "flops": stats["flops"],
        "bytes_accessed": stats["bytes"],
        "collectives": stats["coll"],
        "collective_counts": stats["coll_count"],
        "flops_rawhlo": float(raw.get("flops", 0.0)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }
    return rec


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path) -> dict:
    tag = "multi" if multi_pod else "single"
    out = out_dir / f"{arch}__{shape}__{tag}.json"
    try:
        rec = lower_cell(arch, shape, multi_pod=multi_pod)
    except Exception as e:  # noqa: BLE001 — recorded as cell failure
        rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
               "error": f"{type(e).__name__}: {e}"}
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    status = ("SKIP" if "skipped" in rec else
              "FAIL" if "error" in rec else "ok")
    print(f"[dryrun] {arch:24s} {shape:12s} {tag:6s} {status}"
          + (f" compile={rec.get('compile_s')}s flops={rec.get('flops', 0):.3e}"
             if status == "ok" else "")
          + (f" :: {rec['error'][:120]}" if status == "FAIL" else ""),
          flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=str(ART_DIR))
    args = ap.parse_args()
    out_dir = Path(args.out)

    cells: list[tuple[str, str, bool]] = []
    archs = ARCHITECTURES if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    failures = 0
    for a, s, m in cells:
        rec = run_cell(a, s, m, out_dir)
        if "error" in rec:
            failures += 1
    print(f"[dryrun] done: {len(cells)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
