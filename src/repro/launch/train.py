"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --steps 100 --batch 8 --seq 128 [--reduced] [--ckpt DIR]

``--reduced`` (default on CPU) trains the smoke-sized family variant; the
full configs are for TPU deployments (and are exercised via the dry-run).
The loop is the IDAG-orchestrated TrainLoop: data prefetch, step dispatch
and async checkpointing overlap via the paper's scheduling machinery.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU-scale)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.runtime import TrainLoop

    cfg = get_config(args.arch, reduced=not args.full)
    print(f"[train] {cfg.name} ({'full' if args.full else 'reduced'}): "
          f"{cfg.param_count() / 1e6:.1f}M params, "
          f"batch={args.batch} seq={args.seq}")
    loop = TrainLoop(cfg, global_batch=args.batch, seq_len=args.seq,
                     ckpt_dir=args.ckpt, ckpt_interval=args.ckpt_interval,
                     lr=args.lr)
    t0 = time.perf_counter()
    end, _, m = loop.run(args.steps)
    wall = time.perf_counter() - t0
    print(f"[train] {args.steps} steps in {wall:.1f}s "
          f"({wall / args.steps * 1e3:.0f} ms/step)")
    print(f"[train] loss {m.losses[0]:.4f} -> {m.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
