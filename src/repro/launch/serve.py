"""Serving drivers.

Two engines share this entry point:

``--engine model`` (default) runs the continuous-batching-lite ServeLoop:
requests are packed into slot batches, prefilled once, decoded in
lock-step; finished slots refill from the queue.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --requests 8 --max-new 16 [--full]

``--engine scheduler`` runs the persistent multi-tenant ServingRuntime
(core/memo.py): each tenant submits identical task windows in a loop, the
first few lower cold through TDAG->CDAG->IDAG, the rest replay the
memoized instruction window.  This path never imports jax — it exercises
the scheduler stack alone.

    PYTHONPATH=src python -m repro.launch.serve --engine scheduler \
        --tenants 4 --windows 50 --nodes 2 --devices 1
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np


def _main_model(args: argparse.Namespace) -> None:
    from repro.configs import get_config
    from repro.runtime import ServeLoop

    cfg = get_config(args.arch, reduced=not args.full)
    sl = ServeLoop(cfg, max_batch=args.max_batch, max_len=256)
    rng = np.random.default_rng(0)
    reqs = [sl.submit(rng.integers(0, cfg.vocab_size, size=args.prompt_len),
                      max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    sl.run_until_idle()
    wall = time.perf_counter() - t0
    tokens = sum(len(r.output) for r in reqs)
    print(f"[serve] {cfg.name}: {args.requests} requests, {tokens} tokens "
          f"in {wall:.2f}s ({tokens / wall:.1f} tok/s), "
          f"{sl.stats['batches']} batches, "
          f"{sl.stats['decode_steps']} decode steps")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.output}")


def _main_scheduler(args: argparse.Namespace) -> None:
    from repro.core import ServingRuntime, one_to_one, read_write

    w = args.width

    def kernel(chunk, v):
        v.set(chunk, v.get(chunk) + 1.0)

    with ServingRuntime(args.nodes, args.devices,
                        memo=not args.no_memo) as srv:
        tenants = [srv.tenant(f"t{i}") for i in range(args.tenants)]
        # read_write on an uninitialized region is undefined — seed zeros
        bufs = [t.buffer((w,), init=np.zeros(w), name="x") for t in tenants]

        def window(t, buf):
            t.submit("bump", (w,), [read_write(buf, one_to_one())], kernel)
            return t.run()

        lat_us: list[list[float]] = [[] for _ in tenants]

        def client(slot: int) -> None:
            t, buf = tenants[slot], bufs[slot]
            for _ in range(args.windows):
                t0 = time.perf_counter()
                window(t, buf).wait()
                lat_us[slot].append((time.perf_counter() - t0) * 1e6)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(args.tenants)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        wall = time.perf_counter() - t0

        total = args.tenants * args.windows
        flat = sorted(x for xs in lat_us for x in xs)
        p50 = flat[len(flat) // 2]
        p99 = flat[min(len(flat) - 1, int(len(flat) * 0.99))]
        stats = srv.memo_stats()
        print(f"[serve.scheduler] {args.tenants} tenant(s) x "
              f"{args.windows} windows on {args.nodes}x{args.devices}: "
              f"{total / wall:.0f} req/s, p50 {p50:.0f}us, p99 {p99:.0f}us")
        print(f"  memo: hits={stats['hits']} misses={stats['misses']} "
              f"unreplayable={stats['unreplayable']}")
        for name in sorted(stats["tenants"]):
            ts = stats["tenants"][name]
            print(f"  {name}: lowered={ts['lowered']} "
                  f"replayed={ts['replayed']} done={ts['done']}")
        for t, buf in zip(tenants, bufs):
            got = t.gather(buf)
            expect = float(args.windows)
            if not np.allclose(got, expect):
                raise SystemExit(
                    f"result mismatch for {t.name}: {got[:4]} != {expect}")
        print(f"  results verified: every element == {args.windows:.1f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("model", "scheduler"),
                    default="model")
    # model engine
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--full", action="store_true")
    # scheduler engine
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--windows", type=int, default=50)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--no-memo", action="store_true")
    args = ap.parse_args()
    if args.engine == "scheduler":
        _main_scheduler(args)
    else:
        _main_model(args)


if __name__ == "__main__":
    main()
