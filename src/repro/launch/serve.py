"""Batched serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --requests 8 --max-new 16 [--full]

Runs the continuous-batching-lite ServeLoop: requests are packed into slot
batches, prefilled once, decoded in lock-step; finished slots refill from
the queue.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.runtime import ServeLoop

    cfg = get_config(args.arch, reduced=not args.full)
    sl = ServeLoop(cfg, max_batch=args.max_batch, max_len=256)
    rng = np.random.default_rng(0)
    reqs = [sl.submit(rng.integers(0, cfg.vocab_size, size=args.prompt_len),
                      max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    sl.run_until_idle()
    wall = time.perf_counter() - t0
    tokens = sum(len(r.output) for r in reqs)
    print(f"[serve] {cfg.name}: {args.requests} requests, {tokens} tokens "
          f"in {wall:.2f}s ({tokens / wall:.1f} tok/s), "
          f"{sl.stats['batches']} batches, "
          f"{sl.stats['decode_steps']} decode steps")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.output}")


if __name__ == "__main__":
    main()
