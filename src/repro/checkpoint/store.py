"""Sharded, step-atomic checkpoint store.

Layout (one directory per step)::

    <dir>/step_000042/
        shard_00000.npz ... shard_NNNNN.npz   # leaves, round-robin by size
        MANIFEST.json                          # tree structure + leaf->shard
    <dir>/COMMITTED_000042                     # atomic marker, written last

Arrays are stored *logically global* (unsharded), which is what makes
elastic restore trivial: restoring onto any mesh is just a device_put with
the target shardings.  The marker file is written after every shard has been
fsync'd, so a crash mid-save never corrupts the latest restorable step.
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in leaves]
    return paths, [leaf for _, leaf in leaves], jax.tree.structure(tree)


def save_checkpoint(directory, step: int, tree, *, num_shards: int = 4) -> Path:
    directory = Path(directory)
    step_dir = directory / f"step_{step:06d}"
    step_dir.mkdir(parents=True, exist_ok=True)
    paths, leaves, _ = _flatten(tree)
    arrays = [np.asarray(l) for l in leaves]

    # round-robin by descending size for balanced shards
    order = sorted(range(len(arrays)), key=lambda i: -arrays[i].nbytes)
    assign: dict[int, int] = {}
    sizes = [0] * num_shards
    for i in order:
        s = sizes.index(min(sizes))
        assign[i] = s
        sizes[s] += arrays[i].nbytes

    manifest = {"step": step, "leaves": []}
    for shard in range(num_shards):
        payload = {f"a{i}": arrays[i] for i in range(len(arrays))
                   if assign[i] == shard}
        f = step_dir / f"shard_{shard:05d}.npz"
        with open(f, "wb") as fh:
            np.savez(fh, **payload)
            fh.flush()
            os.fsync(fh.fileno())
    for i, p in enumerate(paths):
        manifest["leaves"].append({"path": p, "key": f"a{i}",
                                   "shard": assign[i]})
    mf = step_dir / "MANIFEST.json"
    mf.write_text(json.dumps(manifest))
    marker = directory / f"COMMITTED_{step:06d}"
    with open(marker, "w") as fh:
        fh.write("ok")
        fh.flush()
        os.fsync(fh.fileno())
    return step_dir


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("COMMITTED_*")]
    return max(steps) if steps else None


def restore_checkpoint(directory, tree_like, *, step: Optional[int] = None):
    """Restore into the structure of ``tree_like`` (shapes define the tree).

    Returns (step, tree) or (None, None) when no committed step exists.
    """
    directory = Path(directory)
    step = step if step is not None else latest_step(directory)
    if step is None:
        return None, None
    step_dir = directory / f"step_{step:06d}"
    manifest = json.loads((step_dir / "MANIFEST.json").read_text())
    shards: dict[int, dict] = {}
    arrays: list[np.ndarray] = [None] * len(manifest["leaves"])  # type: ignore
    for i, ent in enumerate(manifest["leaves"]):
        s = ent["shard"]
        if s not in shards:
            shards[s] = np.load(step_dir / f"shard_{s:05d}.npz")
        arrays[i] = shards[s][ent["key"]]
    treedef = jax.tree.structure(tree_like)
    leaves_like = jax.tree.leaves(tree_like)
    assert len(leaves_like) == len(arrays), \
        f"checkpoint has {len(arrays)} leaves, target {len(leaves_like)}"
    out = jax.tree.unflatten(treedef, arrays)
    return step, out


def prune_old(directory, keep: int = 3) -> None:
    directory = Path(directory)
    steps = sorted(int(p.name.split("_")[1])
                   for p in directory.glob("COMMITTED_*"))
    import shutil
    for s in steps[:-keep]:
        (directory / f"COMMITTED_{s:06d}").unlink(missing_ok=True)
        shutil.rmtree(directory / f"step_{s:06d}", ignore_errors=True)
