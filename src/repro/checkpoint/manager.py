"""Checkpoint manager: interval policy, async save thread, retention,
restore-or-init with elastic resharding.

The async path mirrors the paper's computation/communication overlap applied
to I/O: ``save_async`` snapshots the (host-side) arrays and hands the disk
write to a background thread; the training loop only blocks if a previous
save is still in flight (bounded staleness of one).
"""

from __future__ import annotations

import threading
from pathlib import Path
from typing import Callable, Optional

import jax
import numpy as np

from .store import latest_step, prune_old, restore_checkpoint, save_checkpoint


class CheckpointManager:
    def __init__(self, directory, *, interval: int = 100, keep: int = 3,
                 num_shards: int = 4, async_save: bool = True):
        self.directory = Path(directory)
        self.interval = interval
        self.keep = keep
        self.num_shards = num_shards
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.saves = 0

    # -- save ----------------------------------------------------------------
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.interval == 0

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def close(self) -> Optional[BaseException]:
        """Join any in-flight async save without raising.

        Fault-triggered teardown must not orphan the save thread — a
        half-written checkpoint racing the next grid's restore — nor mask
        the original failure with a save error.  Returns the pending save
        error (if any) and clears it; the manager stays usable.
        """
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        err, self._error = self._error, None
        return err

    def save(self, step: int, tree) -> None:
        # snapshot to host BEFORE going async (donated buffers may be reused)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                num_shards=self.num_shards)
                prune_old(self.directory, keep=self.keep)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self.wait()
        self.saves += 1
        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    # -- restore ---------------------------------------------------------------
    def restore_or_init(self, init_fn: Callable[[], object], *,
                        shardings=None):
        """Restore the latest step (resharding onto ``shardings`` if given)
        or initialize fresh.  Returns (step, tree)."""
        like = jax.eval_shape(init_fn)
        step, tree = restore_checkpoint(self.directory, like)
        if step is None:
            tree = init_fn()
            step = 0
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return step, tree

    @property
    def latest(self) -> Optional[int]:
        return latest_step(self.directory)
