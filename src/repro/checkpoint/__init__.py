from .store import save_checkpoint, restore_checkpoint, latest_step
from .manager import CheckpointManager

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]
