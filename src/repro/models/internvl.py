"""InternVL2-style VLM backbone (arXiv:2404.16821).

The InternViT frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed patch embeddings [B, vis_tokens, d_vis]; a 2-layer MLP projector
maps them into the LM's embedding space and they are prepended to the text
tokens.  The language backbone (InternLM2-20B geometry) is the standard
``DecoderLM``; labels cover only the text positions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig
from .transformer import DecoderLM

D_VIS = 1024   # stub InternViT output width (projector input)


class InternVLModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.lm = DecoderLM(cfg)

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "lm": self.lm.init(k1),
            "proj": {"w1": L.init_linear(k2, D_VIS, self.cfg.d_model, self.cfg.pdt),
                     "w2": L.init_linear(k3, self.cfg.d_model, self.cfg.d_model,
                                         self.cfg.pdt)},
        }

    def _embed_multimodal(self, params, vis, ids):
        cfg = self.cfg
        v = L.linear(params["proj"]["w2"],
                     jax.nn.gelu(L.linear(params["proj"]["w1"],
                                          vis.astype(cfg.adt))))
        t = L.embed(params["lm"]["embed"], ids).astype(cfg.adt)
        return jnp.concatenate([v, t], axis=1)

    def forward(self, params, batch):
        """batch: {vis: [B,Tv,D_VIS], tokens: [B,S]}; logits over text part."""
        cfg = self.cfg
        x = self._embed_multimodal(params, batch["vis"], batch["tokens"])
        S = x.shape[1]
        positions = jnp.arange(S)
        mask = L.causal_mask(S, S)
        logits, aux = self.lm.forward_embedded(params["lm"], x, positions, mask)
        Tv = batch["vis"].shape[1]
        return logits[:, Tv:], aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                               batch.get("mask", None)) + 0.01 * aux

    # -- decode: delegate to the LM with a multimodal prefill ---------------------
    def prefill(self, params, vis, ids, max_len: int):
        cfg = self.cfg
        x = self._embed_multimodal(params, vis, ids)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.arange(S)
        mask = L.causal_mask(S, S)
        logits, _, kvs = self.lm.forward_embedded(params["lm"], x, positions,
                                                  mask, return_cache=True,
                                                  last_only=True)
        cache = self.lm.init_cache(B, max_len)
        W = cache["k"].shape[2]
        take = min(S, W)
        k_all, v_all = kvs
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_all[:, :, S - take:], 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_all[:, :, S - take:], 0, axis=2)
        cache["kpos"] = cache["kpos"].at[:take].set(jnp.arange(S - take, S))
        cache["pos"] = jnp.array(S, jnp.int32)
        return logits[:, -1], cache

    def init_cache(self, B, max_len):
        return self.lm.init_cache(B, max_len)

    def decode_step(self, params, cache, ids):
        return self.lm.decode_step(params["lm"], cache, ids)
