"""Unified architecture config covering all assigned families."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attn-free)
    num_kv_heads: int                # GQA kv heads
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default d_model // num_heads
    # attention details
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # SWA window (h2o-danube)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    mlp: str = "swiglu"              # swiglu | gelu
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_heads: int = 0               # mamba2 value heads
    ssm_expand: int = 2
    ssm_chunk: int = 64
    attn_every: int = 0              # zamba2: shared attn block period
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_frames: int = 1500           # stub frontend output length
    # vlm (internvl)
    vis_tokens: int = 256            # stub patch embeddings per image
    # numerics
    param_dtype: str = "float32"
    dtype: str = "bfloat16"          # activation/compute dtype
    remat: bool = True
    scan_layers: bool = True
    # perf knobs (§Perf hillclimbs; defaults = paper-faithful baseline)
    flash_attention: bool = False    # fused blockwise attention everywhere
    moe_group: int = 512             # MoE dispatch group size
    ablate_attention: bool = False   # measurement-only: zero out attention
                                     # mixing to isolate non-attention traffic

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adt(self):
        return jnp.dtype(self.dtype)

    def reduced(self, **overrides) -> "ArchConfig":
        """A smoke-test-sized config of the same family (see spec §f)."""
        small = dict(
            num_layers=min(self.num_layers, 2 if self.attn_every == 0 else 4),
            d_model=128,
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=32 if self.num_heads else None,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            ssm_chunk=16,
            attn_every=2 if self.attn_every else 0,
            enc_layers=min(self.enc_layers, 2),
            enc_frames=32,
            vis_tokens=16,
            sliding_window=64 if self.sliding_window else None,
            param_dtype="float32",
            dtype="float32",
        )
        small.update(overrides)
        return replace(self, **small)

    # -- parameter count (for 6ND model-flops accounting) --------------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; ``active_only`` counts top-k experts."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d                        # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d                   # lm head
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            hd, H, K = self.hd, self.num_heads, self.num_kv_heads
            attn = d * H * hd + 2 * d * K * hd + H * hd * d
            if self.family == "moe":
                e = self.top_k if active_only else self.num_experts
                mlp = e * 3 * d * self.d_ff
            else:
                mult = 3 if self.mlp == "swiglu" else 2
                mlp = mult * d * self.d_ff
            per_layer = attn + mlp + 2 * d
            n += L * per_layer
            if self.family == "audio":
                n += self.enc_layers * (attn + mlp + 2 * d) + L * attn  # cross
        elif self.family == "ssm":
            di = self.ssm_expand * d
            per_layer = d * (2 * di + 2 * self.ssm_state) + di * d + 2 * d
            n += L * per_layer
        elif self.family == "hybrid":
            di = self.ssm_expand * d
            ssm_l = d * (2 * di + 2 * self.ssm_state) + di * d + 2 * d
            hd, H, K = self.hd, self.num_heads, self.num_kv_heads
            attn = d * H * hd + 2 * d * K * hd + H * hd * d + 3 * d * self.d_ff
            n += L * ssm_l + attn   # shared attn block counted once
        return n
