"""Zamba2-style hybrid: Mamba2 backbone with a *shared* attention block
(arXiv:2411.15242).  One full attention+MLP block's parameters are reused at
every group boundary; each invocation keeps its own KV cache at decode time.

The group size is ``cfg.attn_every`` (must divide ``num_layers``); the
forward pass is a two-level scan: outer over groups (shared attention +
inner scan over that group's Mamba2 layers).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig
from .mamba2 import CONV_WIDTH, Mamba2LM
from .transformer import stack_layer_params


class Zamba2LM:
    def __init__(self, cfg: ArchConfig):
        assert cfg.attn_every and cfg.num_layers % cfg.attn_every == 0, \
            f"attn_every {cfg.attn_every} must divide num_layers {cfg.num_layers}"
        self.cfg = cfg
        self.mamba = Mamba2LM(cfg)
        self.groups = cfg.num_layers // cfg.attn_every

    # -- params ------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        ke, kh, ka, km, *kl = jax.random.split(key, 4 + cfg.num_layers)
        p = {"embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, cfg.pdt),
             "ln_f": L.init_norm(cfg.d_model, cfg.pdt),
             "shared": {"ln1": L.init_norm(cfg.d_model, cfg.pdt),
                        "ln2": L.init_norm(cfg.d_model, cfg.pdt),
                        "attn": L.init_attention(ka, cfg),
                        "mlp": L.init_mlp(km, cfg)},
             "layers": stack_layer_params(
                 [self.mamba.init_layer(k) for k in kl])}
        if not cfg.tie_embeddings:
            p["head"] = L.init_linear(kh, cfg.d_model, cfg.vocab_size, cfg.pdt)
        return p

    def _group_params(self, params):
        """Reshape stacked layer params [L,...] -> [G, g, ...]."""
        G, g = self.groups, self.cfg.attn_every
        return jax.tree.map(lambda v: v.reshape((G, g) + v.shape[1:]),
                            params["layers"])

    def _shared_block(self, sp, x, positions, mask, kv=None):
        cfg = self.cfg
        a, new_kv = L.attention(sp["attn"], cfg,
                                L.rms_norm(sp["ln1"], x, cfg.norm_eps),
                                positions, mask, kv=kv, causal=(kv is None),
                                use_kernel=cfg.flash_attention)
        x = x + a
        x = x + L.mlp(sp["mlp"], cfg, L.rms_norm(sp["ln2"], x, cfg.norm_eps))
        return x, new_kv

    # -- forward / loss -------------------------------------------------------
    def forward(self, params, ids):
        cfg = self.cfg
        B, S = ids.shape
        x = L.embed(params["embed"], ids).astype(cfg.adt)
        positions = jnp.arange(S)
        mask = L.causal_mask(S, S)
        gp = self._group_params(params)
        sp = params["shared"]

        def inner(x, lp):
            return self.mamba._block_seq(lp, x), None

        inner_fn = jax.checkpoint(inner) if cfg.remat else inner

        def outer(x, glp):
            x, _ = self._shared_block(sp, x, positions, mask)
            x, _ = jax.lax.scan(inner_fn, x, glp)
            return x, None

        x, _ = jax.lax.scan(outer, x, gp)
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            return L.unembed(params["embed"], x), 0.0
        return L.linear(params["head"], x).astype(jnp.float32), 0.0

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"])
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                               batch.get("mask", None))

    # -- decode -----------------------------------------------------------------
    def init_cache(self, B: int, max_len: int) -> dict:
        cfg = self.cfg
        m = self.mamba
        G, K, hd = self.groups, cfg.num_kv_heads, cfg.hd
        return {
            "conv": jnp.zeros((cfg.num_layers, B, CONV_WIDTH - 1, m.conv_dim),
                              cfg.adt),
            "ssm": jnp.zeros((cfg.num_layers, B, m.nheads, m.headdim,
                              cfg.ssm_state), cfg.adt),
            "k": jnp.zeros((G, B, max_len, K, hd), cfg.adt),
            "v": jnp.zeros((G, B, max_len, K, hd), cfg.adt),
            "kpos": jnp.full((max_len,), -1, jnp.int32),
            "pos": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, ids, max_len: int):
        cfg = self.cfg
        B, S = ids.shape
        x = L.embed(params["embed"], ids).astype(cfg.adt)
        positions = jnp.arange(S)
        mask = L.causal_mask(S, S)
        gp = self._group_params(params)
        sp = params["shared"]
        cache = self.init_cache(B, max_len)
        ks, vs, convs, ssms = [], [], [], []

        def inner(x, lp):
            # reuse the mamba prefill body to capture states
            xo, conv_tail, hlast = None, None, None
            xo, (conv_tail, hlast) = self._mamba_prefill_layer(lp, x)
            return xo, (conv_tail, hlast)

        x_cur = x
        for gi in range(self.groups):
            x_cur, (k, v) = self._shared_block(sp, x_cur, positions, mask)
            ks.append(k)
            vs.append(v)
            glp = jax.tree.map(lambda a: a[gi], gp)
            x_cur, (ct, hl) = jax.lax.scan(inner, x_cur, glp)
            convs.append(ct)
            ssms.append(hl)
        x_cur = L.rms_norm(params["ln_f"], x_cur, cfg.norm_eps)
        logits = (L.unembed(params["embed"], x_cur) if cfg.tie_embeddings else
                  L.linear(params["head"], x_cur).astype(jnp.float32))
        cache["k"] = cache["k"].at[:, :, :S].set(jnp.stack(ks))
        cache["v"] = cache["v"].at[:, :, :S].set(jnp.stack(vs))
        cache["kpos"] = cache["kpos"].at[:S].set(jnp.arange(S))
        cache["conv"] = jnp.concatenate(convs).astype(cfg.adt)
        cache["ssm"] = jnp.concatenate(ssms).astype(cfg.adt)
        cache["pos"] = jnp.array(S, jnp.int32)
        return logits[:, -1], cache

    def _mamba_prefill_layer(self, lp, x):
        """One mamba layer forward capturing (conv tail, final ssm state)."""
        cfg = self.cfg
        m = self.mamba
        from .mamba2 import causal_conv, ssd_chunked
        Bsz, S, _ = x.shape
        di, n, h = m.d_inner, cfg.ssm_state, m.nheads
        hin = L.rms_norm(lp["ln"], x, cfg.norm_eps)
        z, xBC, dt = m._mix_in(lp, hin)
        conv_tail = xBC[:, -(CONV_WIDTH - 1):, :]
        xBC = jax.nn.silu(causal_conv(xBC, lp["conv_w"].astype(x.dtype),
                                      lp["conv_b"].astype(x.dtype)))
        xs, Bm, Cm = jnp.split(xBC, [di, di + n], axis=-1)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
        A = -jnp.exp(lp["A_log"])
        a = (dt * A).astype(jnp.float32)
        xh = xs.reshape(Bsz, S, h, m.headdim)
        y, hlast = ssd_chunked(xh * dt.astype(x.dtype)[..., None], a,
                               Bm.astype(x.dtype), Cm.astype(x.dtype),
                               cfg.ssm_chunk)
        y = y + xh * lp["D"].astype(x.dtype)[:, None]
        y = y.reshape(Bsz, S, di)
        y = L.rms_norm(lp["norm"], y * jax.nn.silu(z), cfg.norm_eps)
        return x + L.linear(lp["out_proj"], y), (conv_tail, hlast)

    def decode_step(self, params, cache, ids):
        cfg = self.cfg
        B = ids.shape[0]
        pos = cache["pos"]
        T = cache["k"].shape[2]
        x = L.embed(params["embed"], ids).astype(cfg.adt)
        positions = pos[None].astype(jnp.int32)
        kpos = cache["kpos"].at[pos].set(pos)
        mask = (kpos >= 0)[None, :]                     # [1,T]
        gp = self._group_params(params)
        sp = params["shared"]
        K, hd = cfg.num_kv_heads, cfg.hd

        def mamba_step(x, lp_cache):
            lp, conv_st, ssm_st = lp_cache
            return self._mamba_decode_layer(lp, x, conv_st, ssm_st)

        ks_new, vs_new, convs, ssms = [], [], [], []
        x_cur = x
        for gi in range(self.groups):
            h = L.rms_norm(sp["ln1"], x_cur, cfg.norm_eps)
            q = L.linear(sp["attn"]["wq"], h).reshape(B, 1, cfg.num_heads, hd)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            kn = L.linear(sp["attn"]["wk"], h).reshape(B, 1, K, hd)
            vn = L.linear(sp["attn"]["wv"], h).reshape(B, 1, K, hd)
            kn = L.apply_rope(kn, positions, cfg.rope_theta)
            k_g = jax.lax.dynamic_update_slice_in_dim(cache["k"][gi], kn, pos,
                                                      axis=1)
            v_g = jax.lax.dynamic_update_slice_in_dim(cache["v"][gi], vn, pos,
                                                      axis=1)
            qg = q.reshape(B, 1, K, cfg.num_heads // K, hd)
            o = L._sdpa(qg, k_g, v_g, mask)
            x_cur = x_cur + L.linear(sp["attn"]["wo"],
                                     o.reshape(B, 1, cfg.num_heads * hd))
            x_cur = x_cur + L.mlp(sp["mlp"], cfg,
                                  L.rms_norm(sp["ln2"], x_cur, cfg.norm_eps))
            ks_new.append(k_g)
            vs_new.append(v_g)
            lo, hi = gi * cfg.attn_every, (gi + 1) * cfg.attn_every
            glp = jax.tree.map(lambda a: a[gi], gp)
            x_cur, (cs, ss) = jax.lax.scan(
                mamba_step, x_cur,
                (glp, cache["conv"][lo:hi], cache["ssm"][lo:hi]))
            convs.append(cs)
            ssms.append(ss)
        x_cur = L.rms_norm(params["ln_f"], x_cur, cfg.norm_eps)
        logits = (L.unembed(params["embed"], x_cur) if cfg.tie_embeddings else
                  L.linear(params["head"], x_cur).astype(jnp.float32))
        new_cache = {"k": jnp.stack(ks_new), "v": jnp.stack(vs_new),
                     "kpos": kpos, "pos": pos + 1,
                     "conv": jnp.concatenate(convs),
                     "ssm": jnp.concatenate(ssms)}
        return logits[:, 0], new_cache

    def _mamba_decode_layer(self, lp, x, conv_st, ssm_st):
        cfg = self.cfg
        m = self.mamba
        B = x.shape[0]
        di, n = m.d_inner, cfg.ssm_state
        hin = L.rms_norm(lp["ln"], x, cfg.norm_eps)
        z, xBC, dt = m._mix_in(lp, hin)
        hist = jnp.concatenate([conv_st, xBC], axis=1)
        w = lp["conv_w"].astype(x.dtype)
        conv_out = jnp.einsum("bwc,wc->bc", hist, w) + lp["conv_b"].astype(x.dtype)
        xBC1 = jax.nn.silu(conv_out)[:, None]
        xs, Bm, Cm = jnp.split(xBC1, [di, di + n], axis=-1)
        dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + lp["dt_bias"])
        A = -jnp.exp(lp["A_log"])
        a = jnp.exp(dtv * A)
        xh = xs[:, 0].reshape(B, m.nheads, m.headdim)
        dx = xh * dtv.astype(x.dtype)[..., None]
        ssm_new = (a.astype(x.dtype)[..., None, None] * ssm_st
                   + jnp.einsum("bhp,bn->bhpn", dx, Bm[:, 0]))
        y = jnp.einsum("bhpn,bn->bhp", ssm_new, Cm[:, 0])
        y = y + xh * lp["D"].astype(x.dtype)[:, None]
        y = y.reshape(B, 1, di)
        y = L.rms_norm(lp["norm"], y * jax.nn.silu(z), cfg.norm_eps)
        return x + L.linear(lp["out_proj"], y), (hist[:, 1:], ssm_new)
