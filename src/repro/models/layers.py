"""Core layers: norms, RoPE, GQA attention (+SWA, QKV bias), MLPs, MoE.

Pure-functional: ``init_*`` builds parameter pytrees, ``apply``-style
functions consume them.  All matmul dims are kept multiples of 128 where the
configs allow, activations run in ``cfg.dtype`` with fp32 softmax/norm
accumulation — the TPU-native layout expected by the MXU.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers


def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_linear(key, d_in, d_out, dtype, bias=False, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _normal(key, (d_in, d_out), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_norm(d, dtype):
    return {"g": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps=1e-5):
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * p["g"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention


def init_attention(key, cfg):
    ks = jax.random.split(key, 4)
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    return {
        "wq": init_linear(ks[0], d, H * hd, cfg.pdt, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, K * hd, cfg.pdt, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, K * hd, cfg.pdt, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], H * hd, d, cfg.pdt,
                          scale=1.0 / math.sqrt(H * hd * 2 * cfg.num_layers)),
    }


FLASH_THRESHOLD = 4096 * 4096   # S*T above which blockwise attention is used


def _sdpa(q, k, v, mask, *, use_kernel: bool = False, causal: bool = False,
          window: Optional[int] = None):
    """Grouped scaled-dot-product attention.

    q: [B,S,K,G,hd] (G = query groups per kv head), k/v: [B,T,K,hd],
    mask: [B,1,S,T] or broadcastable boolean (True = attend).

    Large S*T (long-context prefill) automatically takes the blockwise
    flash path so O(S*T) logits are never materialized; the Pallas TPU
    kernel is selected by ``use_kernel`` (see kernels/ops.py).
    """
    S, T = q.shape[1], k.shape[1]
    if use_kernel and S > 1:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    if causal and S > 1 and S * T > FLASH_THRESHOLD:
        from ..kernels.ref import flash_attention_ref
        return flash_attention_ref(q, k, v, causal=True, window=window)
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None, None] if mask.ndim == 3 else mask,
                       logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out


def causal_mask(S: int, T: int, offset: int = 0,
                window: Optional[int] = None) -> jnp.ndarray:
    """[S,T] boolean mask; query i attends key j iff j <= i+offset (and
    within the sliding window if given)."""
    qpos = jnp.arange(S)[:, None] + offset
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def attention(p, cfg, x, positions, mask, kv=None, *, use_kernel=False,
              causal=False):
    """kv: optional (k, v) override for cross-attention / cached decode."""
    B, S, d = x.shape
    if getattr(cfg, "ablate_attention", False) and kv is None:
        # measurement-only path (§Perf): QKV/O projections run, the O(S*T)
        # mixing is skipped — isolates attention-mixing HBM traffic
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
        qa = linear(p["wq"], x)
        ka = linear(p["wk"], x).reshape(B, S, K, hd)
        va = linear(p["wv"], x).reshape(B, S, K, hd)
        return linear(p["wo"], qa * 0.001), (ka, va)
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    G = H // K
    q = linear(p["wq"], x).reshape(B, S, H, hd)
    q = apply_rope(q, positions, cfg.rope_theta) if cfg.rope_theta else q
    if kv is None:
        k = linear(p["wk"], x).reshape(B, S, K, hd)
        v = linear(p["wv"], x).reshape(B, S, K, hd)
        k = apply_rope(k, positions, cfg.rope_theta) if cfg.rope_theta else k
    else:
        k, v = kv
    qg = q.reshape(B, S, K, G, hd)
    out = _sdpa(qg, k, v, mask, use_kernel=use_kernel, causal=causal,
                window=cfg.sliding_window)
    out = out.reshape(B, S, H * hd)
    return linear(p["wo"], out), (k, v)


# ---------------------------------------------------------------------------
# MLPs


def init_mlp(key, cfg, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    out_scale = 1.0 / math.sqrt(d_ff * 2 * cfg.num_layers)
    if cfg.mlp == "swiglu":
        return {"wi": init_linear(ks[0], d, d_ff, cfg.pdt),
                "wg": init_linear(ks[1], d, d_ff, cfg.pdt),
                "wo": init_linear(ks[2], d_ff, d, cfg.pdt, scale=out_scale)}
    return {"wi": init_linear(ks[0], d, d_ff, cfg.pdt),
            "wo": init_linear(ks[2], d_ff, d, cfg.pdt, scale=out_scale)}


def mlp(p, cfg, x):
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x)
    else:
        h = jax.nn.gelu(linear(p["wi"], x))
    return linear(p["wo"], h)


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style grouped top-k dispatch with capacity)


def init_moe(key, cfg):
    ks = jax.random.split(key, 4)
    d, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(F * 2 * cfg.num_layers)
    return {
        "router": init_linear(ks[0], d, E, jnp.float32),
        "wi": _normal(ks[1], (E, d, F), cfg.pdt, s_in),
        "wg": _normal(ks[2], (E, d, F), cfg.pdt, s_in),
        "wo": _normal(ks[3], (E, F, d), cfg.pdt, s_out),
    }


def moe(p, cfg, x, *, group_size: int = 512):
    """Top-k routed MoE with per-group expert capacity (token dropping).

    Tokens are processed in groups of ``group_size`` so the dispatch tensor
    [Gs, E, C] stays VMEM-friendly; experts run as one batched einsum over
    the leading expert dim — the layout that shards naturally over an
    expert-parallel mesh axis.
    Returns (output, aux_loss).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    tokens = x.reshape(-1, D)
    N = tokens.shape[0]
    Gs = min(group_size, N)
    assert N % Gs == 0, f"token count {N} not divisible by group {Gs}"
    G = N // Gs
    C = max(1, int(math.ceil(K * Gs / E * cfg.capacity_factor)))
    xg = tokens.reshape(G, Gs, D)

    logits = (xg.astype(jnp.float32) @ p["router"]["w"])       # [G,Gs,E]
    probs = jax.nn.softmax(logits, axis=-1)

    # load-balancing aux loss (Switch): E * mean(frac_tokens * frac_probs)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=1)
    frac_probs = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    # iterative top-k with capacity assignment
    combine = jnp.zeros((G, Gs, E, C), dtype=jnp.float32)
    remaining = probs
    fill = jnp.zeros((G, E), dtype=jnp.int32)                   # slots used
    for _ in range(K):
        idx = jnp.argmax(remaining, axis=-1)                    # [G,Gs]
        gate = jnp.take_along_axis(remaining, idx[..., None], -1)[..., 0]
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)      # [G,Gs,E]
        pos = jnp.cumsum(onehot, axis=1) - onehot               # pos within group
        pos = pos + fill[:, None, :]                            # offset by filled
        in_cap = pos < C
        slot = jnp.einsum("gse,gse->gs", onehot, pos).astype(jnp.int32)
        keep = jnp.einsum("gse,gse->gs", onehot, in_cap.astype(jnp.float32)) > 0
        cslot = jax.nn.one_hot(jnp.clip(slot, 0, C - 1), C, dtype=jnp.float32)
        combine = combine + (gate * keep)[..., None, None] * \
            onehot[..., None] * cslot[:, :, None, :]
        fill = fill + jnp.sum(onehot * in_cap, axis=1).astype(jnp.int32)
        remaining = remaining * (1.0 - onehot)

    # renormalize kept gates over the k choices (granite-style top-k softmax)
    denom = jnp.sum(combine, axis=(2, 3), keepdims=True) + 1e-9
    combine = combine / denom
    dispatch = (combine > 0).astype(x.dtype)                    # [G,Gs,E,C]

    xin = jnp.einsum("gsec,gsd->egcd", dispatch, xg)            # [E,G,C,D]
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, p["wg"].astype(x.dtype)))
    h = h * jnp.einsum("egcd,edf->egcf", xin, p["wi"].astype(x.dtype))
    out_e = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(x.dtype))
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), out_e)
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# embedding / head


def init_embedding(key, vocab, d, dtype):
    return {"e": _normal(key, (vocab, d), dtype, 0.02)}


def embed(p, ids):
    return p["e"][ids]


def unembed(p, x, dtype=jnp.float32):
    return (x @ p["e"].T.astype(x.dtype)).astype(dtype)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0] - lse
    loss = -ll
    if mask is not None:
        return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(loss)
