"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, enc_frames, d_model] (the output the two
conv layers would produce).  Encoder is bidirectional with sinusoidal
positions; decoder has causal self-attention + cross-attention with learned
positions.  No RoPE (rope_theta=0 in the config).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig
from .transformer import stack_layer_params

MAX_TGT = 32768   # extended decoder position table (assignment shapes reach
                  # 32k; whisper's original 448 noted in DESIGN.md)


def sinusoid(S, d):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class WhisperModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- params --------------------------------------------------------------
    def _attn_mlp_block(self, key, cross=False):
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        p = {"ln1": L.init_norm(cfg.d_model, cfg.pdt),
             "ln2": L.init_norm(cfg.d_model, cfg.pdt),
             "attn": L.init_attention(ks[0], cfg),
             "mlp": L.init_mlp(ks[1], cfg)}
        if cross:
            p["lnx"] = L.init_norm(cfg.d_model, cfg.pdt)
            p["xattn"] = L.init_attention(ks[2], cfg)
        return p

    def init(self, key):
        cfg = self.cfg
        ke, kp, *kl = jax.random.split(key, 2 + cfg.enc_layers + cfg.num_layers)
        enc_keys, dec_keys = kl[:cfg.enc_layers], kl[cfg.enc_layers:]
        return {
            "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, cfg.pdt),
            "pos_dec": L._normal(kp, (MAX_TGT, cfg.d_model), cfg.pdt, 0.01),
            "ln_enc": L.init_norm(cfg.d_model, cfg.pdt),
            "ln_f": L.init_norm(cfg.d_model, cfg.pdt),
            "enc": stack_layer_params(
                [self._attn_mlp_block(k) for k in enc_keys]),
            "dec": stack_layer_params(
                [self._attn_mlp_block(k, cross=True) for k in dec_keys]),
        }

    # -- encoder ---------------------------------------------------------------
    def encode(self, params, frames):
        """frames: [B, F, d_model] precomputed frame embeddings (stub)."""
        cfg = self.cfg
        B, F, _ = frames.shape
        x = frames.astype(cfg.adt) + sinusoid(F, cfg.d_model).astype(cfg.adt)
        positions = jnp.arange(F)
        mask = jnp.ones((F, F), bool)

        def body(x, lp):
            a, _ = L.attention(lp["attn"], cfg,
                               L.rms_norm(lp["ln1"], x, cfg.norm_eps),
                               positions, mask)
            x = x + a
            x = x + L.mlp(lp["mlp"], cfg, L.rms_norm(lp["ln2"], x, cfg.norm_eps))
            return x, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["enc"])
        return L.rms_norm(params["ln_enc"], x, cfg.norm_eps)

    # -- decoder -----------------------------------------------------------------
    def decode_train(self, params, enc_out, ids):
        cfg = self.cfg
        B, S = ids.shape
        F = enc_out.shape[1]
        x = (L.embed(params["embed"], ids).astype(cfg.adt)
             + params["pos_dec"][:S].astype(cfg.adt))
        positions = jnp.arange(S)
        self_mask = L.causal_mask(S, S)
        x_mask = jnp.ones((S, F), bool)

        def body(x, lp):
            a, _ = L.attention(lp["attn"], cfg,
                               L.rms_norm(lp["ln1"], x, cfg.norm_eps),
                               positions, self_mask, causal=True)
            x = x + a
            K, hd = cfg.num_kv_heads, cfg.hd
            ek = L.linear(lp["xattn"]["wk"], enc_out).reshape(B, F, K, hd)
            ev = L.linear(lp["xattn"]["wv"], enc_out).reshape(B, F, K, hd)
            a, _ = L.attention(lp["xattn"], cfg,
                               L.rms_norm(lp["lnx"], x, cfg.norm_eps),
                               positions, x_mask, kv=(ek, ev))
            x = x + a
            x = x + L.mlp(lp["mlp"], cfg, L.rms_norm(lp["ln2"], x, cfg.norm_eps))
            return x, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["dec"])
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        return L.unembed(params["embed"], x)   # tied embeddings (whisper)

    def forward(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        return self.decode_train(params, enc_out, batch["tokens"]), 0.0

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                               batch.get("mask", None))

    # -- cached decode --------------------------------------------------------------
    def init_cache(self, B, max_len, enc_out=None):
        cfg = self.cfg
        Lr, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
        return {
            "k": jnp.zeros((Lr, B, max_len, K, hd), cfg.adt),
            "v": jnp.zeros((Lr, B, max_len, K, hd), cfg.adt),
            "pos": jnp.zeros((), jnp.int32),
        }

    def decode_step(self, params, cache, ids, enc_out):
        cfg = self.cfg
        B = ids.shape[0]
        pos = cache["pos"]
        T = cache["k"].shape[2]
        F = enc_out.shape[1]
        x = (L.embed(params["embed"], ids).astype(cfg.adt)
             + jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1)
             .astype(cfg.adt)[None])
        mask = (jnp.arange(T) <= pos)[None, :]
        x_mask = jnp.ones((1, F), bool)
        K, hd = cfg.num_kv_heads, cfg.hd

        def body(carry, lp_kc):
            x, = carry
            lp, k_l, v_l = lp_kc
            h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
            q = L.linear(lp["attn"]["wq"], h).reshape(B, 1, cfg.num_heads, hd)
            kn = L.linear(lp["attn"]["wk"], h).reshape(B, 1, K, hd)
            vn = L.linear(lp["attn"]["wv"], h).reshape(B, 1, K, hd)
            k_l = jax.lax.dynamic_update_slice_in_dim(k_l, kn, pos, axis=1)
            v_l = jax.lax.dynamic_update_slice_in_dim(v_l, vn, pos, axis=1)
            qg = q.reshape(B, 1, K, cfg.num_heads // K, hd)
            o = L._sdpa(qg, k_l, v_l, mask)
            x = x + L.linear(lp["attn"]["wo"], o.reshape(B, 1, -1))
            # cross attention against the (static) encoder output
            ek = L.linear(lp["xattn"]["wk"], enc_out).reshape(B, F, K, hd)
            ev = L.linear(lp["xattn"]["wv"], enc_out).reshape(B, F, K, hd)
            a, _ = L.attention(lp["xattn"], cfg,
                               L.rms_norm(lp["lnx"], x, cfg.norm_eps),
                               jnp.zeros((1,), jnp.int32), x_mask, kv=(ek, ev))
            x = x + a
            x = x + L.mlp(lp["mlp"], cfg, L.rms_norm(lp["ln2"], x, cfg.norm_eps))
            return (x,), (k_l, v_l)

        (x,), (k_new, v_new) = jax.lax.scan(
            body, (x,), (params["dec"], cache["k"], cache["v"]))
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        logits = L.unembed(params["embed"], x)[:, 0]
        return logits, {"k": k_new, "v": v_new, "pos": pos + 1}
