"""Mamba2 — state-space duality (SSD) blocks, arXiv:2405.21060.

Training/prefill uses the chunked matmul-friendly SSD algorithm (quadratic
within a chunk, linear state passing between chunks) — the formulation that
maps onto the MXU.  Decode is the O(1) recurrent state update, which is what
makes ``long_500k`` tractable for this family.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig

CONV_WIDTH = 4


def segsum(a):
    """log-space segment-sum: out[..., i, j] = sum_{k=j+1..i} a[..., k]."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, a, B, C, chunk: int):
    """SSD scan (discrete) — x:[b,s,h,p] a:[b,s,h] B,C:[b,s,n] (1 group).

    a is the per-step log-decay (log a_t = -dt*A). Returns y:[b,s,h,p] and
    the final state [b,h,p,n].
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk:
        # pad to a chunk multiple: x=0, a=0 (decay 1) steps are identities
        pad = chunk - s % chunk
        y, hlast = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(a, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(B, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(C, ((0, 0), (0, pad), (0, 0))), chunk)
        return y[:, :s], hlast
    c = s // chunk
    xc = x.reshape(b, c, chunk, h, p)
    ac = a.reshape(b, c, chunk, h).transpose(0, 1, 3, 2)        # [b,c,h,q]
    Bc = B.reshape(b, c, chunk, n)
    Cc = C.reshape(b, c, chunk, n)

    # 1. intra-chunk (quadratic, causal-decay-masked "attention")
    Lmat = jnp.exp(segsum(ac))                                   # [b,c,h,q,q]
    scores = jnp.einsum("bcin,bcjn,bchij->bchij", Cc, Bc, Lmat)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores, xc)

    # 2. chunk states: decay-weighted sum of B x^T within each chunk
    # (state recurrence runs in f32 regardless of activation dtype)
    a_cum = jnp.cumsum(ac, axis=-1)                              # [b,c,h,q]
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)             # [b,c,h,q]
    states = jnp.einsum("bchq,bcqn,bcqhp->bchpn", decay_to_end, Bc,
                        xc).astype(jnp.float32)

    # 3. inter-chunk recurrence over c (scan)
    chunk_decay = jnp.exp(a_cum[..., -1]).astype(jnp.float32)    # [b,c,h]

    def step(hprev, inp):
        dec, st = inp
        hnew = dec[..., None, None] * hprev + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hlast, hprevs = jax.lax.scan(
        step, h0, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)                     # [b,c,h,p,n]

    # 4. inter-chunk output: C_t · (decay from chunk start) · h_prev
    decay_from_start = jnp.exp(a_cum)                            # [b,c,h,q]
    y_inter = jnp.einsum("bcqn,bchq,bchpn->bcqhp", Cc, decay_from_start,
                         hprevs.astype(x.dtype))

    # both terms accumulate in f32 (Lmat/decay are f32); emit in input dtype
    y = (y_intra + y_inter).astype(x.dtype).reshape(b, s, h, p)
    return y, hlast


def causal_conv(x, w, b):
    """Depthwise causal conv, width W: x [B,S,C], w [W,C], b [C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out + b


class Mamba2LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.d_inner = cfg.ssm_expand * cfg.d_model
        self.headdim = 64
        self.nheads = cfg.ssm_heads or self.d_inner // self.headdim
        self.headdim = self.d_inner // self.nheads
        self.conv_dim = self.d_inner + 2 * cfg.ssm_state

    # -- params ------------------------------------------------------------
    def init_layer(self, key):
        cfg = self.cfg
        d, di, n, h = cfg.d_model, self.d_inner, cfg.ssm_state, self.nheads
        k1, k2, k3 = jax.random.split(key, 3)
        d_in_proj = 2 * di + 2 * n + h
        return {
            "ln": L.init_norm(d, cfg.pdt),
            "in_proj": L.init_linear(k1, d, d_in_proj, cfg.pdt),
            "conv_w": L._normal(k2, (CONV_WIDTH, self.conv_dim), cfg.pdt,
                                1.0 / math.sqrt(CONV_WIDTH)),
            "conv_b": jnp.zeros((self.conv_dim,), cfg.pdt),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
            "D": jnp.ones((h,), jnp.float32),
            "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01))).astype(jnp.float32),
            "norm": L.init_norm(di, cfg.pdt),
            "out_proj": L.init_linear(
                k3, di, d, cfg.pdt, scale=1.0 / math.sqrt(di * 2 * cfg.num_layers)),
        }

    def init(self, key):
        cfg = self.cfg
        from .transformer import stack_layer_params
        ke, kh, *kl = jax.random.split(key, 2 + cfg.num_layers)
        p = {"embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, cfg.pdt),
             "ln_f": L.init_norm(cfg.d_model, cfg.pdt),
             "layers": stack_layer_params([self.init_layer(k) for k in kl])}
        if not cfg.tie_embeddings:
            p["head"] = L.init_linear(kh, cfg.d_model, cfg.vocab_size, cfg.pdt)
        return p

    # -- block --------------------------------------------------------------
    def _mix_in(self, lp, x):
        """in_proj + split + conv; returns z, xs, B, C, dt."""
        cfg = self.cfg
        di, n, h = self.d_inner, cfg.ssm_state, self.nheads
        zxbcdt = L.linear(lp["in_proj"], x)
        z, xBC, dt = jnp.split(zxbcdt, [di, di + self.conv_dim], axis=-1)
        return z, xBC, dt

    def _block_seq(self, lp, x):
        cfg = self.cfg
        Bsz, S, _ = x.shape
        di, n, h = self.d_inner, cfg.ssm_state, self.nheads
        hin = L.rms_norm(lp["ln"], x, cfg.norm_eps)
        z, xBC, dt = self._mix_in(lp, hin)
        xBC = jax.nn.silu(causal_conv(xBC, lp["conv_w"].astype(x.dtype),
                                      lp["conv_b"].astype(x.dtype)))
        xs, Bm, Cm = jnp.split(xBC, [di, di + n], axis=-1)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])  # [B,S,h]
        A = -jnp.exp(lp["A_log"])                                     # [h]
        a = (dt * A).astype(jnp.float32)                              # log-decay
        xh = xs.reshape(Bsz, S, h, self.headdim)
        xin = xh * dt.astype(x.dtype)[..., None]
        y, _ = ssd_chunked(xin, a, Bm.astype(x.dtype), Cm.astype(x.dtype),
                           cfg.ssm_chunk)
        y = y + xh * lp["D"].astype(x.dtype)[:, None]
        y = y.reshape(Bsz, S, di)
        y = L.rms_norm(lp["norm"], y * jax.nn.silu(z), cfg.norm_eps)
        return x + L.linear(lp["out_proj"], y)

    # -- forward / loss --------------------------------------------------------
    def forward(self, params, ids):
        cfg = self.cfg
        x = L.embed(params["embed"], ids).astype(cfg.adt)

        def body(x, lp):
            return self._block_seq(lp, x), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["layers"])
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            return L.unembed(params["embed"], x), 0.0
        return L.linear(params["head"], x).astype(jnp.float32), 0.0

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"])
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                               batch.get("mask", None))

    # -- decode (recurrent; O(1) in sequence length) ------------------------------
    def init_cache(self, B: int, max_len: int) -> dict:
        cfg = self.cfg
        Lr, h, p, n = cfg.num_layers, self.nheads, self.headdim, cfg.ssm_state
        return {
            "conv": jnp.zeros((Lr, B, CONV_WIDTH - 1, self.conv_dim), cfg.adt),
            "ssm": jnp.zeros((Lr, B, h, p, n), cfg.adt),
            "pos": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, ids, max_len: int):
        """Simple prefill: full forward for logits + recurrent state replay
        is avoided by running the chunked scan and capturing final states."""
        cfg = self.cfg
        x = L.embed(params["embed"], ids).astype(cfg.adt)
        B, S = ids.shape
        convs, ssms = [], []

        def run_layer(lp, x):
            Bsz, S, _ = x.shape
            di, n, h = self.d_inner, cfg.ssm_state, self.nheads
            hin = L.rms_norm(lp["ln"], x, cfg.norm_eps)
            z, xBC, dt = self._mix_in(lp, hin)
            conv_tail = xBC[:, -(CONV_WIDTH - 1):, :]
            xBC = jax.nn.silu(causal_conv(xBC, lp["conv_w"].astype(x.dtype),
                                          lp["conv_b"].astype(x.dtype)))
            xs, Bm, Cm = jnp.split(xBC, [di, di + n], axis=-1)
            dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
            A = -jnp.exp(lp["A_log"])
            a = (dt * A).astype(jnp.float32)
            xh = xs.reshape(Bsz, S, h, self.headdim)
            y, hlast = ssd_chunked(xh * dt.astype(x.dtype)[..., None], a,
                                   Bm.astype(x.dtype), Cm.astype(x.dtype),
                                   cfg.ssm_chunk)
            y = y + xh * lp["D"].astype(x.dtype)[:, None]
            y = y.reshape(Bsz, S, di)
            y = L.rms_norm(lp["norm"], y * jax.nn.silu(z), cfg.norm_eps)
            return x + L.linear(lp["out_proj"], y), conv_tail, hlast

        def body(x, lp):
            xo, conv_tail, hlast = run_layer(lp, x)
            return xo, (conv_tail, hlast)

        x, (convs, ssms) = jax.lax.scan(body, x, params["layers"])
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        logits = (L.unembed(params["embed"], x) if cfg.tie_embeddings
                  else L.linear(params["head"], x).astype(jnp.float32))
        cache = {"conv": convs.astype(cfg.adt), "ssm": ssms.astype(cfg.adt),
                 "pos": jnp.array(S, jnp.int32)}
        return logits[:, -1], cache

    def decode_step(self, params, cache, ids):
        cfg = self.cfg
        B = ids.shape[0]
        di, n, h = self.d_inner, cfg.ssm_state, self.nheads
        x = L.embed(params["embed"], ids).astype(cfg.adt)   # [B,1,D]

        def body(x, lp_cache):
            lp, conv_st, ssm_st = lp_cache
            hin = L.rms_norm(lp["ln"], x, cfg.norm_eps)
            z, xBC, dt = self._mix_in(lp, hin)              # [B,1,*]
            hist = jnp.concatenate([conv_st, xBC], axis=1)  # [B,W,convdim]
            w = lp["conv_w"].astype(x.dtype)
            conv_out = jnp.einsum("bwc,wc->bc", hist, w) + lp["conv_b"].astype(x.dtype)
            xBC1 = jax.nn.silu(conv_out)[:, None]
            xs, Bm, Cm = jnp.split(xBC1, [di, di + n], axis=-1)
            dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + lp["dt_bias"])  # [B,h]
            A = -jnp.exp(lp["A_log"])
            a = jnp.exp(dtv * A)                            # [B,h]
            xh = xs[:, 0].reshape(B, h, self.headdim)
            dx = xh * dtv.astype(x.dtype)[..., None]        # [B,h,p]
            ssm_new = (a.astype(x.dtype)[..., None, None] * ssm_st
                       + jnp.einsum("bhp,bn->bhpn", dx, Bm[:, 0]))
            y = jnp.einsum("bhpn,bn->bhp", ssm_new, Cm[:, 0])
            y = y + xh * lp["D"].astype(x.dtype)[:, None]
            y = y.reshape(B, 1, di)
            y = L.rms_norm(lp["norm"], y * jax.nn.silu(z), cfg.norm_eps)
            return x + L.linear(lp["out_proj"], y), (hist[:, 1:], ssm_new)

        x, (conv_new, ssm_new) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssm"]))
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        logits = (L.unembed(params["embed"], x) if cfg.tie_embeddings
                  else L.linear(params["head"], x).astype(jnp.float32))
        return logits[:, 0], {"conv": conv_new, "ssm": ssm_new,
                              "pos": cache["pos"] + 1}
