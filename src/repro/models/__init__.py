"""Model zoo: dense/MoE/SSM/hybrid/enc-dec/VLM transformer backbones in pure
JAX (pytree params + functional apply), built for pjit/shard_map distribution
and scan-over-layers compilation efficiency.
"""

from .config import ArchConfig
from .transformer import DecoderLM
from .mamba2 import Mamba2LM
from .zamba2 import Zamba2LM
from .whisper import WhisperModel
from .internvl import InternVLModel


def build_model(cfg: ArchConfig):
    return {
        "dense": DecoderLM,
        "moe": DecoderLM,
        "ssm": Mamba2LM,
        "hybrid": Zamba2LM,
        "audio": WhisperModel,
        "vlm": InternVLModel,
    }[cfg.family](cfg)


__all__ = ["ArchConfig", "DecoderLM", "Mamba2LM", "Zamba2LM", "WhisperModel",
           "InternVLModel", "build_model"]
