"""Decoder-only LM (dense and MoE) with scan-over-layers, GQA/RoPE/SWA,
KV-cached decode (ring buffer for sliding-window), and remat policies.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig


def stack_layer_params(per_layer: list) -> dict:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


class DecoderLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- params ---------------------------------------------------------------
    def init_layer(self, key) -> dict:
        cfg = self.cfg
        ka, km, k1, k2 = jax.random.split(key, 4)
        p = {"ln1": L.init_norm(cfg.d_model, cfg.pdt),
             "ln2": L.init_norm(cfg.d_model, cfg.pdt),
             "attn": L.init_attention(ka, cfg)}
        if cfg.family == "moe":
            p["moe"] = L.init_moe(km, cfg)
        else:
            p["mlp"] = L.init_mlp(km, cfg)
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        ke, kh, kf, *kl = jax.random.split(key, 3 + cfg.num_layers)
        params = {
            "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, cfg.pdt),
            "ln_f": L.init_norm(cfg.d_model, cfg.pdt),
            "layers": stack_layer_params([self.init_layer(k) for k in kl]),
        }
        if not cfg.tie_embeddings:
            params["head"] = L.init_linear(kh, cfg.d_model, cfg.vocab_size,
                                           cfg.pdt)
        return params

    # -- blocks -----------------------------------------------------------------
    def _block(self, p, x, positions, mask, kv=None, *, use_kernel=None,
               causal=False):
        cfg = self.cfg
        if use_kernel is None:
            use_kernel = cfg.flash_attention
        a, new_kv = L.attention(p["attn"], cfg, L.rms_norm(p["ln1"], x,
                                                           cfg.norm_eps),
                                positions, mask, kv=kv, use_kernel=use_kernel,
                                causal=causal)
        x = x + a
        h = L.rms_norm(p["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            y, aux = L.moe(p["moe"], cfg, h, group_size=cfg.moe_group)
        else:
            y, aux = L.mlp(p["mlp"], cfg, h), 0.0
        return x + y, aux, new_kv

    def _logits(self, params, x):
        cfg = self.cfg
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            return L.unembed(params["embed"], x)
        return L.linear(params["head"], x).astype(jnp.float32)

    # -- full forward (train / prefill) -------------------------------------------
    def forward(self, params, ids, *, return_cache: bool = False,
                last_only: bool = False):
        cfg = self.cfg
        B, S = ids.shape
        x = L.embed(params["embed"], ids).astype(cfg.adt)
        positions = jnp.arange(S)
        mask = L.causal_mask(S, S, window=cfg.sliding_window)
        return self.forward_embedded(params, x, positions, mask,
                                     return_cache=return_cache,
                                     last_only=last_only)

    def forward_embedded(self, params, x, positions, mask, *,
                         return_cache: bool = False, last_only: bool = False):
        """``last_only`` computes logits for the final position only —
        prefill never needs the full [B,S,V] logits tensor (or the head
        matmul + vocab-axis collective behind it)."""
        cfg = self.cfg

        def body(carry, lp):
            x, aux = carry
            x, a, kv = self._block(lp, x, positions, mask, causal=True)
            out = kv if return_cache else 0
            return (x, aux + a), out

        body_fn = jax.checkpoint(body) if cfg.remat else body
        if cfg.scan_layers:
            (x, aux), kvs = jax.lax.scan(body_fn, (x, 0.0), params["layers"])
        else:
            kvs_list = []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda v: v[i], params["layers"])
                (x, aux), kv = body_fn((x, 0.0 if i == 0 else aux), lp)
                kvs_list.append(kv)
            kvs = kvs_list if return_cache else None
        logits = self._logits(params, x[:, -1:] if last_only else x)
        if return_cache:
            return logits, aux, kvs
        return logits, aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"])
        ce = L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                             batch.get("mask", None))
        return ce + 0.01 * aux

    # -- cached decode --------------------------------------------------------------
    def cache_len(self, max_len: int) -> int:
        w = self.cfg.sliding_window
        return min(w, max_len) if w else max_len

    def init_cache(self, B: int, max_len: int) -> dict:
        cfg = self.cfg
        W = self.cache_len(max_len)
        K, hd, Lr = cfg.num_kv_heads, cfg.hd, cfg.num_layers
        return {
            "k": jnp.zeros((Lr, B, W, K, hd), cfg.adt),
            "v": jnp.zeros((Lr, B, W, K, hd), cfg.adt),
            "kpos": jnp.full((W,), -1, jnp.int32),     # global pos per slot
            "pos": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, ids, max_len: int):
        """Run the full prompt, return (last-token logits, primed cache)."""
        cfg = self.cfg
        B, S = ids.shape
        logits, _, kvs = self.forward(params, ids, return_cache=True,
                                      last_only=True)
        cache = self.init_cache(B, max_len)
        W = cache["k"].shape[2]
        take = min(S, W)
        # kvs: (k, v) stacked over layers: [L,B,S,K,hd].  Position p lives in
        # ring slot p % W — the same invariant decode_step maintains.
        k_all, v_all = kvs
        keep_pos = jnp.arange(S - take, S)
        slots = keep_pos % W
        cache["k"] = cache["k"].at[:, :, slots].set(k_all[:, :, S - take:])
        cache["v"] = cache["v"].at[:, :, slots].set(v_all[:, :, S - take:])
        cache["kpos"] = cache["kpos"].at[slots].set(keep_pos)
        cache["pos"] = jnp.array(S, jnp.int32)
        return logits[:, -1], cache

    def decode_step(self, params, cache, ids):
        """ids: [B,1] next token; returns (logits [B,V], new cache)."""
        cfg = self.cfg
        B = ids.shape[0]
        pos = cache["pos"]
        W = cache["k"].shape[2]
        slot = pos % W
        x = L.embed(params["embed"], ids).astype(cfg.adt)
        positions = pos[None].astype(jnp.int32)

        kpos = cache["kpos"].at[slot].set(pos)
        # mask: valid slots, causal, within window
        valid = kpos >= 0
        if cfg.sliding_window:
            valid &= kpos > pos - cfg.sliding_window
        mask = valid[None, :]                          # [S=1, T=W]

        def body(carry, lp_kc):
            x, _ = carry
            lp, k_l, v_l = lp_kc
            h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
            K, hd = cfg.num_kv_heads, cfg.hd
            q = L.linear(lp["attn"]["wq"], h).reshape(B, 1, cfg.num_heads, hd)
            q = L.apply_rope(q, positions, cfg.rope_theta) if cfg.rope_theta else q
            kn = L.linear(lp["attn"]["wk"], h).reshape(B, 1, K, hd)
            vn = L.linear(lp["attn"]["wv"], h).reshape(B, 1, K, hd)
            kn = L.apply_rope(kn, positions, cfg.rope_theta) if cfg.rope_theta else kn
            k_l = jax.lax.dynamic_update_slice_in_dim(k_l, kn, slot, axis=1)
            v_l = jax.lax.dynamic_update_slice_in_dim(v_l, vn, slot, axis=1)
            G = cfg.num_heads // K
            qg = q.reshape(B, 1, K, G, hd)
            o = L._sdpa(qg, k_l, v_l, mask)
            x = x + L.linear(lp["attn"]["wo"], o.reshape(B, 1, cfg.num_heads * hd))
            h2 = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
            if cfg.family == "moe":
                y, _ = L.moe(lp["moe"], cfg, h2, group_size=B)
            else:
                y = L.mlp(lp["mlp"], cfg, h2)
            return (x + y, 0.0), (k_l, v_l)

        (x, _), (k_new, v_new) = jax.lax.scan(
            body, (x, 0.0), (params["layers"], cache["k"], cache["v"]))
        logits = self._logits(params, x)[:, 0]
        new_cache = {"k": k_new, "v": v_new, "kpos": kpos, "pos": pos + 1}
        return logits, new_cache
