"""Pallas TPU SSD (state-space duality) chunk kernel — Mamba2's compute core.

Grid: (batch*heads, chunks) with the chunk dimension sequential
("arbitrary"): each step computes the intra-chunk quadratic term plus the
contribution of the carried state, and updates the running [p, n] state in
f32 VMEM scratch — the cross-chunk recurrence lives entirely in scratch, so
the kernel is one pass over the sequence.

Per grid step (one head, one chunk of q timesteps):
    L[i,j]   = exp(cumsum(a)[i] - cumsum(a)[j]) for j<=i      (decay matrix)
    y_intra  = ((C B^T) * L) x
    y_inter  = diag(exp(cumsum(a))) C h_prev
    h_new    = exp(total) h_prev + sum_j decay_to_end[j] B_j x_j^T
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .flash_attention import _vmem


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_ref, h_ref, *,
            q: int, p: int, n: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # [q, p]
    a = a_ref[0].astype(jnp.float32)          # [q]
    B = b_ref[0].astype(jnp.float32)          # [q, n]
    C = c_ref[0].astype(jnp.float32)          # [q, n]

    cs = jnp.cumsum(a)                        # [q]
    seg = cs[:, None] - cs[None, :]           # [q, q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    Lmat = jnp.where(jj <= ii, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * Lmat
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    h_prev = h_ref[...]                       # [p, n]
    decay_from_start = jnp.exp(cs)            # [q]
    y += (decay_from_start[:, None]
          * jax.lax.dot_general(C, h_prev, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32))

    decay_to_end = jnp.exp(cs[-1] - cs)       # [q]
    state_upd = jax.lax.dot_general(x * decay_to_end[:, None], B,
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    h_ref[...] = jnp.exp(cs[-1]) * h_prev + state_upd

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _fin():
        state_ref[0] = h_ref[...].astype(state_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_tpu(x, a, B, C, *, chunk: int = 64, interpret: bool = False):
    """SSD over full sequences.

    x: [b,s,h,p], a: [b,s,h] (log-decay), B/C: [b,s,n].
    Returns (y [b,s,h,p], final state [b,h,p,n]).  s % chunk == 0 required
    (callers pad, same as models.mamba2.ssd_chunked).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    # fold (batch, head); broadcast B/C across heads
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    af = a.transpose(0, 2, 1).reshape(b * h, s)
    Bf = jnp.broadcast_to(B[:, None], (b, h, s, n)).reshape(b * h, s, n)
    Cf = jnp.broadcast_to(C[:, None], (b, h, s, n)).reshape(b * h, s, n)

    grid = (b * h, nc)
    y, state = pl.pallas_call(
        functools.partial(_kernel, q=chunk, p=p, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk), lambda g, c: (g, c)),
            pl.BlockSpec((1, chunk, n), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, c: (g, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, p, n), lambda g, c: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b * h, p, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((p, n), jnp.float32)],
        interpret=interpret,
    )(xf, af, Bf, Cf)
    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    state = state.reshape(b, h, p, n)
    return y, state
