"""Public jit'd kernel wrappers with automatic backend dispatch.

On TPU the Pallas kernels run natively; on CPU (this container) they execute
through ``interpret=True`` when explicitly requested, and the production
model code falls back to the pure-jnp refs (kernels/ref.py) otherwise.
"""

from __future__ import annotations

import jax

from . import ref
from .flash_attention import flash_attention_tpu
from .nbody import nbody_forces_tpu
from .ssd_scan import ssd_scan_tpu
from .stencil5 import wave_step_tpu


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, interpret=None):
    if on_tpu() or interpret:
        return flash_attention_tpu(q, k, v, causal=causal, window=window,
                                   interpret=bool(interpret) and not on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def nbody_forces(p_all, *, soft=1e-3, interpret=None):
    if on_tpu() or interpret:
        return nbody_forces_tpu(p_all, soft=soft,
                                interpret=bool(interpret) and not on_tpu())
    return ref.nbody_forces_ref(p_all, p_all, soft)


def wave_step(um, u, *, c=0.25, interpret=None):
    if on_tpu() or interpret:
        return wave_step_tpu(um, u, c=c,
                             interpret=bool(interpret) and not on_tpu())
    return ref.wave_step_ref(um, u, c)


def ssd_scan(x, a, B, C, *, chunk=64, interpret=None):
    if on_tpu() or interpret:
        return ssd_scan_tpu(x, a, B, C, chunk=chunk,
                            interpret=bool(interpret) and not on_tpu())
    from repro.models.mamba2 import ssd_chunked
    return ssd_chunked(x, a, B, C, chunk)
