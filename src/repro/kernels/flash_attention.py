"""Pallas TPU fused attention (flash) kernel.

TPU-native adaptation: the kernel tiles Q into ``q_block`` rows held in VMEM,
streams K/V blocks through VMEM, and keeps the running-softmax state
(m, l, acc) in f32 VMEM scratch so nothing of size O(S*T) ever exists.  The
MXU sees [q_block, hd] x [hd, kv_block] and [q_block, kv_block] x
[kv_block, hd] matmuls — both dims multiples of 128 for the standard configs.

Layout: q [BH, S, hd] (batch x query-head folded), k/v [BK, T, hd] with
``group`` query heads per kv head (GQA: kv index = head index // group).

Causal and sliding-window masking are applied from global block indices;
fully-masked blocks are skipped via pl.when.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window, group: int,
            q_block: int, kv_block: int, T: int, q_offset: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = qi * q_block + q_offset
    k0 = kj * kv_block

    # skip key blocks entirely above the causal diagonal / outside window
    live = jnp.array(True)
    if causal:
        live &= k0 <= q0 + q_block - 1
    if window is not None:
        live &= k0 + kv_block - 1 > q0 - window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [qb, hd]
        k = k_ref[0].astype(jnp.float32)          # [kb, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
        mask = kpos < T
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block", "interpret",
                                             "q_offset"))
def flash_attention_tpu(q, k, v, *, causal: bool = True, window=None,
                        q_block: int = 512, kv_block: int = 512,
                        interpret: bool = False, q_offset: int = 0):
    """q: [B,S,K,G,hd], k/v: [B,T,K,hd] -> [B,S,K,G,hd]."""
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    Sp = -(-S // q_block) * q_block
    Tp = -(-T // kv_block) * kv_block
    qf = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    # fold heads: q -> [B*K*G, Sp, hd]; kv -> [B*K, Tp, hd]
    qf = qf.transpose(0, 2, 3, 1, 4).reshape(B * K * G, Sp, hd)
    kf = kf.transpose(0, 2, 1, 3).reshape(B * K, Tp, hd)
    vf = vf.transpose(0, 2, 1, 3).reshape(B * K, Tp, hd)

    grid = (B * K * G, Sp // q_block, Tp // kv_block)
    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(hd), causal=causal, window=window,
        group=G, q_block=q_block, kv_block=kv_block, T=T, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K * G, Sp, hd), q.dtype),
        scratch_shapes=[
            _vmem((q_block,), jnp.float32),      # running max  m
            _vmem((q_block,), jnp.float32),      # running norm l
            _vmem((q_block, hd), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, K, G, Sp, hd).transpose(0, 3, 1, 2, 4)
    return out[:, :S]


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover — non-TPU builds
        return pl.MemorySpace.ANY  # type: ignore[attr-defined]
