"""Pallas TPU 5-point wave-propagation stencil (WaveSim).

Grid over row tiles.  Pallas block index maps are in whole-block units, so
overlapping halo windows are not directly expressible; instead the +-1-row
neighbours are provided as two pre-shifted, tile-aligned input arrays (XLA
fuses the shifts into cheap copies) and each grid step works entirely on
[tile, W] VMEM blocks.  Column neighbours are in-block rolls.

Boundary rows/columns are clamped to zero (Dirichlet), matching
``ref.wave_step_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(um_ref, u_ref, up_ref, dn_ref, o_ref, *, c: float, tile: int,
            H: int):
    i = pl.program_id(0)
    um = um_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    up = up_ref[...].astype(jnp.float32)    # u shifted: row r holds u[r-1]
    dn = dn_ref[...].astype(jnp.float32)    # u shifted: row r holds u[r+1]
    left = jnp.roll(u, 1, axis=1)
    right = jnp.roll(u, -1, axis=1)
    lap = up + dn + left + right - 4.0 * u
    un = 2.0 * u - um + c * lap
    row = i * tile + jax.lax.broadcasted_iota(jnp.int32, un.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.int32, un.shape, 1)
    interior = ((row > 0) & (row < H - 1)
                & (col > 0) & (col < un.shape[1] - 1))
    o_ref[...] = jnp.where(interior, un, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("c", "tile", "interpret"))
def wave_step_tpu(um, u, *, c: float = 0.25, tile: int = 128,
                  interpret: bool = False):
    """One wave step: um/u [H,W] -> next field [H,W]."""
    H, W = u.shape
    tile = min(tile, H)
    Hp = -(-H // tile) * tile
    pad = ((0, Hp - H), (0, 0))
    umpad = jnp.pad(um, pad)
    upad = jnp.pad(u, pad)
    up = jnp.pad(u, ((1, Hp - H), (0, 0)))[:Hp]        # row r -> u[r-1]
    dn = jnp.pad(u, ((0, Hp - H + 1), (0, 0)))[1:Hp + 1]  # row r -> u[r+1]
    grid = (Hp // tile,)
    spec = pl.BlockSpec((tile, W), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, c=c, tile=tile, H=H),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Hp, W), u.dtype),
        interpret=interpret,
    )(umpad, upad, up, dn)
    return out[:H]
