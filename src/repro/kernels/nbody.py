"""Pallas TPU tiled O(N^2) gravity kernel (the paper's N-body example app).

Grid: (i-tiles, j-tiles).  Each step loads a [bi, 3] block of target bodies
and a [bj, 3] block of sources into VMEM and accumulates forces in an f32
VMEM scratch tile; the all-pairs structure is the same "stream the second
operand" pattern as flash attention, so VMEM stays O(tile).

Positions are padded to tile multiples; padded sources get zero mass via an
index mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .flash_attention import _vmem


def _kernel(pi_ref, pj_ref, o_ref, acc_ref, *, soft: float, bj: int, N: int):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pi = pi_ref[...].astype(jnp.float32)            # [bi, 3]
    pj = pj_ref[...].astype(jnp.float32)            # [bj, 3]
    d = pj[None, :, :] - pi[:, None, :]             # [bi, bj, 3]
    r2 = jnp.sum(d * d, axis=-1) + soft
    inv = jax.lax.rsqrt(r2)
    w = inv * inv * inv                             # 1 / r^3
    jpos = j * bj + jax.lax.broadcasted_iota(jnp.int32, w.shape, 1)
    w = jnp.where(jpos < N, w, 0.0)                 # mask padded sources
    acc_ref[...] += jnp.einsum("ijc,ij->ic", d, w)

    @pl.when(j == nj - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_i", "tile_j", "soft",
                                             "interpret"))
def nbody_forces_tpu(p_all, *, tile_i: int = 256, tile_j: int = 256,
                     soft: float = 1e-3, interpret: bool = False):
    """p_all: [N,3] -> forces [N,3]."""
    N = p_all.shape[0]
    ti, tj = min(tile_i, N), min(tile_j, N)
    Np_i = -(-N // ti) * ti
    Np_j = -(-N // tj) * tj
    Np = max(Np_i, Np_j)
    pp = jnp.pad(p_all, ((0, Np - N), (0, 0)))
    grid = (Np // ti, Np // tj)
    out = pl.pallas_call(
        functools.partial(_kernel, soft=soft, bj=tj, N=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((tj, 3), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ti, 3), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, 3), p_all.dtype),
        scratch_shapes=[_vmem((ti, 3), jnp.float32)],
        interpret=interpret,
    )(pp, pp)
    return out[:N]
