"""Pure-jnp oracles for every Pallas kernel, also used as the production
fallback path on non-TPU backends and for long sequences where the naive
einsum attention would materialize O(S*T) logits.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# blockwise (flash) attention — the oracle for kernels/flash_attention.py


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_block: int = 512, kv_block: int = 1024,
                        q_offset: int = 0):
    """Memory-bounded attention with running softmax (flash algorithm).

    q: [B,S,K,G,hd] grouped queries; k/v: [B,T,K,hd].
    Returns [B,S,K,G,hd].  fp32 accumulation, output in q.dtype.

    Backed by a custom_vjp whose BACKWARD is also blockwise (recomputing the
    per-block probabilities from the saved logsumexp) — without it, the
    residuals autodiff saves through the forward scan re-materialize the
    O(S*T) attention matrix and training gains vanish (measured in §Perf).
    """
    return _flash_core(q, k, v, causal, window, q_block, kv_block, q_offset)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_core(q, k, v, causal, window, q_block, kv_block, q_offset):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block,
                             q_offset)
    return out


def _block_mask(q0, k0, q_block, kv_block, T, causal, window):
    qpos = q0 + jnp.arange(q_block)[:, None]
    kpos = k0 + jnp.arange(kv_block)[None, :]
    mask = kpos < T
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block, q_offset):
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    Sp = -(-S // q_block) * q_block
    Tp = -(-T // kv_block) * kv_block
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    nq, nk = Sp // q_block, Tp // kv_block

    qb = qp.reshape(B, nq, q_block, K, G, hd)
    kb = kp.reshape(B, nk, kv_block, K, hd)
    vb = vp.reshape(B, nk, kv_block, K, hd)

    def q_step(_, qi_q):
        qi, qblk = qi_q                                   # [B,q,K,G,hd]
        q0 = qi * q_block + q_offset

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_kv
            logits = jnp.einsum("bqkgh,btkh->bkgqt", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
            mask = _block_mask(q0, ki * kv_block, q_block, kv_block, T,
                               causal, window)
            logits = jnp.where(mask, logits, NEG_INF)
            m2 = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + p.sum(-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m2, l2, acc2), None

        m0 = jnp.full((B, K, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kb.transpose(1, 0, 2, 3, 4),
             vb.transpose(1, 0, 2, 3, 4)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))          # [B,K,G,q]
        return None, (out.transpose(0, 3, 1, 2, 4).astype(qblk.dtype), lse)

    _, (outs, lses) = jax.lax.scan(
        q_step, None, (jnp.arange(nq), qb.transpose(1, 0, 2, 3, 4, 5)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp, K, G, hd)[:, :S]
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, K, G, Sp)[..., :S]
    return out, lse


def _flash_fwd(q, k, v, causal, window, q_block, kv_block, q_offset):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block,
                               q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_block, kv_block, q_offset, res, dout):
    """Blockwise FA2 backward: probabilities are recomputed per block from
    the saved logsumexp — O(block) memory, no O(S*T) residuals."""
    q, k, v, out, lse = res
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    Sp = -(-S // q_block) * q_block
    Tp = -(-T // kv_block) * kv_block
    nq, nk = Sp // q_block, Tp // kv_block
    padq = ((0, 0), (0, Sp - S), (0, 0), (0, 0), (0, 0))
    padk = ((0, 0), (0, Tp - T), (0, 0), (0, 0))
    qb = jnp.pad(q, padq).reshape(B, nq, q_block, K, G, hd)
    dob = jnp.pad(dout, padq).reshape(B, nq, q_block, K, G, hd)
    kb = jnp.pad(k, padk).reshape(B, nk, kv_block, K, hd)
    vb = jnp.pad(v, padk).reshape(B, nk, kv_block, K, hd)
    # D_i = rowsum(dout * out)  [B,K,G,S]
    Dfull = jnp.einsum("bskgh,bskgh->bkgs", jnp.pad(out, padq),
                       jnp.pad(dout, padq)).astype(jnp.float32)
    Db = Dfull.reshape(B, K, G, nq, q_block)
    lseb = jnp.pad(lse, ((0, 0), (0, 0), (0, 0), (0, Sp - S)),
                   constant_values=0.0).reshape(B, K, G, nq, q_block)

    def kv_step(dq_acc, kj):
        kblk = jax.lax.dynamic_index_in_dim(kb, kj, 1, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, kj, 1, keepdims=False)
        k0 = kj * kv_block

        def q_step(carry, qi):
            dk, dv, dq_acc = carry
            qblk = jax.lax.dynamic_index_in_dim(qb, qi, 1, keepdims=False)
            doblk = jax.lax.dynamic_index_in_dim(dob, qi, 1, keepdims=False)
            lse_i = jax.lax.dynamic_index_in_dim(lseb, qi, 3, keepdims=False)
            D_i = jax.lax.dynamic_index_in_dim(Db, qi, 3, keepdims=False)
            q0 = qi * q_block + q_offset
            logits = jnp.einsum("bqkgh,btkh->bkgqt", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
            mask = _block_mask(q0, k0, q_block, kv_block, T, causal, window)
            p = jnp.where(mask, jnp.exp(logits - lse_i[..., None]), 0.0)
            dp = jnp.einsum("bqkgh,btkh->bkgqt", doblk, vblk,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - D_i[..., None]) * scale        # [B,K,G,q,t]
            dq_blk = jnp.einsum("bkgqt,btkh->bqkgh", ds.astype(kblk.dtype),
                                kblk)
            dk += jnp.einsum("bkgqt,bqkgh->btkh", ds.astype(qblk.dtype), qblk)
            dv += jnp.einsum("bkgqt,bqkgh->btkh", p.astype(doblk.dtype), doblk)
            dq_acc = jax.lax.dynamic_update_index_in_dim(
                dq_acc, jax.lax.dynamic_index_in_dim(dq_acc, qi, 1,
                                                     keepdims=False) + dq_blk,
                qi, 1)
            return (dk, dv, dq_acc), None

        dk0 = jnp.zeros((B, kv_block, K, hd), jnp.float32)
        dv0 = jnp.zeros((B, kv_block, K, hd), jnp.float32)
        (dk, dv, dq_acc), _ = jax.lax.scan(q_step, (dk0, dv0, dq_acc),
                                           jnp.arange(nq))
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((B, nq, q_block, K, G, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
    dq = dq.reshape(B, Sp, K, G, hd)[:, :S].astype(q.dtype)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Tp, K, hd)[:, :T].astype(k.dtype)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Tp, K, hd)[:, :T].astype(v.dtype)
    return dq, dk, dv


_flash_core.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# N-body oracle (paper example app)


def nbody_forces_ref(p_all: jnp.ndarray, p_chunk: jnp.ndarray,
                     softening: float = 1e-3) -> jnp.ndarray:
    """Direct O(N^2) gravity: force on each body in p_chunk from p_all."""
    d = p_all[None, :, :] - p_chunk[:, None, :]
    r2 = jnp.sum(d * d, axis=-1) + softening
    return jnp.sum(d / (r2[..., None] ** 1.5), axis=1)


# ---------------------------------------------------------------------------
# 5-point wave stencil oracle (WaveSim)


def wave_step_ref(um: jnp.ndarray, u: jnp.ndarray, c: float = 0.25) -> jnp.ndarray:
    lap = (jnp.roll(u, 1, 0) + jnp.roll(u, -1, 0)
           + jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1) - 4 * u)
    un = 2 * u - um + c * lap
    un = un.at[0, :].set(0.0).at[-1, :].set(0.0)
    un = un.at[:, 0].set(0.0).at[:, -1].set(0.0)
    return un


# ---------------------------------------------------------------------------
# SSD chunk-state kernel oracle (the matmul core of mamba2)


def ssd_chunk_ref(x, a, B, C):
    """Single-chunk SSD: intra-chunk output + end-of-chunk state.

    x: [q,h,p], a: [q,h] log-decay, B/C: [q,n].  (No batch dim — the kernel
    grid supplies it.)  Returns (y [q,h,p], state [h,p,n]).
    """
    q = x.shape[0]
    cs = jnp.cumsum(a, axis=0)                              # [q,h]
    seg = cs[:, None, :] - cs[None, :, :]                   # [i,j,h]
    mask = jnp.tril(jnp.ones((q, q), bool))
    Lmat = jnp.where(mask[..., None], jnp.exp(seg), 0.0)    # [i,j,h]
    scores = jnp.einsum("in,jn,ijh->hij", C, B, Lmat)
    y = jnp.einsum("hij,jhp->ihp", scores, x)
    decay_end = jnp.exp(cs[-1][None, :] - cs)               # [q,h]
    state = jnp.einsum("qh,qn,qhp->hpn", decay_end, B, x)
    return y, state
