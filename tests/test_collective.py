"""Collective exchange layer tests (DESIGN.md §9).

Covers the topology schedules (coverage + O(N log N) message counts at
power-of-two AND non-power-of-two group sizes), the CDAG collective
detection (allgather / broadcast / scatter vs the point-to-point fallback),
the structural message-count win over the all-pairs exchange, value
bitexactness against the point-to-point oracle on 1/2/3/4/6/8 nodes, and
packed reduction fusion (the nbody E+Mx pattern: one exchange per step,
bit-identical per fused component).
"""

import math

import numpy as np
import pytest

from repro.core import (IdagGenerator, InstructionType, Runtime, TaskGraph,
                        all_range, fixed, generate_cdag, one_to_one, read,
                        read_write, reduction, write)
from repro.core.buffer import VirtualBuffer
from repro.core.collective import (allgather_schedule,
                                   allreduce_message_count, message_count,
                                   num_rounds, tree_schedule)
from repro.core.command_graph import CommandType
from repro.core.region import Box

NODE_COUNTS = [1, 2, 3, 4, 6, 8]


# -- topology schedules ------------------------------------------------------
@pytest.mark.parametrize("p", NODE_COUNTS)
def test_allgather_schedule_coverage_and_counts(p):
    group = tuple(range(p))
    rounds = allgather_schedule(group, group)
    assert len(rounds) == num_rounds(p)
    held = {r: {r} for r in group}
    for msgs in rounds:
        sent_from = [m.src for m in msgs]
        assert len(set(sent_from)) == len(sent_from)  # <=1 send/rank/round
        for m in msgs:
            # a rank only forwards blocks it already holds
            assert set(m.blocks) <= held[m.src]
        for m in msgs:
            held[m.dst] |= set(m.blocks)
    for r in group:
        assert held[r] == set(group), f"rank {r} missing blocks"
    assert message_count(rounds) <= p * num_rounds(p)
    if p > 1:
        assert message_count(rounds) < p * (p - 1) or p <= 3


@pytest.mark.parametrize("p", NODE_COUNTS)
def test_allgather_schedule_partial_contributors(p):
    """Non-contributing ranks (e.g. nodes without reduction chunks) still
    receive every block, purely by forwarding."""
    group = tuple(range(p))
    contributors = tuple(r for r in group if r % 2 == 0)
    rounds = allgather_schedule(group, contributors)
    held = {r: ({r} if r in contributors else set()) for r in group}
    for msgs in rounds:
        for m in msgs:
            assert set(m.blocks) <= held[m.src]
        for m in msgs:
            held[m.dst] |= set(m.blocks)
    for r in group:
        assert held[r] == set(contributors)


@pytest.mark.parametrize("p", NODE_COUNTS)
def test_tree_schedules(p):
    group = tuple(range(p))
    bc = tree_schedule(group, 0)
    held = {0}
    for msgs in bc:
        for m in msgs:
            assert m.src in held          # only holders forward
        for m in msgs:
            held.add(m.dst)
    assert held == set(group)
    assert message_count(bc) == p - 1
    assert len(bc) == num_rounds(p)

    sc = tree_schedule(group, 0, scatter=True)
    have = {0: set(group)}               # root holds every block
    for msgs in sc:
        for m in msgs:
            assert set(m.blocks) <= have[m.src]
            have[m.src] -= set(m.blocks)
            have.setdefault(m.dst, set()).update(m.blocks)
    for r in group[1:]:
        assert r in have[r], f"rank {r} never received its block"
    assert message_count(sc) == p - 1


# -- CDAG detection + structural message counts ------------------------------
def _allgather_tdag(n, steps=2):
    """write one_to_one then read all_range: the replicated-exchange
    pattern whose all-pairs materialization is N*(N-1) pushes."""
    tdag = TaskGraph()
    P = VirtualBuffer((n,), name="P", initial_value=np.zeros(n))
    O = VirtualBuffer((n,), name="O", initial_value=np.zeros(n))
    for _ in range(steps):
        tdag.submit("w", (n,), [read_write(P, one_to_one())])
        tdag.submit("r", (n,), [read(P, all_range()),
                                read_write(O, one_to_one())])
    return tdag, P


def _compile_idags(cdag, num_nodes, num_devices=1):
    idags = []
    for n in range(num_nodes):
        g = IdagGenerator(n, num_devices)
        for cmd in cdag.commands[n]:
            if cmd.ctype == CommandType.EPOCH and cmd.task is None:
                continue
            g.compile(cmd)
        idags.append(g)
    return idags


@pytest.mark.parametrize("nodes", [n for n in NODE_COUNTS if n > 1])
def test_allgather_replaces_all_pairs_pushes(nodes):
    tdag, P = _allgather_tdag(64, steps=2)
    cdag = generate_cdag(tdag, nodes, collectives=True)
    cmds = [c for per_node in cdag.commands for c in per_node]
    ags = [c for c in cmds if c.ctype == CommandType.COLL_ALLGATHER]
    assert ags, "allgather pattern not detected"
    # the replicated exchange produced NO point-to-point pushes at all
    assert not any(c.ctype == CommandType.PUSH for c in cmds
                   if c.buffer is not None and c.buffer.bid == P.bid)

    # structural message count: per collective <= N * ceil(log2 N), versus
    # the point-to-point oracle's N * (N - 1)
    idags = _compile_idags(cdag, nodes)
    sends_per_coll: dict[tuple, int] = {}
    for g in idags:
        for i in g.instructions:
            if i.itype == InstructionType.COLL_SEND:
                base = i.transfer_id[:3]
                sends_per_coll[base] = sends_per_coll.get(base, 0) + 1
    assert sends_per_coll
    for base, count in sends_per_coll.items():
        assert count <= nodes * num_rounds(nodes), (base, count)

    # point-to-point oracle on the same TDAG shape
    tdag2, P2 = _allgather_tdag(64, steps=2)
    cdag2 = generate_cdag(tdag2, nodes, collectives=False)
    idags2 = _compile_idags(cdag2, nodes)
    p2p_sends = sum(1 for g in idags2 for i in g.instructions
                    if i.itype == InstructionType.SEND)
    n_exchanges = len(sends_per_coll)
    assert p2p_sends == n_exchanges * nodes * (nodes - 1)
    coll_sends = sum(sends_per_coll.values())
    if nodes > 3:
        assert coll_sends < p2p_sends


def test_broadcast_and_scatter_detection():
    nodes, n = 4, 32
    tdag = TaskGraph()
    B = VirtualBuffer((n,), name="B")
    # a single-chunk task: only node 0 gets work, writing the whole buffer
    tdag.submit("w0", Box((0,), (1,)), [write(B, fixed(Box((0,), (n,))))])
    # every node reads everything -> broadcast from the sole owner
    tdag.submit("rall", (n,), [read(B, all_range()),
                               write(VirtualBuffer((n,), name="O1"),
                                     one_to_one())])
    cdag = generate_cdag(tdag, nodes, collectives=True)
    cmds = [c for per_node in cdag.commands for c in per_node]
    assert any(c.ctype == CommandType.COLL_BROADCAST for c in cmds)

    tdag2 = TaskGraph()
    C = VirtualBuffer((n,), name="C")
    tdag2.submit("w0", Box((0,), (1,)), [write(C, fixed(Box((0,), (n,))))])
    # every node reads its own disjoint chunk -> scatter from the owner
    tdag2.submit("rown", (n,), [read_write(C, one_to_one())])
    cdag2 = generate_cdag(tdag2, nodes, collectives=True)
    cmds2 = [c for per_node in cdag2.commands for c in per_node]
    scatters = [c for c in cmds2 if c.ctype == CommandType.COLL_SCATTER]
    assert scatters
    # binomial tree: N-1 messages total, root sends only ceil(log2 N)
    idags = _compile_idags(cdag2, nodes)
    sends = [i for g in idags for i in g.instructions
             if i.itype == InstructionType.COLL_SEND]
    assert len(sends) == nodes - 1
    root_sends = [s for s in sends if s.node == 0]
    assert len(root_sends) == num_rounds(nodes)


def test_scatter_forwarder_ownership_elides_pushes():
    """A binomial-scatter forwarder transiently holds the blocks of its
    subtree; those replicas must be recorded in the replicated ownership
    map so later exchanges elide pushes of data the forwarder already
    holds (ROADMAP "scatter ownership")."""
    from repro.core.command_graph import CommandGraphGenerator
    from repro.core.region import Region
    nodes, n = 4, 32
    tdag = TaskGraph(horizon_step=100)
    C = VirtualBuffer((n,), name="C")
    O = VirtualBuffer((n,), name="O", initial_value=np.zeros(n))
    O2 = VirtualBuffer((n,), name="O2", initial_value=np.zeros(n))
    gen = CommandGraphGenerator(nodes, collectives=True)

    def feed():
        gen.process(tdag.tasks[-1])

    tdag.submit("w0", Box((0,), (1,)), [write(C, fixed(Box((0,), (n,))))])
    feed()
    # read-only scatter: node i consumes chunk i; the binomial tree routes
    # node 3's chunk [24,32) through forwarder node 2
    tdag.submit("rown", (n,), [read(C, one_to_one()),
                               read_write(O, one_to_one())])
    feed()
    cmds = [c for per in gen.commands for c in per]
    assert any(c.ctype == CommandType.COLL_SCATTER for c in cmds)
    own = gen._ownership[C.bid]
    owners_b3 = {o for _, o in own.query(Region.from_box(Box((24,), (32,))))}
    assert owners_b3 == {frozenset({0, 2, 3})}, owners_b3   # 2 = forwarder
    # a later read-all exchange: pushes to the forwarder exclude BOTH its
    # consumed chunk and the transiently forwarded block
    tdag.submit("rall", (n,), [read(C, all_range()),
                               read_write(O2, one_to_one())])
    feed()
    cmds = [c for per in gen.commands for c in per]
    pushes = [c for c in cmds if c.ctype == CommandType.PUSH
              and c.buffer is C]
    to_fwd = [c for c in pushes if c.target == 2]
    assert len(to_fwd) == 2, to_fwd                # blocks 0 and 1 only
    held = Region.from_box(Box((16,), (32,)))      # own chunk + forwarded
    assert all(not c.region.overlaps(held) for c in to_fwd)
    # the pure consumer at the same tree depth still needs 3 pushes
    assert len([c for c in pushes if c.target == 1]) == 3


def test_scatter_forwarder_serves_later_push():
    """End-to-end: with the scatter rooted at node 2 the binomial order is
    [2, 0, 1, 3], so forwarder node 1 transiently holds node 3's block and
    — as the minimum-rank owner — becomes the SOURCE of a later push of
    that block.  Values must survive the forwarder-served transfer."""
    from repro.core.region import Region
    nodes, n = 4, 32

    def only_node(k):
        def rm(chunk, buffer_shape):
            if chunk.min[0] <= k < chunk.max[0]:
                return Region.from_box(Box.full(buffer_shape))
            return Region.empty()
        rm.__name__ = f"only_node{k}"
        return rm

    def block3(chunk, buffer_shape):
        if chunk.max[0] <= 8:
            return Region.from_box(Box((24 + chunk.min[0],),
                                       (24 + chunk.max[0],)))
        return Region.empty()

    with Runtime(num_nodes=nodes, devices_per_node=1, host_threads=2) as rt:
        C = rt.buffer((n,), name="C")
        O = rt.buffer((n,), init=np.zeros(n), name="O")
        R = rt.buffer((8,), init=np.zeros(8), name="R")

        def w2(chunk, *views):
            if views:
                views[0].set(Box((0,), (n,)),
                             np.arange(n, dtype=float) * 3.0)

        def rd(chunk, cv, ov):
            ov.set(chunk, ov.get(chunk) + cv.get(chunk))

        def rd3(chunk, *views):
            if len(views) == 2:
                a, b = 24 + chunk.min[0], 24 + chunk.max[0]
                views[1].set(chunk, views[0].get(Box((a,), (b,))))

        rt.submit("w2", (nodes,), [write(C, only_node(2))], w2)
        rt.submit("rown", (n,), [read(C, one_to_one()),
                                 read_write(O, one_to_one())], rd)
        rt.submit("rd3", (8,), [read(C, block3),
                                read_write(R, one_to_one())], rd3)
        o = rt.gather(O)
        r = rt.gather(R)
        assert rt.warnings == [], rt.warnings
    ref = np.arange(n, dtype=float) * 3.0
    np.testing.assert_array_equal(o, ref)
    np.testing.assert_array_equal(r, ref[24:])


def test_include_current_prefetch_collectivized():
    """The ``include_current_value`` pre-fetch from a single owner becomes
    ONE broadcast instead of N-1 point-to-point pushes (ROADMAP
    "collectivize include_current")."""
    nodes, n = 4, 32
    tdag = TaskGraph(horizon_step=100)
    X = VirtualBuffer((n,), name="X", initial_value=np.zeros(n))
    E = VirtualBuffer((1,), name="E")
    # a single-chunk task seeds E on node 0 only -> one owner
    tdag.submit("seed", Box((0,), (1,)), [write(E, fixed(Box((0,), (1,))))])
    tdag.submit("red", (n,), [read(X, one_to_one()),
                              reduction(E, "sum",
                                        include_current_value=True)])
    cdag = generate_cdag(tdag, nodes, collectives=True)
    cmds = [c for per in cdag.commands for c in per]
    bcasts = [c for c in cmds if c.ctype == CommandType.COLL_BROADCAST
              and c.buffer is E]
    assert bcasts, "include_current pre-fetch was not collectivized"
    assert not any(c.ctype == CommandType.PUSH and c.buffer is E
                   for c in cmds)


def test_include_current_collectivized_value():
    """Value semantics of the broadcast pre-fetch: the single-owner seed
    enters the fold exactly once, bit-identical to the fsum oracle."""
    nodes, n = 3, 24
    data = np.arange(float(n))
    with Runtime(num_nodes=nodes, devices_per_node=1, host_threads=2) as rt:
        X = rt.buffer((n,), init=data, name="X")
        E = rt.buffer((1,), name="E")

        def seed(chunk, ev):
            ev.set(Box((0,), (1,)), np.full(1, 2.25))

        def k(chunk, xv, red):
            red.contribute(xv.get(chunk))

        rt.submit("seed", Box((0,), (1,)),
                  [write(E, fixed(Box((0,), (1,))))], seed)
        rt.submit("red", (n,),
                  [read(X, one_to_one()),
                   reduction(E, "sum", include_current_value=True)], k)
        out = float(rt.gather(E)[0])
        assert rt.warnings == [], rt.warnings
    assert out == math.fsum(list(data) + [2.25])


def test_irregular_exchange_keeps_point_to_point():
    """Neighborhood reads (partial-overlap pattern) must NOT be collectivized."""
    from repro.core import neighborhood
    nodes, n = 4, 64
    tdag = TaskGraph()
    U = VirtualBuffer((n,), name="U", initial_value=np.zeros(n))
    V = VirtualBuffer((n,), name="V")
    tdag.submit("w", (n,), [read_write(U, one_to_one())])
    tdag.submit("st", (n,), [read(U, neighborhood((1,))),
                             write(V, one_to_one())])
    cdag = generate_cdag(tdag, nodes, collectives=True)
    cmds = [c for per_node in cdag.commands for c in per_node]
    assert any(c.ctype == CommandType.PUSH for c in cmds)
    assert not any(c.ctype in (CommandType.COLL_ALLGATHER,
                               CommandType.COLL_BROADCAST,
                               CommandType.COLL_SCATTER) for c in cmds)


# -- value bitexactness vs the point-to-point oracle -------------------------
def _exchange_program(rt, n=48, steps=3):
    P = rt.buffer((n,), init=np.arange(n, dtype=float), name="P")
    O = rt.buffer((n,), init=np.zeros(n), name="O")

    def step(chunk, p):
        p.set(chunk, p.get(chunk) * 1.5 + 1.0)

    def fold(chunk, pall, out):
        a = pall.get(Box((0,), (n,)))
        out.set(chunk, out.get(chunk) + a.sum() + a[:: 7].sum())

    for _ in range(steps):
        rt.submit("step", (n,), [read_write(P, one_to_one())], step)
        rt.submit("fold", (n,), [read(P, all_range()),
                                 read_write(O, one_to_one())], fold)
    return rt.gather(P), rt.gather(O)


@pytest.mark.parametrize("nodes", NODE_COUNTS)
def test_allgather_bitexact_vs_p2p_oracle(nodes):
    with Runtime(num_nodes=nodes, devices_per_node=1, collectives=False,
                 host_threads=2) as rt:
        p_ref, o_ref = _exchange_program(rt)
        assert rt.warnings == []
        assert rt.comm.coll_messages == 0
    with Runtime(num_nodes=nodes, devices_per_node=1, collectives=True,
                 host_threads=2) as rt:
        p_c, o_c = _exchange_program(rt)
        assert rt.warnings == []
        stats = rt.comm_stats()
    np.testing.assert_array_equal(p_ref, p_c)
    np.testing.assert_array_equal(o_ref, o_c)
    if nodes > 1:
        assert stats["coll_messages"] > 0


@pytest.mark.parametrize("nodes", [3, 4, 6])
def test_scatter_bitexact_vs_p2p_oracle(nodes):
    n = 48

    def program(rt):
        B = rt.buffer((n,), name="B")

        def w0(chunk, bv):
            bv.set(Box((0,), (n,)), np.arange(n, dtype=float) * 2.0)

        def own(chunk, bv):
            bv.set(chunk, bv.get(chunk) + 1.0)

        rt.submit("w0", Box((0,), (1,)), [write(B, fixed(Box((0,), (n,))))],
                  w0)
        rt.submit("own", (n,), [read_write(B, one_to_one())], own)
        return rt.gather(B)

    with Runtime(num_nodes=nodes, devices_per_node=1, collectives=False,
                 host_threads=2) as rt:
        ref = program(rt)
    with Runtime(num_nodes=nodes, devices_per_node=1, collectives=True,
                 host_threads=2) as rt:
        out = program(rt)
    np.testing.assert_array_equal(ref, out)


# -- reduction exchange as an allgather participant --------------------------
@pytest.mark.parametrize("nodes", [2, 3, 4, 6])
def test_reduction_exchange_message_count(nodes):
    tdag = TaskGraph(horizon_step=100)
    X = VirtualBuffer((32,), name="X", initial_value=np.zeros(32))
    E = VirtualBuffer((1,), name="E", initial_value=np.zeros(1))
    tdag.submit("k", (32,), [read(X, one_to_one()), reduction(E, "sum")])
    cdag = generate_cdag(tdag, nodes, collectives=True)
    idags = _compile_idags(cdag, nodes)
    coll_sends = sum(1 for g in idags for i in g.instructions
                     if i.itype == InstructionType.COLL_SEND)
    assert 0 < coll_sends <= nodes * num_rounds(nodes)

    # point-to-point oracle: the partial broadcast is N*(N-1) sends
    tdag2 = TaskGraph(horizon_step=100)
    X2 = VirtualBuffer((32,), name="X2", initial_value=np.zeros(32))
    E2 = VirtualBuffer((1,), name="E2", initial_value=np.zeros(1))
    tdag2.submit("k", (32,), [read(X2, one_to_one()), reduction(E2, "sum")])
    cdag2 = generate_cdag(tdag2, nodes, collectives=False)
    idags2 = _compile_idags(cdag2, nodes)
    p2p_sends = sum(1 for g in idags2 for i in g.instructions
                    if i.itype == InstructionType.SEND)
    assert p2p_sends == nodes * (nodes - 1)
    if nodes > 3:
        assert coll_sends < p2p_sends


@pytest.mark.parametrize("nodes,devs", [(1, 1), (2, 2), (3, 1), (6, 1)])
def test_reduction_bitexact_collective_vs_p2p(nodes, devs):
    rng = np.random.default_rng(17)
    data = rng.normal(size=513) * 10.0 ** rng.integers(-20, 20, size=513)
    oracle = math.fsum(data)
    for coll in (False, True):
        with Runtime(num_nodes=nodes, devices_per_node=devs,
                     collectives=coll, host_threads=2) as rt:
            X = rt.buffer((513,), init=data, name="X")
            E = rt.buffer((1,), init=np.zeros(1), name="E")

            def k(chunk, xv, red):
                red.contribute(xv.get(chunk))

            rt.submit("red", (513,),
                      [read(X, one_to_one()), reduction(E, "sum")], k)
            assert float(rt.gather(E)[0]) == oracle
            assert rt.warnings == []


# -- packed reduction fusion (the nbody E+Mx pattern) ------------------------
def _energy_momentum(nodes, devs, *, fused, steps=3, n=96):
    """Adjacent E (energy) and Mx (momentum) reductions each step."""
    rng = np.random.default_rng(5)
    data = rng.normal(size=(n,))
    with Runtime(num_nodes=nodes, devices_per_node=devs,
                 reduction_fusion=fused, host_threads=2) as rt:
        X = rt.buffer((n,), init=data, name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")
        M = rt.buffer((1,), init=np.zeros(1), name="Mx")

        def evolve(chunk, xv):
            xv.set(chunk, xv.get(chunk) * 1.125)

        def energy(chunk, xv, red):
            red.contribute(xv.get(chunk) ** 2)

        def momentum(chunk, xv, red):
            red.contribute(xv.get(chunk) * 3.0)

        es, ms = [], []
        for _ in range(steps):
            rt.submit("evolve", (n,), [read_write(X, one_to_one())], evolve)
            rt.submit("energy", (n,), [read(X, one_to_one()),
                                       reduction(E, "sum")], energy)
            rt.submit("momentum", (n,), [read(X, one_to_one()),
                                         reduction(M, "sum")], momentum)
            es.append(float(rt.gather(E)[0]))
            ms.append(float(rt.gather(M)[0]))
        stats = rt.comm_stats()
        assert rt.warnings == []
    # fsum oracle per step
    x = data.copy()
    oe, om = [], []
    for _ in range(steps):
        x = x * 1.125
        oe.append(math.fsum(x ** 2))
        om.append(math.fsum(x * 3.0))
    return es, ms, oe, om, stats


@pytest.mark.parametrize("nodes,devs", [(1, 1), (2, 2), (3, 1)])
def test_fused_reduction_bitexact(nodes, devs):
    es, ms, oe, om, _ = _energy_momentum(nodes, devs, fused=True)
    assert es == oe and ms == om


@pytest.mark.parametrize("nodes", [2, 3, 4])
def test_fusion_halves_exchanges(nodes):
    """Fused: ONE packed exchange per step; unfused: one exchange per
    reduction per step — exactly double.  The per-exchange message count
    is the allreduce schedule's (reduce-scatter + shard allgather)."""
    steps = 3
    *_, fused_stats = _energy_momentum(nodes, 1, fused=True, steps=steps)
    *_, unfused_stats = _energy_momentum(nodes, 1, fused=False, steps=steps)
    group = tuple(range(nodes))
    per_exchange = allreduce_message_count(group, group, 1)
    assert fused_stats["coll_messages"] == steps * per_exchange
    assert unfused_stats["coll_messages"] == 2 * steps * per_exchange


def test_fusion_respects_dependencies():
    """A reduction whose producing task READS the previous reduction's
    result must not fuse (the packed exchange would deadlock); the chain
    breaks and both values stay correct."""
    n = 32
    data = np.arange(n, dtype=float)
    with Runtime(num_nodes=2, devices_per_node=1, host_threads=2) as rt:
        X = rt.buffer((n,), init=data, name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")
        F = rt.buffer((1,), init=np.zeros(1), name="F")

        def k1(chunk, xv, red):
            red.contribute(xv.get(chunk))

        def k2(chunk, xv, ev, red):
            red.contribute(xv.get(chunk) + ev.get(Box((0,), (1,)))[0])

        t1 = rt.submit("e", (n,), [read(X, one_to_one()),
                                   reduction(E, "sum")], k1)
        t2 = rt.submit("f", (n,), [read(X, one_to_one()),
                                   read(E, all_range()),
                                   reduction(F, "sum")], k2)
        assert not t2.fuse_with_prev      # dependency path E -> t2
        e = float(rt.gather(E)[0])
        f = float(rt.gather(F)[0])
        assert rt.warnings == []
    oe = math.fsum(data)
    assert e == oe
    assert f == math.fsum(data + oe)


def test_fusion_within_one_task():
    """Two reductions bound by ONE task share the packed exchange."""
    n = 64
    data = np.arange(n, dtype=float)
    with Runtime(num_nodes=2, devices_per_node=1, host_threads=2) as rt:
        X = rt.buffer((n,), init=data, name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")
        M = rt.buffer((1,), init=np.zeros(1), name="M")

        def k(chunk, xv, red_e, red_m):
            red_e.contribute(xv.get(chunk) ** 2)
            red_m.contribute(xv.get(chunk))

        rt.submit("both", (n,), [read(X, one_to_one()),
                                 reduction(E, "sum"), reduction(M, "sum")], k)
        e = float(rt.gather(E)[0])
        m = float(rt.gather(M)[0])
        stats = rt.comm_stats()
        assert rt.warnings == []
    assert e == math.fsum(data ** 2)
    assert m == math.fsum(data)
    per_exchange = allreduce_message_count((0, 1), (0, 1), 1)
    assert stats["coll_messages"] == per_exchange     # ONE exchange, not two


def test_include_current_value_with_collectives():
    data = np.arange(24.0)
    for nodes in (1, 2, 3):
        with Runtime(num_nodes=nodes, devices_per_node=1,
                     host_threads=2) as rt:
            X = rt.buffer((24,), init=data, name="X")
            E = rt.buffer((1,), init=np.full(1, 2.25), name="E")

            def k(chunk, xv, red):
                red.contribute(xv.get(chunk))

            rt.submit("k", (24,),
                      [read(X, one_to_one()),
                       reduction(E, "sum", include_current_value=True)], k)
            out = float(rt.gather(E)[0])
        assert out == math.fsum(list(data) + [2.25])
