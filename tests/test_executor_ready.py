"""Regression tests for the ready-queue executor redesign (paper §4.1).

Asserts the observable contract of the dependency-counter engine:

* ready instructions issue immediately, blocked ones only after their last
  dependency completes (no head-of-line blocking behind a stalled chain);
* eager issue still fires: an instruction whose incomplete dependencies all
  sit on one in-order device queue is submitted before they complete;
* horizon completion retires finished instructions so the executor's
  tracking structures stay bounded on long runs.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import Runtime, read_write, one_to_one
from repro.core.command_graph import Command, CommandType
from repro.core.communicator import Communicator
from repro.core.executor import Executor
from repro.core.instruction_graph import Instruction, InstructionType
from repro.core.task_graph import DepKind


class RecordingTracer:
    """Minimal tracer double: logs (event, name) in order, thread-safe."""

    def __init__(self):
        self.events: list[tuple[str, str]] = []
        self._lock = threading.Lock()

    def issue(self, node, instr):
        with self._lock:
            self.events.append(("issue", instr.name))

    def complete(self, node, instr):
        with self._lock:
            self.events.append(("complete", instr.name))

    def record(self, node, instr, lane, **stamps):
        # completion + wait-attribution hook (DESIGN.md §11.2)
        self.complete(node, instr)

    def counter(self, name, value):
        pass                        # scheduler-lag samples: not asserted here

    def wait_for(self, event, timeout=5.0):
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if event in self.events:
                    return True
            time.sleep(0.001)
        return False

    def snapshot(self):
        with self._lock:
            return list(self.events)


def _host_task(name, fn, deps=()):
    i = Instruction(InstructionType.HOST_TASK, node=0, queue=("host",),
                    kernel_fn=fn, name=name)
    for d in deps:
        i.add_dependency(d, DepKind.TRUE)
    return i


def _device_kernel(name, fn, deps=(), device=0):
    i = Instruction(InstructionType.DEVICE_KERNEL, node=0,
                    queue=("device", device), kernel_fn=fn, name=name,
                    device=device)
    for d in deps:
        i.add_dependency(d, DepKind.TRUE)
    return i


def _epoch(name="fin"):
    cmd = Command(CommandType.EPOCH, node=0)
    return Instruction(InstructionType.EPOCH, node=0, queue=("host",),
                       name=name, command=cmd), cmd


def test_ready_queue_order_skips_blocked_chain():
    """An independent instruction issues while a blocked dependent waits."""
    tracer = RecordingTracer()
    comm = Communicator(1)
    ex = Executor(0, 1, comm, host_threads=2, tracer=tracer)
    gate = threading.Event()
    try:
        a = _host_task("A", lambda chunk: gate.wait(5))
        b = _host_task("B", lambda chunk: None, deps=[a])
        c = _host_task("C", lambda chunk: None)
        ex.submit([a, b, c])
        # A (ready) and C (ready) issue; B must not, its dep is incomplete
        assert tracer.wait_for(("issue", "A"))
        assert tracer.wait_for(("issue", "C"))
        assert tracer.wait_for(("complete", "C"))
        assert ("issue", "B") not in tracer.snapshot()
        gate.set()
        assert tracer.wait_for(("issue", "B"))
        ev = tracer.snapshot()
        # B was only issued after A completed (host pool: no eager issue)
        assert ev.index(("issue", "B")) > ev.index(("complete", "A"))
        # ready-queue preserves submission order for same-batch ready instrs
        assert ev.index(("issue", "A")) < ev.index(("issue", "C"))
    finally:
        gate.set()
        ex.shutdown()


def test_eager_issue_on_single_in_order_queue():
    """A device instruction whose incomplete dep sits on one in-order queue
    is submitted eagerly, before the dep completes (§4.1)."""
    tracer = RecordingTracer()
    comm = Communicator(1)
    ex = Executor(0, 1, comm, queues_per_device=2, host_threads=1,
                  tracer=tracer)
    gate = threading.Event()
    try:
        a = _device_kernel("A", lambda chunk: gate.wait(5))
        b = _device_kernel("B", lambda chunk: None, deps=[a])
        ex.submit([a, b])
        assert tracer.wait_for(("issue", "A"))
        # B must be issued while A is still running (gate not yet set)
        assert tracer.wait_for(("issue", "B"))
        ev = tracer.snapshot()
        assert ("complete", "A") not in ev, "eager issue happened too late"
        # both must land on the same in-order queue (FIFO safety)
        qa, qb = ex._issued_on.get(a.iid), ex._issued_on.get(b.iid)
        assert qa is not None and qa is qb
        gate.set()
        assert tracer.wait_for(("complete", "B"))
        ev = tracer.snapshot()
        assert ev.index(("complete", "A")) < ev.index(("complete", "B"))
    finally:
        gate.set()
        ex.shutdown()


def test_horizon_completion_retires_instructions():
    """Completed instructions are dropped from _registered at horizons."""
    tracer = RecordingTracer()
    comm = Communicator(1)
    ex = Executor(0, 1, comm, host_threads=2, tracer=tracer)
    try:
        tasks = [_host_task("t0", lambda chunk: None)]
        for k in range(1, 20):
            tasks.append(_host_task(f"t{k}", lambda chunk: None,
                                    deps=[tasks[-1]]))
        horizon = Instruction(InstructionType.HORIZON, node=0, queue=("host",),
                              name="H")
        horizon.add_dependency(tasks[-1], DepKind.SYNC)
        fin, cmd = _epoch()
        fin.add_dependency(horizon, DepKind.SYNC)
        ex.submit(tasks + [horizon, fin])
        ex.wait_epoch(cmd.cid, timeout=30)
        # everything before the final epoch was retired; dep lists cleared
        assert len(ex._registered) <= 1
        assert ex._retired_count >= len(tasks)
        assert tasks[0].dependents == [] and tasks[5].dependencies == []
    finally:
        ex.shutdown()


def test_runtime_peak_registered_bounded():
    """End-to-end: retained instructions do not grow with program length."""
    def run(steps: int):
        with Runtime(num_nodes=1, devices_per_node=2) as rt:
            B = rt.buffer((64,), init=np.zeros(64), name="b")
            for i in range(steps):
                rt.submit(f"k{i}", (64,), [read_write(B, one_to_one())],
                          lambda c, v: None)
            rt.sync(timeout=120)
            ex = rt.executors[0]
            return ex._peak_registered, len(ex._registered), \
                rt.total_instructions()

    peak_s, final_s, total_s = run(60)
    peak_l, final_l, total_l = run(240)
    assert total_l > 3 * total_s              # the program really did grow
    assert final_s <= 8 and final_l <= 8      # retirement drained both
    # peak must not scale with program length (throttle + retirement)
    assert peak_l < total_l / 3
    assert peak_l <= peak_s + 120


@pytest.mark.parametrize("nodes", [1, 2])
def test_results_unchanged_by_redesign(nodes):
    """The ready-queue engine computes the same data as a plain loop."""
    with Runtime(num_nodes=nodes, devices_per_node=2) as rt:
        B = rt.buffer((32,), init=np.arange(32, dtype=np.float64), name="b")

        def bump(chunk, v):
            v.set(chunk, v.get(chunk) + 1.0)

        for i in range(12):
            rt.submit(f"bump{i}", (32,), [read_write(B, one_to_one())], bump)
        out = rt.gather(B)
    np.testing.assert_allclose(out, np.arange(32) + 12.0)
