"""Pallas kernel validation: shape/dtype sweeps in interpret=True against the
pure-jnp oracles in kernels/ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.nbody import nbody_forces_tpu
from repro.kernels.ssd_scan import ssd_scan_tpu
from repro.kernels.stencil5 import wave_step_tpu
from repro.models.mamba2 import ssd_chunked


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
           dict(atol=2e-5, rtol=2e-5)


# -- flash attention ----------------------------------------------------------
@pytest.mark.parametrize("S,T,K,G,hd", [
    (64, 64, 2, 3, 32), (128, 128, 1, 4, 64), (48, 96, 2, 1, 16),
    (256, 256, 4, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
def test_flash_attention(S, T, K, G, hd, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, S, K, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, K, hd), dtype)
    out = flash_attention_tpu(q, k, v, causal=causal, window=window,
                              q_block=32, kv_block=32, interpret=True)
    exp = ref.flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                                  v.astype(jnp.float32), causal=causal,
                                  window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp),
                               **tol(dtype))


def test_flash_attention_decode_offset():
    """q_offset supports decode-style partial queries."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S0, S1, K, G, hd = 1, 48, 16, 2, 2, 32
    q_full = jax.random.normal(ks[0], (B, S0 + S1, K, G, hd))
    k = jax.random.normal(ks[1], (B, S0 + S1, K, hd))
    v = jax.random.normal(ks[2], (B, S0 + S1, K, hd))
    full = ref.flash_attention_ref(q_full, k, v, causal=True)
    part = flash_attention_tpu(q_full[:, S0:], k, v, causal=True,
                               q_block=16, kv_block=16, interpret=True,
                               q_offset=S0)
    np.testing.assert_allclose(np.asarray(part), np.asarray(full[:, S0:]),
                               atol=2e-5)


# -- nbody ----------------------------------------------------------------------
@pytest.mark.parametrize("N,tile", [(64, 32), (100, 32), (256, 128), (33, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_nbody(N, tile, dtype):
    p = jax.random.normal(jax.random.PRNGKey(0), (N, 3), dtype)
    out = nbody_forces_tpu(p, tile_i=tile, tile_j=tile, interpret=True)
    exp = ref.nbody_forces_ref(p, p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


# -- stencil ----------------------------------------------------------------------
@pytest.mark.parametrize("H,W,tile", [(64, 32, 16), (100, 24, 32), (32, 16, 32)])
def test_wave_step(H, W, tile):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    um = jax.random.normal(k1, (H, W))
    u = jax.random.normal(k2, (H, W))
    out = wave_step_tpu(um, u, tile=tile, interpret=True)
    exp = ref.wave_step_ref(um, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


# -- ssd ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,chunk,h,p,n", [
    (64, 16, 2, 8, 4), (128, 64, 4, 64, 16), (96, 32, 1, 16, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_scan(s, chunk, h, p, n, dtype):
    b = 2
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    a = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    B = jax.random.normal(ks[2], (b, s, n), dtype)
    C = jax.random.normal(ks[3], (b, s, n), dtype)
    y, st = ssd_scan_tpu(x, a, B, C, chunk=chunk, interpret=True)
    ye, ste = ssd_chunked(x, a, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(ste),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunk_ref_single():
    """kernels/ref.ssd_chunk_ref matches the models-level chunked scan."""
    q, h, p, n = 32, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (1, q, h, p))
    a = -jax.nn.softplus(jax.random.normal(ks[1], (1, q, h)))
    B = jax.random.normal(ks[2], (1, q, n))
    C = jax.random.normal(ks[3], (1, q, n))
    y_ref, st_ref = ref.ssd_chunk_ref(x[0], a[0], B[0], C[0])
    y_full, st_full = ssd_chunked(x, a, B, C, q)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_full[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_ref), np.asarray(st_full[0]),
                               rtol=1e-4, atol=1e-4)
