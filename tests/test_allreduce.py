"""Reduce-scatter + allgather allreduce tests (DESIGN.md §9).

Covers the recursive-halving schedule (fold coverage, per-rank message
bounds, non-power-of-two pre-fold), the structural slot-traffic win over
the full-partial slot allgather (~2/N of the bytes, asserted from the
per-message slot-range block sets AND confirmed by ``Communicator`` byte
accounting), CDAG classification (COLL_ALLREDUCE vs the retained
slot-allgather fallback, order-free gating), value bitexactness against
the ``math.fsum`` oracle and the fallback path on 1/2/3/4/6/8-rank
groups, packed-fusion interop, and the ``ReceiveArbiter``'s slot-range
fragment matching with late pilots.
"""

import math

import numpy as np
import pytest

from repro.core import (IdagGenerator, InstructionType, Runtime, TaskGraph,
                        generate_cdag, one_to_one, read, read_write,
                        reduction)
from repro.core.allocation import PINNED_HOST
from repro.core.buffer import VirtualBuffer
from repro.core.collective import (allgather_schedule, allreduce_message_count,
                                   reduce_scatter_schedule, shard_bounds)
from repro.core.command_graph import CommandType
from repro.core.communicator import Communicator, Payload, ReceiveArbiter
from repro.core.instruction_graph import CollFragment, Instruction, Pilot
from repro.core.region import Box

NODE_COUNTS = [1, 2, 3, 4, 6, 8]


# -- the reduce-scatter schedule ---------------------------------------------
@pytest.mark.parametrize("p", NODE_COUNTS + [5, 7, 12])
def test_reduce_scatter_schedule_folds_everything(p):
    """Contributor-set simulation: after the rounds every active rank owns
    its shard folded over ALL participants, with at most one send and one
    receive per rank per round."""
    group = tuple(range(p))
    rounds, owner, m = reduce_scatter_schedule(group)
    held = {r: {s: {r} for s in range(m)} for r in group}
    for msgs in rounds:
        snap = {r: {s: set(v) for s, v in d.items()} for r, d in held.items()}
        srcs = [msg.src for msg in msgs]
        dsts = [msg.dst for msg in msgs]
        assert len(set(srcs)) == len(srcs)       # <= 1 send per rank/round
        assert len(set(dsts)) == len(dsts)       # <= 1 recv per rank/round
        for msg in msgs:
            lo, hi = msg.shards
            for s in range(lo, hi):
                held[msg.dst][s] |= snap[msg.src][s]
    for r, s in owner.items():
        assert held[r][s] == set(group), (p, r, s)
    # each active rank owns exactly one distinct shard; m = 2^floor(log2 p)
    assert sorted(owner.values()) == list(range(m))
    assert m <= p < 2 * m and (m & (m - 1)) == 0


def test_reduce_scatter_non_power_of_two_prefold():
    """p=6: the two excess ranks ship their whole partial in a pre-round
    and drop out; the remaining 4 ranks run the pure halving."""
    rounds, owner, m = reduce_scatter_schedule(range(6))
    assert m == 4
    pre = rounds[0]
    assert [(msg.src, msg.dst, msg.shards) for msg in pre] == \
        [(1, 0, (0, 4)), (3, 2, (0, 4))]
    assert set(owner) == {0, 2, 4, 5}            # excess ranks own nothing


@pytest.mark.parametrize("p", [4, 6, 8])
@pytest.mark.parametrize("slots", [1, 64, 1024])
def test_allreduce_slot_traffic_vs_full_partial(p, slots):
    """Structural byte model from the per-message slot-range block sets:
    reduce-scatter + shard allgather ships <= 0.6x the slots of the
    full-partial dissemination allgather at >= 4 ranks."""
    group = tuple(range(p))
    rounds, owner, m = reduce_scatter_schedule(group)
    bounds = shard_bounds(slots, m)
    rs = sum(bounds[msg.shards[1]] - bounds[msg.shards[0]]
             for msgs in rounds for msg in msgs)
    contributors = tuple(sorted(r for r, s in owner.items()
                                if bounds[s] < bounds[s + 1]))
    ag = sum(bounds[owner[b] + 1] - bounds[owner[b]]
             for msgs in allgather_schedule(group, contributors)
             for msg in msgs for b in msg.blocks)
    full = sum(slots for msgs in allgather_schedule(group, group)
               for msg in msgs for _ in msg.blocks)
    assert (rs + ag) / full <= 0.6, (p, slots, rs + ag, full)


# -- CDAG classification ------------------------------------------------------
def _reduction_tdag(op="sum", n=64):
    tdag = TaskGraph(horizon_step=100)
    X = VirtualBuffer((n,), name="X", initial_value=np.zeros(n))
    E = VirtualBuffer((1,), name="E", initial_value=np.ones(1))
    tdag.submit("k", (n,), [read(X, one_to_one()), reduction(E, op)])
    return tdag


def _cmds(cdag):
    return [c for per_node in cdag.commands for c in per_node]


def test_cdag_classifies_allreduce_with_fallback_flag():
    cdag = generate_cdag(_reduction_tdag(), 4, collectives=True)
    cmds = _cmds(cdag)
    assert any(c.ctype == CommandType.COLL_ALLREDUCE for c in cmds)
    assert not any(c.ctype == CommandType.COLL_ALLGATHER for c in cmds)
    assert all(c.allreduce for c in cmds
               if c.ctype in (CommandType.REDUCE_PARTIAL,
                              CommandType.REDUCE_GLOBAL))
    # the retained slot-allgather path, behind the flag
    cdag2 = generate_cdag(_reduction_tdag(), 4, collectives=True,
                          allreduce=False)
    cmds2 = _cmds(cdag2)
    assert any(c.ctype == CommandType.COLL_ALLGATHER for c in cmds2)
    assert not any(c.ctype == CommandType.COLL_ALLREDUCE for c in cmds2)


def test_two_node_groups_keep_full_partial_exchange():
    """Below 3 nodes the decomposition cannot reduce bytes (every slot
    crosses the wire once per direction regardless) and would only double
    the message count — the fallback stays in charge."""
    cdag = generate_cdag(_reduction_tdag(), 2, collectives=True)
    cmds = _cmds(cdag)
    assert any(c.ctype == CommandType.COLL_ALLGATHER for c in cmds)
    assert not any(c.ctype == CommandType.COLL_ALLREDUCE for c in cmds)


def test_cdag_prod_falls_back_to_slot_allgather():
    """float prod has no order-free combine: the recursive-halving fold
    tree would change bits, so it keeps the canonical slot allgather."""
    cdag = generate_cdag(_reduction_tdag(op="prod"), 4, collectives=True)
    cmds = _cmds(cdag)
    assert any(c.ctype == CommandType.COLL_ALLGATHER for c in cmds)
    assert not any(c.ctype == CommandType.COLL_ALLREDUCE for c in cmds)


def test_mixed_order_free_reductions_do_not_fuse():
    """An order-free (sum) and a canonical-order (prod) reduction never
    share a packed exchange: the fusion chain breaks on the class change
    and each exchange keeps its own mode."""
    n = 32
    tdag = TaskGraph(horizon_step=100)
    X = VirtualBuffer((n,), name="X", initial_value=np.zeros(n))
    E = VirtualBuffer((1,), name="E", initial_value=np.zeros(1))
    P = VirtualBuffer((1,), name="P", initial_value=np.ones(1))
    tdag.submit("e", (n,), [read(X, one_to_one()), reduction(E, "sum")])
    tdag.submit("p", (n,), [read(X, one_to_one()), reduction(P, "prod")])
    cdag = generate_cdag(tdag, 4, collectives=True)
    cmds = _cmds(cdag)
    arx = [c for c in cmds if c.ctype == CommandType.COLL_ALLREDUCE]
    ag = [c for c in cmds if c.ctype == CommandType.COLL_ALLGATHER]
    assert arx and ag                         # two exchanges, one per mode
    assert all(len(c.coll_members) == 1 for c in arx + ag)
    assert {m[1].buffer.name for c in arx for m in c.coll_members} == {"E"}
    assert {m[1].buffer.name for c in ag for m in c.coll_members} == {"P"}


# -- IDAG structural: per-message block sets + bytes --------------------------
def _compile_idags(cdag, num_nodes, num_devices=1):
    idags = []
    for n in range(num_nodes):
        g = IdagGenerator(n, num_devices)
        for cmd in cdag.commands[n]:
            if cmd.ctype == CommandType.EPOCH and cmd.task is None:
                continue
            g.compile(cmd)
        idags.append(g)
    return idags


def _exchange_slots(idags):
    """Slots shipped by reduction-exchange COLL_SENDs (tid tagged 3),
    derived from each message's slot-range / slot fragments."""
    slots = 0
    for g in idags:
        for i in g.instructions:
            if (i.itype != InstructionType.COLL_SEND
                    or len(i.transfer_id) != 4 or i.transfer_id[2] != 3):
                continue
            for f in i.coll_frags:
                if f.srange is not None:
                    slots += f.srange[1] - f.srange[0]
                else:                  # full-partial slot fragment
                    slots += f.alloc.box.volume() // f.alloc.box.shape[0]
    return slots


@pytest.mark.parametrize("nodes", [4, 6, 8])
def test_allreduce_structural_bytes_vs_fallback(nodes):
    n = 256
    slots = {}
    for arx in (False, True):
        tdag = TaskGraph(horizon_step=100)
        X = VirtualBuffer((n,), name="X", initial_value=np.zeros(n))
        V = VirtualBuffer((n,), name="V", initial_value=np.zeros(n))
        tdag.submit("k", (n,), [read(X, one_to_one()), reduction(V, "sum")])
        cdag = generate_cdag(tdag, nodes, collectives=True, allreduce=arx)
        slots[arx] = _exchange_slots(_compile_idags(cdag, nodes))
    assert slots[True] > 0 < slots[False]
    assert slots[True] <= 0.6 * slots[False], slots


# -- runtime: bitexactness + wire accounting ----------------------------------
def _run_reductions(nodes, devs, *, allreduce, n=193):
    rng = np.random.default_rng(23)
    data = rng.normal(size=n) * 10.0 ** rng.integers(-18, 18, size=n)
    vdata = rng.normal(size=(n, 3))
    with Runtime(num_nodes=nodes, devices_per_node=devs,
                 reduction_allreduce=allreduce, host_threads=2) as rt:
        X = rt.buffer((n,), init=data, name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")
        Y = rt.buffer((n, 3), init=vdata, name="Y")
        W = rt.buffer((3,), init=np.zeros(3), name="W")

        def ke(chunk, xv, red):
            red.contribute(xv.get(chunk))

        def kw(chunk, yv, red):
            red.contribute(yv.get(Box((chunk.min[0], 0), (chunk.max[0], 3))))

        rt.submit("e", (n,), [read(X, one_to_one()), reduction(E, "sum")],
                  ke)
        rt.submit("w", (n, 3), [read(Y, one_to_one()), reduction(W, "sum")],
                  kw)
        e = float(rt.gather(E)[0])
        w = rt.gather(W)
        stats = rt.comm_stats()
        assert rt.warnings == [], rt.warnings
    return e, w, data, vdata, stats


@pytest.mark.parametrize("nodes", NODE_COUNTS)
def test_allreduce_bitexact_vs_fsum_and_fallback(nodes):
    """Scalar + multi-dim vector reduction: the allreduce result is
    bitwise identical to ``math.fsum`` AND to the retained slot-allgather
    path on every grid, power-of-two or not."""
    e_a, w_a, data, vdata, stats_a = _run_reductions(nodes, 1, allreduce=True)
    e_f, w_f, _, _, stats_f = _run_reductions(nodes, 1, allreduce=False)
    assert e_a == math.fsum(data)
    assert list(w_a) == [math.fsum(vdata[:, j]) for j in range(3)]
    assert e_a == e_f and list(w_a) == list(w_f)
    if nodes >= 4:
        # wire ground truth: the dominant vector exchange halves traffic
        assert 0 < stats_a["red_bytes"] <= 0.6 * stats_f["red_bytes"], \
            (stats_a, stats_f)


@pytest.mark.parametrize("nodes,devs", [(2, 2), (3, 2)])
def test_allreduce_multi_device(nodes, devs):
    """Device partials fold into the flat accumulator before the exchange."""
    e, w, data, vdata, _ = _run_reductions(nodes, devs, allreduce=True)
    assert e == math.fsum(data)
    assert list(w) == [math.fsum(vdata[:, j]) for j in range(3)]


@pytest.mark.parametrize("nodes", [2, 3, 4, 6])
def test_allreduce_fusion_interop(nodes):
    """Adjacent E+M reductions share ONE two-phase exchange; the wire
    message count equals the replicated schedule's."""
    n = 96
    rng = np.random.default_rng(7)
    data = rng.normal(size=(n,))
    with Runtime(num_nodes=nodes, devices_per_node=1, host_threads=2) as rt:
        X = rt.buffer((n,), init=data, name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")
        M = rt.buffer((1,), init=np.zeros(1), name="M")

        def ke(chunk, xv, red):
            red.contribute(xv.get(chunk) ** 2)

        def km(chunk, xv, red):
            red.contribute(xv.get(chunk) * 3.0)

        rt.submit("e", (n,), [read(X, one_to_one()), reduction(E, "sum")], ke)
        rt.submit("m", (n,), [read(X, one_to_one()), reduction(M, "sum")], km)
        e = float(rt.gather(E)[0])
        m = float(rt.gather(M)[0])
        stats = rt.comm_stats()
        assert rt.warnings == [], rt.warnings
    assert e == math.fsum(data ** 2)
    assert m == math.fsum(data * 3.0)
    group = tuple(range(nodes))
    assert stats["red_messages"] == allreduce_message_count(group, group, 1)


@pytest.mark.parametrize("nodes", [1, 2, 3, 4])
def test_allreduce_include_current_value(nodes):
    data = np.arange(24.0)
    with Runtime(num_nodes=nodes, devices_per_node=1, host_threads=2) as rt:
        X = rt.buffer((24,), init=data, name="X")
        E = rt.buffer((1,), init=np.full(1, 2.25), name="E")

        def k(chunk, xv, red):
            red.contribute(xv.get(chunk))

        rt.submit("k", (24,),
                  [read(X, one_to_one()),
                   reduction(E, "sum", include_current_value=True)], k)
        out = float(rt.gather(E)[0])
        assert rt.warnings == [], rt.warnings
    assert out == math.fsum(list(data) + [2.25])


@pytest.mark.parametrize("nodes", [2, 3, 4, 6])
def test_allreduce_subset_participants(nodes):
    """A single-chunk reduction task: only node 0 contributes, yet every
    node ends with the replicated result (the allgather phase spans ALL
    nodes; non-participants start empty and forward)."""
    from repro.core import all_range, fixed
    with Runtime(num_nodes=nodes, devices_per_node=1, host_threads=2) as rt:
        X = rt.buffer((8,), init=np.arange(8.0), name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")
        O = rt.buffer((nodes,), init=np.zeros(nodes), name="O")

        def k(chunk, xv, red):
            red.contribute(xv.get(Box((0,), (8,))))

        def use(chunk, ev, ov):
            ov.set(chunk, ov.get(chunk) + ev.get(Box((0,), (1,)))[0])

        rt.submit("red", Box((0,), (1,)),
                  [read(X, fixed(Box((0,), (8,)))), reduction(E, "sum")], k)
        rt.submit("use", (nodes,), [read(E, all_range()),
                                    read_write(O, one_to_one())], use)
        o = rt.gather(O)
        assert rt.warnings == [], rt.warnings
    assert list(o) == [math.fsum(np.arange(8.0))] * nodes


@pytest.mark.parametrize("nodes", [2, 3, 4])
def test_prod_matches_p2p_oracle(nodes):
    """The canonical-order fallback keeps prod identical to the
    point-to-point oracle at the same grid."""
    vals = {}
    for coll in (False, True):
        with Runtime(num_nodes=nodes, devices_per_node=1, collectives=coll,
                     host_threads=2) as rt:
            X = rt.buffer((12,), init=1.0 + np.arange(12.0) / 7, name="X")
            P = rt.buffer((1,), init=np.ones(1), name="P")

            def k(chunk, xv, red):
                red.contribute(xv.get(chunk))

            rt.submit("p", (12,), [read(X, one_to_one()),
                                   reduction(P, "prod")], k)
            vals[coll] = float(rt.gather(P)[0])
            assert rt.warnings == [], rt.warnings
    assert vals[False] == vals[True]


@pytest.mark.parametrize("nodes", [2, 4, 6])
@pytest.mark.parametrize("op", ["max", "min"])
def test_order_free_minmax_allreduce(nodes, op):
    rng = np.random.default_rng(31)
    data = rng.normal(size=57)
    with Runtime(num_nodes=nodes, devices_per_node=1, host_threads=2) as rt:
        X = rt.buffer((57,), init=data, name="X")
        M = rt.buffer((1,), init=np.zeros(1), name="M")

        def k(chunk, xv, red):
            red.contribute(xv.get(chunk))

        rt.submit("m", (57,), [read(X, one_to_one()), reduction(M, op)], k)
        out = float(rt.gather(M)[0])
        assert rt.warnings == [], rt.warnings
    assert out == (data.max() if op == "max" else data.min())


# -- ReceiveArbiter: slot-range fragment matching -----------------------------
def _coll_recv(tid, source, land):
    rc = Instruction(InstructionType.COLL_RECV, node=0, transfer_id=tid,
                     coll_source=source,
                     coll_allocs=tuple({f.alloc.aid: f.alloc
                                        for f in land}.values()),
                     coll_expect=tuple(f.key for f in land),
                     coll_land=tuple(land))
    rc.state = "issued"
    return rc


def test_arbiter_slot_range_fragments_with_late_pilots():
    """A COLL_RECV with a slot-range landing map: fragments land at the
    flat ranges of their entries, completion requires every expected key,
    and pilots arriving after the payload change nothing."""
    from repro.core.allocation import Allocation
    comm = Communicator(2)
    store = {}
    acc = Allocation(mid=PINNED_HOST, bid=None, box=Box((0,), (8,)))
    scr = Allocation(mid=PINNED_HOST, bid=None, box=Box((0,), (4,)))
    store[acc.aid] = np.full(8, -1.0)
    store[scr.aid] = np.full(4, -1.0)
    arb = ReceiveArbiter(0, comm, store)
    tid = (5, 0, 3, 1)
    land = [CollFragment(key=(0, 4, 8), alloc=acc, srange=(4, 8)),
            CollFragment(key=(1, 0, 4), alloc=scr, srange=(0, 4))]
    rc = _coll_recv(tid, source=1, land=land)
    arb.begin(rc)
    done = []
    arb.step(done)
    assert done == []
    # first fragment only -> no completion, lands at [4:8) of the acc
    comm.isend(0, Payload(source=1, msg_id=0, transfer_id=tid,
                          fragments=[((0, 4, 8), np.arange(4.0))]))
    arb.step(done)
    assert done == []
    np.testing.assert_array_equal(store[acc.aid][4:], np.arange(4.0))
    np.testing.assert_array_equal(store[acc.aid][:4], np.full(4, -1.0))
    # the pilot arrives LATE (after the payload): accounting only
    comm.post_pilot(Pilot(source=1, target=0, transfer_id=tid,
                          box=Box((0,), (8,)), msg_id=1, gather=True))
    arb.step(done)
    assert done == []
    # the remaining key, in a second packed message from the same source
    comm.isend(0, Payload(source=1, msg_id=1, transfer_id=tid,
                          fragments=[((1, 0, 4), np.full(4, 7.0))]))
    arb.step(done)
    assert done == [rc]
    np.testing.assert_array_equal(store[scr.aid], np.full(4, 7.0))
    assert not arb.has_pending()


def test_arbiter_slot_range_wrong_source_does_not_land():
    """Packed slot-range messages are source-addressed: a payload from a
    different rank with colliding keys must not land."""
    from repro.core.allocation import Allocation
    comm = Communicator(3)
    store = {}
    acc = Allocation(mid=PINNED_HOST, bid=None, box=Box((0,), (4,)))
    store[acc.aid] = np.zeros(4)
    arb = ReceiveArbiter(0, comm, store)
    tid = (6, 0, 3, 0)
    rc = _coll_recv(tid, source=2, land=[
        CollFragment(key=(0, 0, 4), alloc=acc, srange=(0, 4))])
    arb.begin(rc)
    done = []
    comm.isend(0, Payload(source=1, msg_id=0, transfer_id=tid,
                          fragments=[((0, 0, 4), np.full(4, 9.0))]))
    arb.step(done)
    assert done == [] and store[acc.aid].sum() == 0.0
    comm.isend(0, Payload(source=2, msg_id=1, transfer_id=tid,
                          fragments=[((0, 0, 4), np.full(4, 3.0))]))
    arb.step(done)
    assert done == [rc]
    np.testing.assert_array_equal(store[acc.aid], np.full(4, 3.0))
