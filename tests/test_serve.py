"""Serving-entry regression tests.

``ServeLoop.submit`` is called from many client threads at once; request
ids must stay unique and no request may be lost (a duplicated rid loses a
request for anyone keying on it — the original race was a non-atomic
``self._rid += 1`` read-modify-write).
"""

import threading

import numpy as np

from repro.runtime.serve_loop import ServeLoop


def _bare_serve_loop() -> ServeLoop:
    """A ServeLoop with only the submission plumbing — no model build, so
    the concurrency test isolates exactly the submit path."""
    import itertools
    import queue

    sl = object.__new__(ServeLoop)
    sl.queue = queue.Queue()
    sl._rids = itertools.count(1)
    return sl


def test_submit_rids_unique_under_contention():
    """8 threads x 50 submissions: every request lands in the queue with a
    distinct rid and none is lost."""
    sl = _bare_serve_loop()
    n_threads, per_thread = 8, 50
    reqs = [[] for _ in range(n_threads)]
    barrier = threading.Barrier(n_threads)

    def client(slot):
        barrier.wait()                 # maximal contention at the counter
        for k in range(per_thread):
            reqs[slot].append(sl.submit(np.array([slot, k]), max_new=1))

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    total = n_threads * per_thread
    flat = [r for rs in reqs for r in rs]
    rids = [r.rid for r in flat]
    assert len(set(rids)) == total, "duplicate rids handed out"
    assert sl.queue.qsize() == total, "requests lost on the way to the queue"
    assert min(rids) == 1 and max(rids) == total   # dense: nothing skipped


def test_submit_copies_prompt_as_int32():
    sl = _bare_serve_loop()
    req = sl.submit([3, 1, 4], max_new=7)
    assert req.prompt.dtype == np.int32
    assert req.max_new == 7
    assert list(req.prompt) == [3, 1, 4]
