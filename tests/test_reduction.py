"""Distributed reduction subsystem tests (§2.2 + acceptance criteria).

Covers the value semantics (exact-sum superaccumulator vs ``math.fsum``),
the end-to-end pipeline on 1/2/4 simulated nodes (bit-for-bit partition
independence), visibility of the new instruction types in the IDAG, and the
no-serialization property for unrelated kernels.
"""

import math
import time

import numpy as np
import pytest

from repro.core import (IdagGenerator, InstructionType, Runtime, TaskGraph,
                        all_range, generate_cdag, one_to_one, read,
                        read_write, reduction, write)
from repro.core.command_graph import CommandType
from repro.core.reduction import ReductionOp, _make_op
from repro.core.region import Box

NODE_GRIDS = [(1, 1), (2, 2), (4, 1)]


# -- value semantics ---------------------------------------------------------
def test_exact_sum_matches_fsum_any_split():
    rng = np.random.default_rng(0)
    vals = list(rng.normal(size=257) * 10.0 ** rng.integers(-8, 8, size=257))
    op = _make_op("sum", None)
    oracle = math.fsum(vals)
    for nsplit in (1, 2, 3, 7, 257):
        accs = []
        bounds = np.linspace(0, len(vals), nsplit + 1).astype(int)
        for i in range(nsplit):
            acc = op.identity_acc((1,), np.dtype(np.float64))
            op.contribute(acc, np.asarray(vals[bounds[i]:bounds[i + 1]]))
            accs.append(acc)
        total = accs[0]
        for a in accs[1:]:
            total = op.combine(total, a)
        assert op.finalize(total, np.dtype(np.float64))[0] == oracle


def test_binned_exact_sum_large_oracle():
    """The vectorized two-level binned accumulator (int64 limb bins + one
    big-int carry fold) is bitwise identical to the elementwise lift AND to
    ``math.fsum`` on a >=1e5-element mixed-magnitude input."""
    from repro.core.reduction import _exact_scale, _exact_scale_sum
    rng = np.random.default_rng(3)
    n = 120_000
    vals = rng.normal(size=n) * 10.0 ** rng.integers(-250, 250, size=n)
    vals[:100] = rng.normal(size=100) * 5e-324          # subnormals
    vals[100:200] = 0.0
    vals[200] = -0.0
    binned = _exact_scale_sum(vals.reshape(-1, 1))[0]
    elementwise = _exact_scale(vals.reshape(-1, 1)).sum(axis=0)[0]
    assert binned == elementwise
    op = _make_op("sum", None)
    acc = op.identity_acc((1,), np.dtype(np.float64))
    op.contribute(acc, vals)
    assert op.finalize(acc, np.dtype(np.float64))[0] == math.fsum(vals)


def test_binned_exact_sum_vector_shape():
    """Binned accumulation with a non-scalar reduction shape matches the
    elementwise path per output element."""
    from repro.core.reduction import _exact_scale, _exact_scale_sum
    rng = np.random.default_rng(4)
    vals = rng.normal(size=(512, 3, 2)) * 10.0 ** rng.integers(-40, 40,
                                                               size=(512, 3, 2))
    binned = _exact_scale_sum(vals)
    elementwise = _exact_scale(vals).sum(axis=0)
    assert binned.shape == (3, 2)
    assert (binned == elementwise).all()


def test_runtime_exact_sum_1e5_elements():
    """End-to-end: a 1e5-element distributed sum stays bit-for-bit equal to
    the fsum oracle (and fast enough to live in the tier-1 suite)."""
    n = 100_000
    rng = np.random.default_rng(5)
    data = rng.normal(size=n) * 10.0 ** rng.integers(-30, 30, size=n)
    with Runtime(num_nodes=2, devices_per_node=2) as rt:
        X = rt.buffer((n,), init=data, name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")

        def k(chunk, xv, red):
            red.contribute(xv.get(chunk))

        rt.submit("redsum", (n,), [read(X, one_to_one()), reduction(E, "sum")], k)
        assert float(rt.gather(E)[0]) == math.fsum(data)


def test_minmax_prod_and_custom_ops():
    data = np.array([3.0, -7.5, 2.25, 11.0])
    for name, expect in [("max", 11.0), ("min", -7.5), ("prod", np.prod(data))]:
        op = _make_op(name, None)
        acc = op.identity_acc((1,), np.dtype(np.float64))
        op.contribute(acc, data)
        assert op.finalize(acc, np.dtype(np.float64))[0] == expect
    op = _make_op(lambda a, b: np.hypot(a, b), 0.0)
    acc = op.identity_acc((1,), np.dtype(np.float64))
    op.contribute(acc, data)
    assert acc[0] == pytest.approx(np.sqrt((data ** 2).sum()))
    with pytest.raises(ValueError):
        _make_op(lambda a, b: a + b, None)   # custom op needs identity
    with pytest.raises(ValueError):
        _make_op("median", None)


def test_minmax_integer_dtype_identity():
    """Integer buffers get iinfo-based identities, not +/-inf (which cannot
    be stored in an integer accumulator)."""
    data = np.array([3, -7, 11], dtype=np.int64)
    for name, expect in [("max", 11), ("min", -7)]:
        op = _make_op(name, None)
        acc = op.identity_acc((1,), np.dtype(np.int64))
        op.contribute(acc, data)
        assert op.finalize(acc, np.dtype(np.int64))[0] == expect


def test_integer_max_reduction_runtime():
    data = np.arange(32, dtype=np.int64) - 5
    with Runtime(num_nodes=2, devices_per_node=2) as rt:
        X = rt.buffer((32,), dtype=np.int64, init=data, name="X")
        M = rt.buffer((1,), dtype=np.int64, init=np.zeros(1, np.int64),
                      name="M")

        def k(chunk, xv, red):
            red.contribute(xv.get(chunk))

        rt.submit("k", (32,), [read(X, one_to_one()), reduction(M, "max")], k)
        assert int(rt.gather(M)[0]) == 26


def test_exact_sum_rejects_non_finite():
    op = _make_op("sum", None)
    acc = op.identity_acc((1,), np.dtype(np.float64))
    with pytest.raises(ValueError, match="non-finite"):
        op.contribute(acc, np.array([1.0, np.inf]))


def test_integer_sum_is_exact_beyond_2_53():
    """int64 contributions lift as raw integers — no float64 round-trip."""
    op = _make_op("sum", None)
    acc = op.identity_acc((1,), np.dtype(np.int64))
    op.contribute(acc, np.array([2 ** 53 + 1, 1], dtype=np.int64))
    assert op.finalize(acc, np.dtype(np.int64))[0] == 2 ** 53 + 2


def test_duplicate_reduction_buffer_rejected():
    tdag = TaskGraph()
    from repro.core import VirtualBuffer
    X = VirtualBuffer(shape=(8,), initial_value=np.zeros(8), name="X")
    E = VirtualBuffer(shape=(1,), initial_value=np.zeros(1), name="E")
    with pytest.raises(ValueError, match="multiple reductions"):
        tdag.submit("bad", (8,), [read(X, one_to_one()),
                                  reduction(E, "sum"), reduction(E, "max")])


# -- end-to-end: nbody total energy (acceptance criterion) -------------------
def _nbody_energy(nodes, devs, N=48, steps=3, dt=0.01, eps=1e-3):
    rng = np.random.default_rng(7)
    P0 = rng.normal(size=(N, 3))
    V0 = rng.normal(size=(N, 3)) * 0.1

    def energies(P, Vrows, lo, hi):
        d = P[None, :, :] - P[lo:hi, None, :]
        r2 = (d * d).sum(-1) + eps
        pot = -0.5 / np.sqrt(r2)
        for r in range(hi - lo):
            pot[r, lo + r] = 0.0
        return 0.5 * (Vrows ** 2).sum(-1) + pot.sum(1)

    with Runtime(num_nodes=nodes, devices_per_node=devs, trace=True) as rt:
        P = rt.buffer((N, 3), init=P0, name="P")
        V = rt.buffer((N, 3), init=V0, name="V")
        E = rt.buffer((1,), init=np.zeros(1), name="E")

        def timestep(chunk, p, v):
            Pa = p.get(Box((0, 0), (N, 3)))
            lo, hi = chunk.min[0], chunk.max[0]
            d = Pa[None, :, :] - Pa[lo:hi, None, :]
            r2 = (d * d).sum(-1) + eps
            v.set(chunk, v.get(chunk) + (d / r2[..., None] ** 1.5).sum(1) * dt)

        def update(chunk, v, p):
            p.set(chunk, p.get(chunk) + v.get(chunk) * dt)

        def energy(chunk, p, v, red):
            Pa = p.get(Box((0, 0), (N, 3)))
            lo, hi = chunk.min[0], chunk.max[0]
            red.contribute(energies(Pa, v.get(chunk), lo, hi))

        for _ in range(steps):
            rt.submit("timestep", (N, 3),
                      [read(P, all_range()), read_write(V, one_to_one())],
                      timestep)
            rt.submit("update", (N, 3),
                      [read(V, one_to_one()), read_write(P, one_to_one())],
                      update)
        rt.submit("energy", (N, 3),
                  [read(P, all_range()), read(V, one_to_one()),
                   reduction(E, "sum")], energy)
        e = float(rt.gather(E)[0])
        assert rt.warnings == []
        tracer = rt.tracer

    # single-node oracle (math.fsum == correctly-rounded sum)
    P, V = P0.copy(), V0.copy()
    for _ in range(steps):
        d = P[None, :, :] - P[:, None, :]
        r2 = (d * d).sum(-1) + eps
        V = V + (d / r2[..., None] ** 1.5).sum(1) * dt
        P = P + V * dt
    oracle = math.fsum(energies(P, V, 0, N))
    return e, oracle, tracer


@pytest.mark.parametrize("nodes,devs", NODE_GRIDS)
def test_nbody_energy_bit_for_bit(nodes, devs):
    e, oracle, tracer = _nbody_energy(nodes, devs)
    assert e == oracle
    kinds = {s.kind for ss in tracer.lanes().values() for s in ss}
    assert "global_reduce" in kinds and "local_reduce" in kinds
    assert "fill_identity" in kinds
    if nodes > 1:
        # the partial exchange runs as collective rounds (DESIGN.md §9)
        assert "coll_recv" in kinds and "coll_send" in kinds


# -- end-to-end: wavesim residual norm (acceptance criterion) ----------------
def _wavesim_residual(nodes, devs, H=24, W=16, steps=3, c=0.25):
    rng = np.random.default_rng(3)
    u0 = np.zeros((H, W))
    u1 = rng.normal(size=(H, W)) * 0.01
    u1[0, :] = u1[-1, :] = u1[:, 0] = u1[:, -1] = 0.0

    def step_kernel(chunk, um_v, u_v, un_v):
        lo, hi = chunk.min[0], chunk.max[0]
        ext = Box((max(0, lo - 1), 0), (min(H, hi + 1), W))
        u = u_v.get(ext)
        um = um_v.get(chunk)
        pad = lo - ext.min[0]
        out = np.empty((hi - lo, W))
        for r in range(hi - lo):
            g, gi = r + pad, lo + r
            if gi == 0 or gi == H - 1:
                out[r] = 0.0
                continue
            row = u[g]
            lap = (u[g - 1] + u[g + 1] + np.roll(row, 1) + np.roll(row, -1)
                   - 4 * row)
            out[r] = 2 * row - um[r] + c * lap
            out[r, 0] = out[r, -1] = 0.0
        un_v.set(chunk, out)

    def residual(chunk, ua, ub, red):
        d = ub.get(chunk) - ua.get(chunk)
        red.contribute(d * d)

    from repro.core import neighborhood
    with Runtime(num_nodes=nodes, devices_per_node=devs) as rt:
        B = [rt.buffer((H, W), init=u0, name="um"),
             rt.buffer((H, W), init=u1, name="u"),
             rt.buffer((H, W), init=np.zeros((H, W)), name="un")]
        R2 = rt.buffer((1,), init=np.zeros(1), name="R2")
        for s in range(steps):
            um, u, un = B[s % 3], B[(s + 1) % 3], B[(s + 2) % 3]
            rt.submit(f"wave{s}", (H, W),
                      [read(um, one_to_one()), read(u, neighborhood((1, 0))),
                       write(un, one_to_one())], step_kernel)
        rt.submit("residual", (H, W),
                  [read(B[steps % 3], one_to_one()),
                   read(B[(steps + 1) % 3], one_to_one()),
                   reduction(R2, "sum")], residual)
        res2 = float(rt.gather(R2)[0])
        last = rt.gather(B[(steps + 1) % 3])
        prev = rt.gather(B[steps % 3])
        assert rt.warnings == []
    return res2, math.fsum(((last - prev) ** 2).ravel())


@pytest.mark.parametrize("nodes,devs", NODE_GRIDS)
def test_wavesim_residual_bit_for_bit(nodes, devs):
    res2, oracle = _wavesim_residual(nodes, devs)
    assert res2 == oracle


# -- include_current_value / other ops end-to-end ----------------------------
@pytest.mark.parametrize("nodes", [1, 2, 4])
def test_include_current_value_folds_once(nodes):
    data = np.arange(32.0)
    with Runtime(num_nodes=nodes, devices_per_node=1) as rt:
        X = rt.buffer((32,), init=data, name="X")
        E = rt.buffer((1,), init=np.full(1, 5.5), name="E")

        def k(chunk, xv, red):
            red.contribute(xv.get(chunk))

        rt.submit("k", (32,),
                  [read(X, one_to_one()),
                   reduction(E, "sum", include_current_value=True)], k)
        out = float(rt.gather(E)[0])
    assert out == math.fsum(list(data) + [5.5])


@pytest.mark.parametrize("op,expect", [("max", 31.0), ("min", 0.0)])
def test_minmax_reduction_runtime(op, expect):
    data = np.arange(32.0)
    with Runtime(num_nodes=2, devices_per_node=2) as rt:
        X = rt.buffer((32,), init=data, name="X")
        M = rt.buffer((1,), init=np.zeros(1), name="M")

        def k(chunk, xv, red):
            red.contribute(xv.get(chunk))

        rt.submit("k", (32,), [read(X, one_to_one()), reduction(M, op)], k)
        assert float(rt.gather(M)[0]) == expect


# -- TDAG replicated-pending state -------------------------------------------
def test_tdag_tracks_pending_reduction():
    tdag = TaskGraph(horizon_step=100)
    from repro.core import VirtualBuffer
    X = VirtualBuffer(shape=(8,), initial_value=np.zeros(8), name="X")
    E = VirtualBuffer(shape=(1,), initial_value=np.zeros(1), name="E")
    t = tdag.submit("k", (8,), [read(X, one_to_one()), reduction(E, "sum")])
    assert tdag.pending_reductions() == {E.bid: t}
    # a reader takes a TRUE dep on the reduction task
    t2 = tdag.submit("r", (1,), [read(E, one_to_one())])
    assert any(d is t and k.value == "true" for d, k in t2.dependencies)
    # ANY overwrite (even partial) clears the replicated-pending state
    S = VirtualBuffer(shape=(4,), initial_value=np.zeros(4), name="S")
    ts = tdag.submit("k2", (4,), [read(X, one_to_one()), reduction(S, "sum")])
    assert tdag.pending_reductions()[S.bid] is ts
    tdag.submit("wpart", (2,), [write(S, one_to_one())])   # partial write
    assert S.bid not in tdag.pending_reductions()
    # a full overwrite clears it too
    tdag.submit("w", (1,), [write(E, one_to_one())])
    assert tdag.pending_reductions() == {}


# -- IDAG structure: instruction types + no serialization --------------------
def _compile_idags(tdag, num_nodes, num_devices=2):
    cdag = generate_cdag(tdag, num_nodes)
    idags = []
    for n in range(num_nodes):
        g = IdagGenerator(n, num_devices)
        for cmd in cdag.commands[n]:
            if cmd.ctype == CommandType.EPOCH and cmd.task is None:
                continue
            g.compile(cmd)
        idags.append(g)
    return cdag, idags


def test_idag_contains_reduction_instructions():
    from repro.core import VirtualBuffer
    tdag = TaskGraph(horizon_step=100)
    X = VirtualBuffer(shape=(16,), initial_value=np.zeros(16), name="X")
    E = VirtualBuffer(shape=(1,), initial_value=np.zeros(1), name="E")
    tdag.submit("k", (16,), [read(X, one_to_one()), reduction(E, "sum")])
    cdag, idags = _compile_idags(tdag, 2)
    for n, g in enumerate(idags):
        kinds = [i.itype for i in g.instructions]
        assert kinds.count(InstructionType.FILL_IDENTITY) == 2  # one per device
        assert InstructionType.LOCAL_REDUCE in kinds
        assert InstructionType.GATHER_RECEIVE in kinds
        assert InstructionType.GLOBAL_REDUCE in kinds
        # gather expects exactly the peer rank
        gr = next(i for i in g.instructions
                  if i.itype == InstructionType.GATHER_RECEIVE)
        assert gr.gather_sources == tuple(p for p in (0, 1) if p != n)
        # the partial broadcast posts one pilot per peer, flagged as gather
    for n, g in enumerate(idags):
        gather_pilots = [p for p in g.pilots if p.gather]
        assert [p.target for p in gather_pilots] == [1 - n]


def test_reduction_does_not_serialize_unrelated_kernels_structurally():
    """No dependency path between the reduction pipeline and kernels on
    unrelated buffers — the IDAG keeps them fully concurrent."""
    from repro.core import VirtualBuffer
    tdag = TaskGraph(horizon_step=100)      # no horizons: pure dataflow deps
    X = VirtualBuffer(shape=(16,), initial_value=np.zeros(16), name="X")
    E = VirtualBuffer(shape=(1,), initial_value=np.zeros(1), name="E")
    B = VirtualBuffer(shape=(16,), initial_value=np.zeros(16), name="B")
    tdag.submit("red", (16,), [read(X, one_to_one()), reduction(E, "sum")])
    for i in range(3):
        tdag.submit(f"unrel{i}", (16,), [read_write(B, one_to_one())])
    cdag, idags = _compile_idags(tdag, 2)
    red_types = {InstructionType.LOCAL_REDUCE, InstructionType.GATHER_RECEIVE,
                 InstructionType.GLOBAL_REDUCE, InstructionType.FILL_IDENTITY}
    for g in idags:
        red_instrs = {i for i in g.instructions if i.itype in red_types}
        kernels = [i for i in g.instructions
                   if i.itype == InstructionType.DEVICE_KERNEL
                   and i.name.startswith("unrel")]
        assert kernels and red_instrs
        seen = set()

        def reaches_reduction(i):
            if i.iid in seen:
                return False
            seen.add(i.iid)
            return any(d in red_instrs or reaches_reduction(d)
                       for d, _ in i.dependencies)

        for k in kernels:
            seen.clear()
            assert not reaches_reduction(k), \
                f"{k} transitively depends on the reduction pipeline"


def test_reduction_overlaps_unrelated_kernels_timewise():
    """While rank 1's slow partial delays the gather, rank 0 keeps executing
    unrelated kernels (Tracer.overlap_fraction > 0 between device lanes)."""
    with Runtime(num_nodes=2, devices_per_node=1, trace=True) as rt:
        X = rt.buffer((16,), init=np.zeros(16), name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")
        B = rt.buffer((16,), init=np.zeros(16), name="B")

        def red_kernel(chunk, xv, red):
            if chunk.min[0] >= 8:
                time.sleep(0.15)        # rank 1 is slow to produce
            red.contribute(xv.get(chunk))

        def unrel(chunk, bv):
            time.sleep(0.01)
            bv.set(chunk, bv.get(chunk) + 1)

        rt.submit("red", (16,), [read(X, one_to_one()), reduction(E, "sum")],
                  red_kernel)
        for i in range(10):
            rt.submit(f"unrel{i}", (16,), [read_write(B, one_to_one())], unrel)
        rt.sync()
        tr = rt.tracer
        assert float(rt.gather(E)[0]) == 0.0
    f = tr.overlap_fraction("N0.device", "N1.device")
    assert f > 0.2, f"unrelated kernels serialized behind the reduction: {f}"
