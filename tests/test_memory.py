"""Memory-layer tests (DESIGN.md §8): budgeted MemoryManager, spill/reload
correctness against an unbudgeted oracle, eviction ordering under concurrent
readers, and lookahead-reservation cooperation.
"""

import numpy as np

from repro.core import (IdagGenerator, InstructionType, Runtime, TaskGraph,
                        generate_cdag, one_to_one, read, read_write, write)
from repro.core.allocation import PINNED_HOST, device_memory
from repro.core.buffer import VirtualBuffer
from repro.core.command_graph import CommandType
from repro.core.task_graph import DepKind

N = 4096                      # per-buffer doubles -> 32768 bytes
BYTES = N * 8


# --------------------------------------------------------------------------
# end-to-end: budget pressure vs an unbudgeted oracle
# --------------------------------------------------------------------------
def _phased_program(q, groups=3, revisit=True):
    """``groups`` disjoint (A, B) buffer pairs touched in phases; phase 0 is
    split in half around the other phases so its buffers are evicted while
    dirty (spill) and touched again afterwards (reload)."""
    rng = np.random.default_rng(7)
    bufs = [(q.buffer((N,), init=rng.normal(size=N), name=f"A{g}"),
             q.buffer((N,), init=np.zeros(N), name=f"B{g}"))
            for g in range(groups)]

    def steps(g, lo, hi):
        A, B = bufs[g]
        for s in range(lo, hi):
            def k(chunk, av, bv, s=s):
                bv.set(chunk, bv.get(chunk) + av.get(chunk) * (s + 1))
            q.submit(f"g{g}s{s}", (N,), [read(A, one_to_one()),
                                         read_write(B, one_to_one())], k)

    if revisit:
        steps(0, 0, 3)
        for g in range(1, groups):
            steps(g, 0, 6)
        steps(0, 3, 6)        # phase 0 resumes after eviction -> RELOAD
    else:
        for g in range(groups):
            steps(g, 0, 6)
    return [q.gather(B) for _, B in bufs]


def _device_peak(report):
    return max((v for k, v in report["real_peak"].items() if k >= 2),
               default=0)


def test_spill_reload_bitwise_oracle():
    """Budget = 50% of the unbudgeted high-water mark: results stay
    bit-identical, real per-memory peaks stay under budget, and both spill
    and reload paths are actually exercised."""
    with Runtime(1, 1) as q:
        base = _phased_program(q)
        rep = q.memory_report()[0]
    hwm = _device_peak(rep)
    assert rep["spills"] == rep["reloads"] == 0      # unbudgeted: no pressure

    budget = hwm // 2
    with Runtime(1, 1, device_memory_budget=budget) as q:
        out = _phased_program(q)
        rep2 = q.memory_report()[0]
        warnings = q.warnings
    assert warnings == []
    for a, b in zip(base, out):
        np.testing.assert_array_equal(a, b)
    assert rep2["spills"] > 0 and rep2["reloads"] > 0
    assert rep2["evictions"] > 0
    assert rep2["over_budget"] == 0
    assert _device_peak(rep2) <= budget
    # the compile-time model never exceeded the budget either
    assert all(v <= budget for k, v in rep2["peak"].items() if k >= 2)


def test_budget_quarter_of_working_set():
    """25% of the working set (6 phases): still bit-identical, still under
    budget — one phase's working set fits, everything else cycles through."""
    with Runtime(1, 1) as q:
        base = _phased_program(q, groups=6, revisit=False)
        rep = q.memory_report()[0]
    hwm = _device_peak(rep)
    budget = hwm // 4
    with Runtime(1, 1, device_memory_budget=budget) as q:
        out = _phased_program(q, groups=6, revisit=False)
        rep2 = q.memory_report()[0]
        warnings = q.warnings
    assert warnings == []
    for a, b in zip(base, out):
        np.testing.assert_array_equal(a, b)
    assert rep2["evictions"] > 0
    assert _device_peak(rep2) <= budget


def test_budget_multi_node_multi_device():
    """Budgets are per device memory on every node; a 2x2 grid stays
    bit-identical under 50% pressure."""
    def run(budget):
        with Runtime(2, 2, device_memory_budget=budget) as q:
            out = _phased_program(q)
            reps = q.memory_report()
            warnings = q.warnings
        return out, reps, warnings

    base, reps, _ = run(None)
    hwm = max(_device_peak(r) for r in reps)
    out, reps2, warnings = run(hwm // 2)
    assert warnings == []
    for a, b in zip(base, out):
        np.testing.assert_array_equal(a, b)
    assert sum(r["evictions"] for r in reps2) > 0
    assert all(_device_peak(r) <= hwm // 2 for r in reps2)


def test_traced_memory_counters_match_executor_peaks():
    """With tracing on, per-memory byte counter tracks are recorded and
    their peaks (``Tracer.counter_peaks``) agree with the executor's
    ground-truth accounting."""
    with Runtime(1, 1, device_memory_budget=2 * BYTES, trace=True) as q:
        _phased_program(q)
        tracer = q.tracer
        ex_peaks = {f"N0.M{mid}.bytes": v
                    for mid, v in q.executors[0].mem_peak.items()}
    peaks = tracer.counter_peaks()
    assert peaks, "no counter tracks recorded"
    for name, v in ex_peaks.items():
        assert peaks.get(name) == v, (name, peaks.get(name), v)
    dev = {k: v for k, v in peaks.items() if ".M2." in k}
    assert dev and all(v <= 2 * BYTES for v in dev.values())


def test_over_budget_fallback_never_fails():
    """A budget smaller than a single kernel's working set cannot be met —
    the manager goes over budget with a warning instead of failing, and the
    results remain correct."""
    with Runtime(1, 1, device_memory_budget=BYTES // 2) as q:
        A = q.buffer((N,), init=np.ones(N), name="A")
        B = q.buffer((N,), init=np.zeros(N), name="B")

        def k(chunk, av, bv):
            bv.set(chunk, av.get(chunk) * 2.0)

        q.submit("k", (N,), [read(A, one_to_one()), write(B, one_to_one())], k)
        out = q.gather(B)
        rep = q.memory_report()[0]
        warnings = q.warnings
    np.testing.assert_array_equal(out, np.full(N, 2.0))
    assert rep["over_budget"] > 0
    assert any("over budget" in w for w in warnings)


def test_over_budget_warning_dedup():
    """Long over-budget runs must not grow ``Runtime.warnings`` without
    bound: repeated pressure on the same (memory, node) updates ONE entry
    with a repeat counter instead of appending per pressuring ALLOC."""
    steps = 12
    with Runtime(1, 1, device_memory_budget=BYTES // 2) as q:
        A = q.buffer((N,), init=np.ones(N), name="A")
        B = q.buffer((N,), init=np.zeros(N), name="B")

        def k(chunk, av, bv, s=0):
            bv.set(chunk, av.get(chunk) + bv.get(chunk))

        for s in range(steps):
            q.submit(f"k{s}", (N,),
                     [read(A, one_to_one()), read_write(B, one_to_one())], k)
        out = q.gather(B)
        rep = q.memory_report()[0]
        warnings = q.warnings
    np.testing.assert_array_equal(out, np.full(N, float(steps)))
    over = [w for w in warnings if "over budget" in w]
    assert rep["over_budget"] > 1
    # one deduped entry per (memory, node), carrying the repeat count
    assert len(over) == 1, over
    assert f"repeated {rep['over_budget']} times" in over[0], over[0]


def test_reduction_under_budget_bit_for_bit():
    """Reduction scratches are charged against the budget but never evicted;
    a budgeted distributed sum stays bitwise equal to the unbudgeted one."""
    import math
    n = 8192
    rng = np.random.default_rng(11)
    data = rng.normal(size=n)
    from repro.core import reduction

    def run(budget):
        with Runtime(2, 2, device_memory_budget=budget) as rt:
            X = rt.buffer((n,), init=data, name="X")
            Y = rt.buffer((n,), init=data * 2, name="Y")
            E = rt.buffer((1,), init=np.zeros(1), name="E")

            def k(chunk, v, red):
                red.contribute(v.get(chunk))

            rt.submit("r1", (n,), [read(X, one_to_one()), reduction(E, "sum")], k)
            rt.submit("r2", (n,), [read(Y, one_to_one()), reduction(E, "sum")], k)
            return float(rt.gather(E)[0])

    unbudgeted = run(None)
    assert unbudgeted == math.fsum(data * 2)
    assert run(n * 8) == unbudgeted        # room for ~one buffer chunk set


# --------------------------------------------------------------------------
# structural: spill-chain dependency rules
# --------------------------------------------------------------------------
def _compile(tdag, idag):
    gen = generate_cdag(tdag, 1)
    out = []
    for cmd in gen.commands[0]:
        if cmd.ctype == CommandType.EPOCH and cmd.task is None:
            continue
        out.extend(idag.compile(cmd))
    return out


def test_spill_chain_dependency_rules():
    """SPILL copies depend on the producer, the evicting FREE is
    anti-ordered after the spill copy AND all prior readers, and the
    pressure-causing ALLOC is anti-ordered after the FREE (so the executor
    can never exceed the budget at runtime)."""
    tdag = TaskGraph()
    A = VirtualBuffer((N,), name="A")
    B = VirtualBuffer((N,), name="B")
    tdag.submit("wA", (N,), [write(A, one_to_one())])
    tdag.submit("wB", (N,), [write(B, one_to_one())])   # evicts A (dirty)
    tdag.submit("rA", (N,), [read_write(A, one_to_one())])  # reloads A
    idag = IdagGenerator(0, 1, budgets={device_memory(0): BYTES})
    _compile(tdag, idag)
    instrs = idag.instructions
    by_type = {}
    for i in instrs:
        by_type.setdefault(i.itype, []).append(i)

    spills = by_type.get(InstructionType.SPILL, [])
    reloads = by_type.get(InstructionType.RELOAD, [])
    # A is spilled to make room for B; B is spilled when A returns
    assert len(spills) == 2 and len(reloads) == 1
    spill = next(s for s in spills if s.src_alloc.bid == A.bid)
    assert spill.src_alloc.mid == device_memory(0)
    assert spill.dst_alloc.mid == PINNED_HOST
    # the spill reads what the kernel wrote
    wA = next(i for i in instrs if i.name == "wA")
    assert any(d is wA for d, _ in spill.dependencies)

    # the FREE of the victim is anti-ordered after the spill copy
    victim_free = next(i for i in by_type[InstructionType.FREE]
                       if i.allocation is spill.src_alloc)
    dep_kinds = {d.iid: k for d, k in victim_free.dependencies}
    assert dep_kinds.get(spill.iid) == DepKind.ANTI
    assert dep_kinds.get(wA.iid) == DepKind.ANTI

    # the ALLOC that caused the pressure waits for the FREE
    b_alloc = next(i for i in by_type[InstructionType.ALLOC]
                   if i.allocation.bid == B.bid
                   and i.allocation.mid == device_memory(0))
    assert any(d is victim_free and k == DepKind.ANTI
               for d, k in b_alloc.dependencies)
    assert instrs.index(victim_free) < instrs.index(b_alloc)

    # the reload brings the spilled bytes back and reads the spill copy
    reload = reloads[0]
    assert reload.dst_alloc.mid == device_memory(0)
    assert reload.src_alloc is spill.dst_alloc
    assert any(d is spill for d, _ in reload.dependencies)


def test_eviction_orders_after_concurrent_readers():
    """Two kernels read the victim allocation; the evicting FREE must be
    anti-ordered after BOTH readers (the lifetime bookkeeping the manager
    inherited from the reduction scratches)."""
    tdag = TaskGraph()
    A = VirtualBuffer((N,), name="A", initial_value=np.zeros(N))
    O1 = VirtualBuffer((N,), name="O1")
    O2 = VirtualBuffer((N,), name="O2")
    C = VirtualBuffer((2 * N,), name="C")
    tdag.submit("r1", (N,), [read(A, one_to_one()), write(O1, one_to_one())])
    tdag.submit("r2", (N,), [read(A, one_to_one()), write(O2, one_to_one())])
    tdag.submit("wC", (2 * N,), [write(C, one_to_one())])
    # budget fits A+O1+O2; C needs two evictions — LRU reaches O1 then A
    # (A was re-touched by r2, so it outlives O1 but not O2)
    idag = IdagGenerator(0, 1, budgets={device_memory(0): 3 * BYTES})
    _compile(tdag, idag)
    readers = [i for i in idag.instructions
               if i.itype == InstructionType.DEVICE_KERNEL
               and i.name in ("r1", "r2")]
    a_alloc = readers[0].bindings[0].allocation
    victim_frees = [i for i in idag.instructions
                    if i.itype == InstructionType.FREE
                    and i.allocation is a_alloc]
    assert victim_frees, "A's allocation was not evicted"
    deps = {d.iid for d, k in victim_frees[0].dependencies
            if k == DepKind.ANTI}
    for r in readers:
        assert r.iid in deps, f"FREE not ordered after reader {r.name}"


def test_lookahead_reservation_protects_from_eviction():
    """Under pressure the eviction policy prefers victims outside the
    lookahead reservations; reserved allocations only fall when nothing
    else is left."""
    tdag = TaskGraph()
    A = VirtualBuffer((N,), name="A")
    B = VirtualBuffer((N,), name="B")
    C = VirtualBuffer((N,), name="C")
    tdag.submit("wA", (N,), [write(A, one_to_one())])   # A is LRU-oldest
    tdag.submit("wB", (N,), [write(B, one_to_one())])
    tdag.submit("wC", (N,), [write(C, one_to_one())])   # forces one eviction
    idag = IdagGenerator(0, 1, budgets={device_memory(0): 2 * BYTES})
    gen = generate_cdag(tdag, 1)
    cmds = [c for c in gen.commands[0]
            if not (c.ctype == CommandType.EPOCH and c.task is None)]
    for cmd in cmds:
        if cmd.task is not None and cmd.task.name == "wC":
            # the lookahead window announced A is about to be accessed
            idag.mem.reserve({(A.bid, device_memory(0)): A.full_region})
        idag.compile(cmd)
    freed_bids = {i.allocation.bid for i in idag.instructions
                  if i.itype == InstructionType.FREE}
    assert B.bid in freed_bids        # LRU alone would have picked A
    assert A.bid not in freed_bids

    # fallback: reserve EVERYTHING and force more pressure — eviction still
    # proceeds (cooperate, but never wedge)
    tdag2 = TaskGraph()
    D = VirtualBuffer((N,), name="D")
    E = VirtualBuffer((N,), name="E")
    F = VirtualBuffer((N,), name="F")
    tdag2.submit("wD", (N,), [write(D, one_to_one())])
    tdag2.submit("wE", (N,), [write(E, one_to_one())])
    tdag2.submit("wF", (N,), [write(F, one_to_one())])
    idag2 = IdagGenerator(0, 1, budgets={device_memory(0): 2 * BYTES})
    gen2 = generate_cdag(tdag2, 1)
    cmds2 = [c for c in gen2.commands[0]
             if not (c.ctype == CommandType.EPOCH and c.task is None)]
    for cmd in cmds2:
        if cmd.task is not None and cmd.task.name == "wF":
            idag2.mem.reserve({
                (D.bid, device_memory(0)): D.full_region,
                (E.bid, device_memory(0)): E.full_region,
            })
        idag2.compile(cmd)
    assert any(i.itype == InstructionType.FREE for i in idag2.instructions)
    assert idag2.mem.stats.evictions >= 1
    assert idag2.mem.stats.over_budget == 0


def test_writeback_elision_clean_victim():
    """A victim whose regions are all coherent elsewhere (reloaded but never
    re-written) is dropped WITHOUT a device->host SPILL copy, the elision is
    counted, and the eviction policy prefers such clean victims over dirty
    ones regardless of LRU order."""
    tdag = TaskGraph()
    A = VirtualBuffer((N,), name="A")
    B = VirtualBuffer((N,), name="B")
    C = VirtualBuffer((N,), name="C")
    D = VirtualBuffer((N,), name="D")
    tdag.submit("wA", (N,), [write(A, one_to_one())])
    tdag.submit("wB", (N,), [write(B, one_to_one())])      # A+B fill budget
    tdag.submit("wC", (N,), [write(C, one_to_one())])      # evicts A (dirty)
    # reads A back (reload): A is now coherent on device AND host => clean
    tdag.submit("rA", (N,), [read(A, one_to_one())])       # evicts B (dirty)
    # pressure again: the clean A must fall before the dirty, LRU-older C —
    # and its eviction needs NO spill copy (the host replica is current)
    tdag.submit("wD", (N,), [write(D, one_to_one())])
    idag = IdagGenerator(0, 1, budgets={device_memory(0): 2 * BYTES})
    _compile(tdag, idag)
    stats = idag.mem.stats
    spills = [i for i in idag.instructions if i.itype == InstructionType.SPILL]
    # exactly the two dirty evictions (A for C, B for A's reload) spilled;
    # the clean re-eviction of A emitted NO spill copy
    assert len(spills) == 2, spills
    assert stats.evictions == 3
    assert stats.writeback_elisions == 1
    assert stats.elided_bytes == BYTES
    reloads = [i for i in idag.instructions
               if i.itype == InstructionType.RELOAD]
    assert len(reloads) == 1
    # the clean A was chosen over the dirty C (which LRU alone would evict)
    freed_bids = [i.allocation.bid for i in idag.instructions
                  if i.itype == InstructionType.FREE
                  and i.allocation.mid == device_memory(0)]
    assert freed_bids == [A.bid, B.bid, A.bid]
    assert C.bid not in freed_bids


def test_writeback_elision_in_memory_report():
    """The elision counters surface through ``Runtime.memory_report()``."""
    with Runtime(1, 1) as q:
        _phased_program(q)
        rep = q.memory_report()[0]
    assert "writeback_elisions" in rep and "elided_bytes" in rep
    assert "prefetched_reloads" in rep


def test_prefetch_reload_overlaps_execution():
    """Spill-aware lookahead: the resumed phase's RELOADs are issued at the
    window flush, ahead of first use, and execute while the previous
    phase's kernels are still running (Tracer.overlap_fraction on the
    reload spans vs the kernel spans > 0)."""
    import time as _time

    def program(q, slow):
        bufs = [q.buffer((N,), init=np.zeros(N), name=f"B{g}")
                for g in range(3)]

        def steps(g, lo, hi, sleep=0.0):
            B = bufs[g]
            for s in range(lo, hi):
                def k(chunk, bv, s=s, sleep=sleep):
                    if sleep:
                        _time.sleep(sleep)
                    bv.set(chunk, bv.get(chunk) * 0.5 + (s + 1))
                q.submit(f"g{g}s{s}", (N,),
                         [read_write(B, one_to_one())], k)

        # phases long enough to reach allocation steady state (two horizons
        # without a new alloc), so every phase is its OWN lookahead window:
        # phase 0 pauses, is spilled while 1/2 compile (all buffers dirty),
        # and its resume window prefetches the reload while the slow phase
        # 2 is still executing — phase 1's bytes free without waiting on 2
        steps(0, 0, 6)
        steps(1, 0, 12)
        steps(2, 0, 12, sleep=slow)
        steps(0, 6, 12)
        return [q.gather(B) for B in bufs]

    with Runtime(1, 1) as q:
        base = program(q, slow=0.0)
        hwm = _device_peak(q.memory_report()[0])

    # budget = two of the three phase working sets: the resumed phase can
    # materialize by evicting the DONE phase 1, never the running phase 2
    with Runtime(1, 1, device_memory_budget=(2 * hwm) // 3, trace=True) as q:
        out = program(q, slow=0.02)
        rep = q.memory_report()[0]
        tracer = q.tracer
    for a, b in zip(base, out):
        np.testing.assert_array_equal(a, b)
    assert rep["reloads"] > 0
    assert rep["prefetched_reloads"] > 0, rep
    f = tracer.overlap_fraction("N0.device", "N0.device",
                                kind_a="reload", kind_b="device_kernel")
    assert f > 0.0, f"prefetched reloads did not overlap kernels: {f}"


def test_unbudgeted_stream_has_no_spill_instructions():
    """With no budget the memory layer is inert: the instruction stream
    contains no SPILL/RELOAD and allocations only ever grow (the historical
    §3.2 behavior)."""
    tdag = TaskGraph()
    A = VirtualBuffer((N,), name="A")
    B = VirtualBuffer((N,), name="B")
    tdag.submit("wA", (N,), [write(A, one_to_one())])
    tdag.submit("wB", (N,), [write(B, one_to_one())])
    tdag.submit("rA", (N,), [read_write(A, one_to_one())])
    idag = IdagGenerator(0, 1)
    _compile(tdag, idag)
    types = {i.itype for i in idag.instructions}
    assert InstructionType.SPILL not in types
    assert InstructionType.RELOAD not in types
    assert idag.mem.stats.evictions == 0
