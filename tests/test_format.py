"""Format gate for ``src/repro/core/`` — container-side mirror of the CI
``ruff check --select E101,E501,W191,W291,W292,W293`` step.

The development container has no ruff (and no network to install it), so
the same enumerable whitespace/line-length rules are enforced here in pure
Python: a formatting regression fails tier-1 locally with the same rule
names CI would report.
"""

from pathlib import Path

CORE = Path(__file__).resolve().parents[1] / "src" / "repro" / "core"
MAX_LINE = 100        # [tool.ruff] line-length in pyproject.toml


def _violations() -> list[str]:
    out: list[str] = []
    for path in sorted(CORE.glob("*.py")):
        text = path.read_text()
        if text and not text.endswith("\n"):
            out.append(f"{path.name}: W292 no newline at end of file")
        for no, line in enumerate(text.splitlines(), 1):
            indent = line[:len(line) - len(line.lstrip())]
            if "\t" in indent:        # W191/E101 flag indentation tabs only
                out.append(f"{path.name}:{no}: E101/W191 tab in indentation")
            if line != line.rstrip():
                rule = "W293" if not line.strip() else "W291"
                out.append(f"{path.name}:{no}: {rule} trailing whitespace")
            if len(line) > MAX_LINE and "# noqa" not in line:
                out.append(f"{path.name}:{no}: E501 line too long "
                           f"({len(line)} > {MAX_LINE})")
    return out


def test_core_tree_is_format_clean():
    v = _violations()
    assert not v, "format violations in src/repro/core/:\n" + "\n".join(v)
