"""Receive-arbitration unit tests — paper §3.4's three inbound geometries.

An await-push only knows the UNION of regions that will arrive; the sender
geometry becomes known at execution time via pilots/payloads.  The arbiter
must complete a split-receive's await-receive children:

  a) senders transmit exactly the consumer-split geometry (ideal overlap);
  b) a single sender satisfies the whole region at once;
  c) senders transmit a geometry ORTHOGONAL to the consumer split.
"""

import numpy as np

from repro.core import Box, Region
from repro.core.allocation import Allocation, PINNED_HOST
from repro.core.communicator import Communicator, Payload, ReceiveArbiter
from repro.core.instruction_graph import Instruction, InstructionType


def make_split_receive(alloc, tid, union_box, consumer_boxes):
    split = Instruction(InstructionType.SPLIT_RECEIVE, node=0,
                        transfer_id=tid,
                        recv_region=Region.from_box(union_box),
                        recv_alloc=alloc)
    awaits = []
    for cb in consumer_boxes:
        aw = Instruction(InstructionType.AWAIT_RECEIVE, node=0,
                         transfer_id=tid, recv_region=Region.from_box(cb),
                         recv_alloc=alloc, split_parent=split)
        awaits.append(aw)
    return split, awaits


def setup(union_box):
    comm = Communicator(2)
    store = {}
    alloc = Allocation(mid=PINNED_HOST, bid=0, box=union_box)
    store[alloc.aid] = np.full(union_box.shape, -1.0)
    arb = ReceiveArbiter(0, comm, store)
    return comm, store, alloc, arb


def drain(arb):
    done = []
    arb.step(done)
    return done


def test_case_a_matching_geometry():
    """Two senders transmit exactly the two consumer halves; each await
    completes as soon as ITS half lands (early compute start)."""
    union = Box((0,), (8,))
    comm, store, alloc, arb = setup(union)
    tid = (1, 0)
    split, (aw0, aw1) = make_split_receive(
        alloc, tid, union, [Box((0,), (4,)), Box((4,), (8,))])
    for i in (split, aw0, aw1):
        i.state = "issued"
        arb.begin(i)
    # first half lands -> only aw0 completes
    comm.isend(0, Payload(1, 0, tid, Box((0,), (4,)), np.arange(4.0)))
    done = drain(arb)
    assert aw0 in done and aw1 not in done
    np.testing.assert_array_equal(store[alloc.aid][:4], np.arange(4.0))
    # second half -> split + aw1 complete
    comm.isend(0, Payload(1, 1, tid, Box((4,), (8,)), np.arange(4.0) + 10))
    done = drain(arb)
    assert aw1 in done and split in done


def test_case_b_single_sender_whole_region():
    """One payload covers the union: all awaits complete together."""
    union = Box((0,), (8,))
    comm, store, alloc, arb = setup(union)
    tid = (2, 0)
    split, (aw0, aw1) = make_split_receive(
        alloc, tid, union, [Box((0,), (4,)), Box((4,), (8,))])
    for i in (split, aw0, aw1):
        i.state = "issued"
        arb.begin(i)
    comm.isend(0, Payload(1, 0, tid, union, np.arange(8.0)))
    done = drain(arb)
    assert {aw0, aw1, split} <= set(done)
    np.testing.assert_array_equal(store[alloc.aid], np.arange(8.0))


def test_case_c_orthogonal_geometry():
    """2-D: consumers split by rows, senders split by columns.  Each await
    completes only once BOTH column payloads covering its rows landed."""
    union = Box((0, 0), (4, 4))
    comm, store, alloc, arb = setup(union)
    tid = (3, 0)
    split, (aw_top, aw_bot) = make_split_receive(
        alloc, tid, union, [Box((0, 0), (2, 4)), Box((2, 0), (4, 4))])
    for i in (split, aw_top, aw_bot):
        i.state = "issued"
        arb.begin(i)
    # left column block arrives: covers rows 0..4 x cols 0..2 — neither
    # row-consumer is fully covered yet
    left = np.ones((4, 2))
    comm.isend(0, Payload(1, 0, tid, Box((0, 0), (4, 2)), left))
    done = drain(arb)
    assert aw_top not in done and aw_bot not in done
    # right column block arrives: both awaits now covered
    right = np.full((4, 2), 2.0)
    comm.isend(0, Payload(1, 1, tid, Box((0, 2), (4, 4)), right))
    done = drain(arb)
    assert aw_top in done and aw_bot in done and split in done
    np.testing.assert_array_equal(store[alloc.aid][:, :2], left)
    np.testing.assert_array_equal(store[alloc.aid][:, 2:], right)


def test_payload_before_receive_posted():
    """Eager senders: the payload arrives BEFORE the receive instruction is
    issued (buffered as 'early', landed on begin)."""
    union = Box((0,), (4,))
    comm, store, alloc, arb = setup(union)
    tid = (4, 0)
    comm.isend(0, Payload(1, 0, tid, union, np.arange(4.0)))
    drain(arb)                       # nothing pending yet
    recv = Instruction(InstructionType.RECEIVE, node=0, transfer_id=tid,
                       recv_region=Region.from_box(union), recv_alloc=alloc)
    recv.state = "issued"
    arb.begin(recv)
    done = drain(arb)
    assert recv in done
    np.testing.assert_array_equal(store[alloc.aid], np.arange(4.0))


def test_interleaved_transfers_do_not_cross():
    """Two concurrent transfer ids never land into each other's buffers."""
    union = Box((0,), (4,))
    comm = Communicator(2)
    store = {}
    a1 = Allocation(mid=PINNED_HOST, bid=0, box=union)
    a2 = Allocation(mid=PINNED_HOST, bid=1, box=union)
    store[a1.aid] = np.zeros(4)
    store[a2.aid] = np.zeros(4)
    arb = ReceiveArbiter(0, comm, store)
    r1 = Instruction(InstructionType.RECEIVE, node=0, transfer_id=(5, 0),
                     recv_region=Region.from_box(union), recv_alloc=a1)
    r2 = Instruction(InstructionType.RECEIVE, node=0, transfer_id=(6, 1),
                     recv_region=Region.from_box(union), recv_alloc=a2)
    for r in (r1, r2):
        r.state = "issued"
        arb.begin(r)
    comm.isend(0, Payload(1, 0, (6, 1), union, np.full(4, 2.0)))
    comm.isend(0, Payload(1, 1, (5, 0), union, np.full(4, 1.0)))
    done = []
    arb.step(done)
    assert {r1, r2} == set(done)
    np.testing.assert_array_equal(store[a1.aid], np.full(4, 1.0))
    np.testing.assert_array_equal(store[a2.aid], np.full(4, 2.0))
