"""Receive-arbitration unit tests — paper §3.4's three inbound geometries.

An await-push only knows the UNION of regions that will arrive; the sender
geometry becomes known at execution time via pilots/payloads.  The arbiter
must complete a split-receive's await-receive children:

  a) senders transmit exactly the consumer-split geometry (ideal overlap);
  b) a single sender satisfies the whole region at once;
  c) senders transmit a geometry ORTHOGONAL to the consumer split.
"""

import numpy as np

from repro.core import Box, Region
from repro.core.allocation import Allocation, PINNED_HOST
from repro.core.communicator import Communicator, Payload, ReceiveArbiter
from repro.core.instruction_graph import Instruction, InstructionType, Pilot


def make_split_receive(alloc, tid, union_box, consumer_boxes):
    split = Instruction(InstructionType.SPLIT_RECEIVE, node=0,
                        transfer_id=tid,
                        recv_region=Region.from_box(union_box),
                        recv_alloc=alloc)
    awaits = []
    for cb in consumer_boxes:
        aw = Instruction(InstructionType.AWAIT_RECEIVE, node=0,
                         transfer_id=tid, recv_region=Region.from_box(cb),
                         recv_alloc=alloc, split_parent=split)
        awaits.append(aw)
    return split, awaits


def setup(union_box):
    comm = Communicator(2)
    store = {}
    alloc = Allocation(mid=PINNED_HOST, bid=0, box=union_box)
    store[alloc.aid] = np.full(union_box.shape, -1.0)
    arb = ReceiveArbiter(0, comm, store)
    return comm, store, alloc, arb


def drain(arb):
    done = []
    arb.step(done)
    return done


def test_case_a_matching_geometry():
    """Two senders transmit exactly the two consumer halves; each await
    completes as soon as ITS half lands (early compute start)."""
    union = Box((0,), (8,))
    comm, store, alloc, arb = setup(union)
    tid = (1, 0)
    split, (aw0, aw1) = make_split_receive(
        alloc, tid, union, [Box((0,), (4,)), Box((4,), (8,))])
    for i in (split, aw0, aw1):
        i.state = "issued"
        arb.begin(i)
    # first half lands -> only aw0 completes
    comm.isend(0, Payload(1, 0, tid, Box((0,), (4,)), np.arange(4.0)))
    done = drain(arb)
    assert aw0 in done and aw1 not in done
    np.testing.assert_array_equal(store[alloc.aid][:4], np.arange(4.0))
    # second half -> split + aw1 complete
    comm.isend(0, Payload(1, 1, tid, Box((4,), (8,)), np.arange(4.0) + 10))
    done = drain(arb)
    assert aw1 in done and split in done


def test_case_b_single_sender_whole_region():
    """One payload covers the union: all awaits complete together."""
    union = Box((0,), (8,))
    comm, store, alloc, arb = setup(union)
    tid = (2, 0)
    split, (aw0, aw1) = make_split_receive(
        alloc, tid, union, [Box((0,), (4,)), Box((4,), (8,))])
    for i in (split, aw0, aw1):
        i.state = "issued"
        arb.begin(i)
    comm.isend(0, Payload(1, 0, tid, union, np.arange(8.0)))
    done = drain(arb)
    assert {aw0, aw1, split} <= set(done)
    np.testing.assert_array_equal(store[alloc.aid], np.arange(8.0))


def test_case_c_orthogonal_geometry():
    """2-D: consumers split by rows, senders split by columns.  Each await
    completes only once BOTH column payloads covering its rows landed."""
    union = Box((0, 0), (4, 4))
    comm, store, alloc, arb = setup(union)
    tid = (3, 0)
    split, (aw_top, aw_bot) = make_split_receive(
        alloc, tid, union, [Box((0, 0), (2, 4)), Box((2, 0), (4, 4))])
    for i in (split, aw_top, aw_bot):
        i.state = "issued"
        arb.begin(i)
    # left column block arrives: covers rows 0..4 x cols 0..2 — neither
    # row-consumer is fully covered yet
    left = np.ones((4, 2))
    comm.isend(0, Payload(1, 0, tid, Box((0, 0), (4, 2)), left))
    done = drain(arb)
    assert aw_top not in done and aw_bot not in done
    # right column block arrives: both awaits now covered
    right = np.full((4, 2), 2.0)
    comm.isend(0, Payload(1, 1, tid, Box((0, 2), (4, 4)), right))
    done = drain(arb)
    assert aw_top in done and aw_bot in done and split in done
    np.testing.assert_array_equal(store[alloc.aid][:, :2], left)
    np.testing.assert_array_equal(store[alloc.aid][:, 2:], right)


def test_payload_before_receive_posted():
    """Eager senders: the payload arrives BEFORE the receive instruction is
    issued (buffered as 'early', landed on begin)."""
    union = Box((0,), (4,))
    comm, store, alloc, arb = setup(union)
    tid = (4, 0)
    comm.isend(0, Payload(1, 0, tid, union, np.arange(4.0)))
    drain(arb)                       # nothing pending yet
    recv = Instruction(InstructionType.RECEIVE, node=0, transfer_id=tid,
                       recv_region=Region.from_box(union), recv_alloc=alloc)
    recv.state = "issued"
    arb.begin(recv)
    done = drain(arb)
    assert recv in done
    np.testing.assert_array_equal(store[alloc.aid], np.arange(4.0))


def test_multi_fragment_with_pilots_after_split():
    """Pilots and payloads arrive AFTER the receive was already split into
    await-receives, in multiple fragments per consumer half; each await
    completes exactly when its half is fully covered."""
    union = Box((0,), (8,))
    comm, store, alloc, arb = setup(union)
    tid = (7, 0)
    split, (aw0, aw1) = make_split_receive(
        alloc, tid, union, [Box((0,), (4,)), Box((4,), (8,))])
    for i in (split, aw0, aw1):
        i.state = "issued"
        arb.begin(i)
    assert drain(arb) == []                   # nothing in flight yet
    # pilots announce four fragments only AFTER the split was posted
    frags = [Box((0,), (2,)), Box((2,), (4,)), Box((4,), (6,)), Box((6,), (8,))]
    for m, b in enumerate(frags):
        comm.post_pilot(Pilot(source=1, target=0, transfer_id=tid, box=b,
                              msg_id=m))
    assert drain(arb) == []                   # pilots alone complete nothing
    # fragments land out of order; aw1 completes before aw0
    comm.isend(0, Payload(1, 2, tid, frags[2], np.full(2, 3.0)))
    comm.isend(0, Payload(1, 3, tid, frags[3], np.full(2, 4.0)))
    done = drain(arb)
    assert aw1 in done and aw0 not in done and split not in done
    comm.isend(0, Payload(1, 0, tid, frags[0], np.full(2, 1.0)))
    done = drain(arb)
    assert done == []                         # half of aw0 still missing
    comm.isend(0, Payload(1, 1, tid, frags[1], np.full(2, 2.0)))
    done = drain(arb)
    assert aw0 in done and split in done
    np.testing.assert_array_equal(store[alloc.aid],
                                  np.repeat([1.0, 2.0, 3.0, 4.0], 2))
    # once the executor marks the split done, the arbiter drops the entry
    split.state = "done"
    drain(arb)
    assert not arb.has_pending()


def make_gather(alloc, tid, box, sources):
    g = Instruction(InstructionType.GATHER_RECEIVE, node=0, transfer_id=tid,
                    recv_region=Region.from_box(box), recv_alloc=alloc,
                    gather_sources=tuple(sources))
    g.state = "issued"
    return g


def test_gather_receive_lands_by_source_slot():
    """Reduction partials from several peers land at slot=source rank of the
    fixed-stride gather staging, regardless of arrival order."""
    comm = Communicator(4)
    store = {}
    # slots for ranks 0..3, one partial element each
    galloc = Allocation(mid=PINNED_HOST, bid=None, box=Box((0, 0), (4, 1)))
    store[galloc.aid] = np.full((4, 1), -1.0)
    arb = ReceiveArbiter(0, comm, store)
    tid = (9, 0, 1)
    g = make_gather(galloc, tid, Box((0,), (1,)), sources=[1, 2, 3])
    arb.begin(g)
    assert arb.has_pending()
    # peers arrive out of order; completion only after ALL landed
    comm.isend(0, Payload(3, 0, tid, Box((0,), (1,)), np.array([30.0])))
    comm.isend(0, Payload(1, 1, tid, Box((0,), (1,)), np.array([10.0])))
    done = drain(arb)
    assert g not in done
    comm.isend(0, Payload(2, 2, tid, Box((0,), (1,)), np.array([20.0])))
    done = drain(arb)
    assert g in done
    np.testing.assert_array_equal(store[galloc.aid],
                                  [[-1.0], [10.0], [20.0], [30.0]])
    assert not arb.has_pending()


def test_gather_payload_before_receive_posted():
    """An eager peer's partial arrives before GATHER_RECEIVE is issued; it is
    buffered as early and landed when the gather begins."""
    comm = Communicator(2)
    store = {}
    galloc = Allocation(mid=PINNED_HOST, bid=None, box=Box((0, 0), (2, 1)))
    store[galloc.aid] = np.zeros((2, 1))
    arb = ReceiveArbiter(0, comm, store)
    tid = (10, 0, 1)
    comm.isend(0, Payload(1, 0, tid, Box((0,), (1,)), np.array([5.5])))
    drain(arb)                                # buffered, nothing pending
    g = make_gather(galloc, tid, Box((0,), (1,)), sources=[1])
    arb.begin(g)
    done = drain(arb)
    assert g in done
    assert store[galloc.aid][1, 0] == 5.5


def test_gather_and_push_traffic_do_not_cross():
    """A push payload with the 2-tuple transfer id never lands in a gather
    slot with the 3-tuple reduction id of the same (task, buffer)."""
    comm = Communicator(2)
    store = {}
    box = Box((0,), (1,))
    galloc = Allocation(mid=PINNED_HOST, bid=None, box=Box((0, 0), (2, 1)))
    palloc = Allocation(mid=PINNED_HOST, bid=0, box=box)
    store[galloc.aid] = np.zeros((2, 1))
    store[palloc.aid] = np.zeros(1)
    arb = ReceiveArbiter(0, comm, store)
    g = make_gather(galloc, (11, 0, 1), box, sources=[1])
    recv = Instruction(InstructionType.RECEIVE, node=0, transfer_id=(11, 0),
                       recv_region=Region.from_box(box), recv_alloc=palloc)
    recv.state = "issued"
    arb.begin(g)
    arb.begin(recv)
    comm.isend(0, Payload(1, 0, (11, 0), box, np.array([1.0])))
    comm.isend(0, Payload(1, 1, (11, 0, 1), box, np.array([2.0])))
    done = drain(arb)
    assert {g, recv} == set(done)
    np.testing.assert_array_equal(store[palloc.aid], [1.0])
    np.testing.assert_array_equal(store[galloc.aid], [[0.0], [2.0]])


def _redeliver(comm, target, payload):
    """Simulate a retransmit race: the sender re-delivers an already-landed
    sequenced copy (same seq) just before the ack reached it."""
    with comm._cv:
        comm.payload_box[target].append(payload)
        comm._cv.notify_all()


def test_duplicate_push_payload_lands_exactly_once():
    """A duplicated sequenced payload is acked twice but landed once —
    re-landing would re-copy stale bytes over a region a later writer may
    already own."""
    union = Box((0,), (4,))
    comm, store, alloc, arb = setup(union)
    tid = (20, 0)
    recv = Instruction(InstructionType.RECEIVE, node=0, transfer_id=tid,
                       recv_region=Region.from_box(union), recv_alloc=alloc)
    recv.state = "issued"
    arb.begin(recv)
    p = Payload(1, 0, tid, union, np.arange(4.0))
    comm.isend(0, p)
    _redeliver(comm, 0, p)
    done = drain(arb)
    assert recv in done
    assert arb.dups_suppressed == 1
    assert comm.acks == 2                    # every delivered copy is acked
    np.testing.assert_array_equal(store[alloc.aid], np.arange(4.0))
    # overwrite the landed region, then a THIRD copy straggles in: suppressed
    store[alloc.aid][:] = 99.0
    _redeliver(comm, 0, p)
    drain(arb)
    assert arb.dups_suppressed == 2
    np.testing.assert_array_equal(store[alloc.aid], np.full(4, 99.0))


def test_duplicate_coll_fragment_after_scratch_freed():
    """A retransmitted collective fragment arrives AFTER the one-shot scratch
    allocation was freed: duplicate suppression must reject it before any
    landing logic touches the (gone) allocation."""
    from repro.core.instruction_graph import CollFragment
    comm = Communicator(2)
    store = {}
    scr = Allocation(mid=PINNED_HOST, bid=None, box=Box((0,), (4,)))
    store[scr.aid] = np.full(4, -1.0)
    arb = ReceiveArbiter(0, comm, store)
    tid = (21, 0, 3, 1)
    rc = Instruction(InstructionType.COLL_RECV, node=0, transfer_id=tid,
                     coll_source=1, coll_allocs=(scr,),
                     coll_expect=((0, 0, 4),),
                     coll_land=(CollFragment(key=(0, 0, 4), alloc=scr,
                                             srange=(0, 4)),))
    rc.state = "issued"
    arb.begin(rc)
    p = Payload(source=1, msg_id=0, transfer_id=tid,
                fragments=[((0, 0, 4), np.arange(4.0))])
    comm.isend(0, p)
    done = drain(arb)
    assert rc in done
    del store[scr.aid]                       # executor frees the scratch
    _redeliver(comm, 0, p)
    drain(arb)                               # must not KeyError into store
    assert arb.dups_suppressed == 1
    assert comm.acks == 2


def test_pilot_arriving_after_payload_is_harmless():
    """Eager wires can reorder pilot behind payload; the late pilot only
    feeds stall attribution and never disturbs the landed transfer."""
    union = Box((0,), (4,))
    comm, store, alloc, arb = setup(union)
    tid = (22, 0)
    recv = Instruction(InstructionType.RECEIVE, node=0, transfer_id=tid,
                       recv_region=Region.from_box(union), recv_alloc=alloc)
    recv.state = "issued"
    arb.begin(recv)
    comm.isend(0, Payload(1, 0, tid, union, np.arange(4.0)))
    done = drain(arb)
    assert recv in done
    comm.post_pilot(Pilot(source=1, target=0, transfer_id=tid, box=union,
                          msg_id=0))
    assert drain(arb) == []
    np.testing.assert_array_equal(store[alloc.aid], np.arange(4.0))
    # the completed transfer's announcement is garbage-collected with it, so
    # late pilots leave no residual arbiter state behind
    assert not arb.has_pending()
    assert not arb.announced.get(tid)


def test_stale_tid_traffic_from_aborted_epoch_rejected():
    """After ``poison`` (an EPOCH_ABORT), late pilots and payloads for the
    tombstoned transfer are counted and dropped — their allocations belong
    to the dead epoch."""
    union = Box((0,), (4,))
    comm, store, alloc, arb = setup(union)
    tid = (23, 0)
    recv = Instruction(InstructionType.RECEIVE, node=0, transfer_id=tid,
                       recv_region=Region.from_box(union), recv_alloc=alloc)
    recv.state = "issued"
    arb.begin(recv)
    assert arb.poison("epoch aborted by peer") == 1
    comm.post_pilot(Pilot(source=1, target=0, transfer_id=tid, box=union,
                          msg_id=0))
    comm.isend(0, Payload(1, 0, tid, union, np.arange(4.0)))
    assert drain(arb) == []
    assert arb.stale_rejected == 1
    assert tid not in arb.announced          # stale pilots not recorded
    assert not arb.has_pending()
    np.testing.assert_array_equal(store[alloc.aid], np.full(4, -1.0))
    assert comm.acks == 1                    # transport-level delivery stands


def test_wrong_source_coll_fragment_never_lands():
    """A packed round message from a rank that is NOT the schedule's source
    for this COLL_RECV must not land or complete it (collective rounds are
    source-addressed, unlike push traffic)."""
    from repro.core.instruction_graph import CollFragment
    comm = Communicator(3)
    store = {}
    scr = Allocation(mid=PINNED_HOST, bid=None, box=Box((0,), (4,)))
    store[scr.aid] = np.full(4, -1.0)
    arb = ReceiveArbiter(0, comm, store)
    tid = (24, 0, 3, 1)
    rc = Instruction(InstructionType.COLL_RECV, node=0, transfer_id=tid,
                     coll_source=1, coll_allocs=(scr,),
                     coll_expect=((0, 0, 4),),
                     coll_land=(CollFragment(key=(0, 0, 4), alloc=scr,
                                             srange=(0, 4)),))
    rc.state = "issued"
    arb.begin(rc)
    comm.isend(0, Payload(source=2, msg_id=0, transfer_id=tid,
                          fragments=[((0, 0, 4), np.full(4, 66.0))]))
    assert drain(arb) == []
    np.testing.assert_array_equal(store[scr.aid], np.full(4, -1.0))
    # the true source arrives: lands and completes
    comm.isend(0, Payload(source=1, msg_id=0, transfer_id=tid,
                          fragments=[((0, 0, 4), np.arange(4.0))]))
    done = drain(arb)
    assert rc in done
    np.testing.assert_array_equal(store[scr.aid], np.arange(4.0))


def test_interleaved_transfers_do_not_cross():
    """Two concurrent transfer ids never land into each other's buffers."""
    union = Box((0,), (4,))
    comm = Communicator(2)
    store = {}
    a1 = Allocation(mid=PINNED_HOST, bid=0, box=union)
    a2 = Allocation(mid=PINNED_HOST, bid=1, box=union)
    store[a1.aid] = np.zeros(4)
    store[a2.aid] = np.zeros(4)
    arb = ReceiveArbiter(0, comm, store)
    r1 = Instruction(InstructionType.RECEIVE, node=0, transfer_id=(5, 0),
                     recv_region=Region.from_box(union), recv_alloc=a1)
    r2 = Instruction(InstructionType.RECEIVE, node=0, transfer_id=(6, 1),
                     recv_region=Region.from_box(union), recv_alloc=a2)
    for r in (r1, r2):
        r.state = "issued"
        arb.begin(r)
    comm.isend(0, Payload(1, 0, (6, 1), union, np.full(4, 2.0)))
    comm.isend(0, Payload(1, 1, (5, 0), union, np.full(4, 1.0)))
    done = []
    arb.step(done)
    assert {r1, r2} == set(done)
    np.testing.assert_array_equal(store[a1.aid], np.full(4, 1.0))
    np.testing.assert_array_equal(store[a2.aid], np.full(4, 2.0))
