"""Structural invariant tests for TDAG / CDAG / IDAG generation."""

import numpy as np
import pytest

from repro.core import (AccessMode, Box, CommandType, IdagGenerator,
                        InstructionType, Region, TaskGraph, TaskType,
                        all_range, fixed, generate_cdag, neighborhood,
                        one_to_one, read, read_write, write)
from repro.core.buffer import VirtualBuffer
from repro.core.instruction_graph import Instruction
from repro.core.lookahead import LookaheadScheduler
from repro.core.task_graph import DepKind


def nbody_tdag(n=64, steps=3):
    tdag = TaskGraph()
    P = VirtualBuffer((n, 3), name="P", initial_value=np.zeros((n, 3)))
    V = VirtualBuffer((n, 3), name="V", initial_value=np.zeros((n, 3)))
    for _ in range(steps):
        tdag.submit("timestep", (n, 3),
                    [read(P, all_range()), read_write(V, one_to_one())])
        tdag.submit("update", (n, 3),
                    [read(V, one_to_one()), read_write(P, one_to_one())])
    return tdag, P, V


# --------------------------------------------------------------------------
class TestTDAG:
    def test_linear_chain_nbody(self):
        """Paper fig. 2: all-read + 1:1 write yields a linear dep chain."""
        tdag, P, V = nbody_tdag()
        kts = tdag.kernel_tasks()
        for prev, nxt in zip(kts, kts[1:]):
            assert any(d is prev for d, _ in nxt.dependencies), \
                f"{nxt} should depend on {prev}"

    def test_dep_kinds(self):
        tdag = TaskGraph()
        B = VirtualBuffer((16,), name="B")
        t1 = tdag.submit("w", (16,), [write(B, one_to_one())])
        t2 = tdag.submit("r", (16,), [read(B, one_to_one())])
        t3 = tdag.submit("w2", (16,), [write(B, one_to_one())])
        assert (t1, DepKind.TRUE) in [(d, k) for d, k in t2.dependencies]
        kinds = {k for d, k in t3.dependencies if d is t2}
        assert DepKind.ANTI in kinds

    def test_disjoint_writes_no_dep(self):
        tdag = TaskGraph()
        B = VirtualBuffer((16,), name="B")
        t1 = tdag.submit("lo", (16,), [write(B, fixed(Box((0,), (8,))))])
        t2 = tdag.submit("hi", (16,), [write(B, fixed(Box((8,), (16,))))])
        assert all(d is not t1 for d, _ in t2.dependencies
                   if d.ttype == TaskType.KERNEL)

    def test_uninitialized_read_warning(self):
        tdag = TaskGraph()
        B = VirtualBuffer((8,), name="B")  # no initial value
        tdag.submit("r", (8,), [read(B, one_to_one())])
        assert any("uninitialized" in w for w in tdag.warnings)

    def test_horizon_emission_bounds_tracking(self):
        tdag = TaskGraph(horizon_step=4)
        B = VirtualBuffer((8,), name="B", initial_value=np.zeros(8))
        for i in range(20):
            tdag.submit(f"k{i}", (8,), [read_write(B, one_to_one())])
        horizons = [t for t in tdag.tasks if t.ttype == TaskType.HORIZON]
        assert len(horizons) >= 3
        # tracking structures bounded: last_writers should map to few entries
        st = tdag._buffers[B.bid]
        assert len(st.last_writers.entries) <= 4


# --------------------------------------------------------------------------
class TestCDAG:
    def test_push_await_pairing(self):
        tdag, P, V = nbody_tdag(n=64, steps=2)
        gen = generate_cdag(tdag, num_nodes=2)
        all_cmds = [c for cmds in gen.commands for c in cmds]
        pushes = [c for c in all_cmds if c.ctype == CommandType.PUSH]
        awaits = [c for c in all_cmds if c.ctype == CommandType.AWAIT_PUSH]
        assert pushes and awaits
        # every push region is covered by its peer's awaited region
        for p in pushes:
            match = [a for a in awaits if a.transfer_id == p.transfer_id
                     and a.node == p.target]
            assert match, f"push {p} has no matching await"
            assert match[0].region.contains(p.region)

    def test_push_knows_target_await_knows_union_only(self):
        tdag, P, V = nbody_tdag(n=64, steps=2)
        gen = generate_cdag(tdag, num_nodes=4)
        for cmds in gen.commands:
            for c in cmds:
                if c.ctype == CommandType.PUSH:
                    assert c.target is not None and c.region is not None
                if c.ctype == CommandType.AWAIT_PUSH:
                    assert c.target is None  # senders unknown (paper §3.4)

    def test_overlapping_write_detection(self):
        tdag = TaskGraph()
        B = VirtualBuffer((16,), name="B")
        tdag.submit("bad", (16,), [write(B, all_range())])  # every node writes all
        gen = generate_cdag(tdag, num_nodes=2)
        assert any("overlapping write" in e for e in gen.errors)

    def test_no_self_push(self):
        tdag, P, V = nbody_tdag()
        gen = generate_cdag(tdag, num_nodes=2)
        for cmds in gen.commands:
            for c in cmds:
                if c.ctype == CommandType.PUSH:
                    assert c.target != c.node


# --------------------------------------------------------------------------
def compile_idag(tdag, num_nodes, num_devices, node=0, lookahead=False):
    gen = generate_cdag(tdag, num_nodes)
    idag = IdagGenerator(node, num_devices)
    la = LookaheadScheduler(idag, enabled=lookahead)
    for cmd in gen.commands[node]:
        if cmd.ctype == CommandType.EPOCH and cmd.task is None:
            continue
        la.push(cmd)
    la.flush()
    return idag


class TestIDAG:
    def test_topological_emission_order(self):
        tdag, P, V = nbody_tdag()
        idag = compile_idag(tdag, 2, 2)
        pos = {i.iid: k for k, i in enumerate(idag.instructions)}
        for instr in idag.instructions:
            for dep, _ in instr.dependencies:
                assert pos[dep.iid] < pos[instr.iid], \
                    f"{instr} emitted before its dependency {dep}"

    def test_acyclic(self):
        tdag, P, V = nbody_tdag()
        idag = compile_idag(tdag, 2, 2)
        seen, done = set(), set()

        def visit(i):
            assert i.iid not in seen or i.iid in done, "cycle detected"
            if i.iid in done:
                return
            seen.add(i.iid)
            for d, _ in i.dependencies:
                visit(d)
            done.add(i.iid)

        for i in idag.instructions:
            visit(i)

    def test_backing_allocations_disjoint(self):
        """Paper §3.2: backing allocations per (buffer, memory) never overlap."""
        tdag = TaskGraph()
        B = VirtualBuffer((64,), name="B", initial_value=np.zeros(64))
        tdag.submit("a", (16,), [read_write(B, one_to_one())])
        tdag.submit("b", (64,), [read_write(B, one_to_one())])   # forces resize
        tdag.submit("c", (32,), [read_write(B, neighborhood((4,)))])
        idag = compile_idag(tdag, 1, 2)
        for (bid, mid), allocs in idag._allocs.items():
            live = [a for a in allocs if a.live]
            for i, a in enumerate(live):
                for b in live[i + 1:]:
                    assert not a.box.overlaps(b.box), \
                        f"live allocations overlap: {a} vs {b}"

    def test_accessor_contiguous_backing(self):
        """Every kernel accessor is backed by ONE allocation containing its region."""
        tdag, P, V = nbody_tdag()
        idag = compile_idag(tdag, 2, 2)
        for instr in idag.instructions:
            if instr.itype != InstructionType.DEVICE_KERNEL:
                continue
            for b in instr.bindings:
                assert b.allocation.box.contains(b.region.bounding_box())

    def test_device_kernels_per_device(self):
        """§3.1 hierarchical split: one kernel instr per local device."""
        tdag, P, V = nbody_tdag(steps=1)
        idag = compile_idag(tdag, 2, 4)
        per_task = {}
        for i in idag.instructions:
            if i.itype == InstructionType.DEVICE_KERNEL:
                per_task.setdefault(i.name, set()).add(i.device)
        assert per_task["timestep"] == {0, 1, 2, 3}

    def test_resize_chain_alloc_copy_free(self):
        """Fig. 3: growing access emits alloc -> copy(live) -> free(old)."""
        tdag = TaskGraph()
        B = VirtualBuffer((64,), name="B")
        tdag.submit("w", (32,), [write(B, one_to_one())])
        tdag.submit("r", (64,), [read_write(B, one_to_one())])
        idag = compile_idag(tdag, 1, 1)
        kinds = [i.itype for i in idag.instructions]
        assert kinds.count(InstructionType.ALLOC) >= 2
        assert InstructionType.FREE in kinds
        frees = [i for i in idag.instructions if i.itype == InstructionType.FREE]
        # the freed allocation's live data must have been copied out first
        copies = [i for i in idag.instructions if i.itype == InstructionType.COPY]
        assert any(c.src_alloc is frees[0].allocation for c in copies)

    def test_no_downsize(self):
        """§3.2: allocations never shrink."""
        tdag = TaskGraph()
        B = VirtualBuffer((64,), name="B")
        tdag.submit("big", (64,), [write(B, one_to_one())])
        tdag.submit("small", (8,), [read_write(B, one_to_one())])
        idag = compile_idag(tdag, 1, 1)
        allocs = [i for i in idag.instructions if i.itype == InstructionType.ALLOC]
        assert len(allocs) == 1  # the small access reuses the big allocation

    def test_producer_split_copies(self):
        """§3.3: one coherence copy per (producer, consumer) pairing."""
        tdag = TaskGraph()
        B = VirtualBuffer((64,), name="B")
        # two producers write halves on devices; then one consumer reads all
        tdag.submit("w", (64,), [write(B, one_to_one())])
        tdag.submit("r", (64,), [read(B, all_range()),
                                 write(VirtualBuffer((64,), name="O"), one_to_one())])
        idag = compile_idag(tdag, 1, 2)
        copies = [i for i in idag.instructions if i.itype == InstructionType.COPY]
        # D0 wrote [0,32), D1 wrote [32,64); making all of B coherent on both
        # devices needs one d2d copy per (producer half, consumer device)
        d2d = [c for c in copies if c.src_alloc.mid >= 2 and c.dst_alloc.mid >= 2
               and c.src_alloc.mid != c.dst_alloc.mid]
        assert len(d2d) == 2

    def test_send_has_pilot(self):
        tdag, P, V = nbody_tdag(steps=2)
        idag = compile_idag(tdag, 2, 1)
        sends = [i for i in idag.instructions if i.itype == InstructionType.SEND]
        assert sends
        pilot_ids = {p.msg_id for p in idag.pilots}
        for s in sends:
            assert s.msg_id in pilot_ids

    def test_split_receive_for_multiple_consumers(self):
        """§3.4: await-push consumed in parts by 2 devices -> split receive."""
        tdag = TaskGraph()
        B = VirtualBuffer((64,), name="B")
        tdag.submit("w", (64,), [write(B, one_to_one())])
        # second task reads one-to-one => each device consumes its own half
        # of the remote part => consumer split applies on the *remote* node
        tdag.submit("r2", (64,), [read(B, fixed(Box((0,), (64,)))),
                                  write(VirtualBuffer((64,), name="O2"), one_to_one())],
                    split_dims=(0,))
        # node 1's await-push of [0,32) is consumed by its two devices in parts
        idag = compile_idag(tdag, 2, 2, node=1)
        types = [i.itype for i in idag.instructions]
        assert (InstructionType.SPLIT_RECEIVE in types
                or InstructionType.RECEIVE in types)

    def test_horizon_prunes_producers(self):
        tdag = TaskGraph(horizon_step=2)
        B = VirtualBuffer((16,), name="B", initial_value=np.zeros(16))
        for i in range(12):
            tdag.submit(f"k{i}", (16,), [read_write(B, one_to_one())])
        idag = compile_idag(tdag, 1, 1)
        for ms in idag._mem.values():
            assert len(ms.producers.entries) <= 3


# --------------------------------------------------------------------------
class TestLookahead:
    def _growing_tdag(self, T=10, W=16):
        from repro.core import rows_upto
        tdag = TaskGraph()
        B = VirtualBuffer((T, W), name="R", initial_value=np.zeros((T, W)))
        for t in range(T):
            tdag.submit(
                f"rad{t}", (1, W),
                [read(B, fixed(Box((0, 0), (max(t, 1), W)))),
                 write(B, fixed(Box((t, 0), (t + 1, W))))])
        return tdag

    def test_resize_elision(self):
        tdag = self._growing_tdag()
        idag_on = compile_idag(tdag, 1, 1, lookahead=True)
        tdag2 = self._growing_tdag()
        idag_off = compile_idag(tdag2, 1, 1, lookahead=False)
        n_alloc_on = sum(1 for i in idag_on.instructions
                         if i.itype == InstructionType.ALLOC)
        n_alloc_off = sum(1 for i in idag_off.instructions
                          if i.itype == InstructionType.ALLOC)
        n_free_on = sum(1 for i in idag_on.instructions
                        if i.itype == InstructionType.FREE)
        assert n_alloc_on == 1 and n_free_on == 0
        assert n_alloc_off > 3  # resize storm without lookahead

    def test_steady_state_passthrough(self):
        """Stable access patterns must not be queued (no added latency)."""
        tdag = TaskGraph()
        B = VirtualBuffer((32,), name="B", initial_value=np.zeros(32))
        idag = IdagGenerator(0, 1)
        la = LookaheadScheduler(idag, enabled=True)
        gen = generate_cdag(tdag, 1)
        for i in range(20):
            tdag.submit(f"k{i}", (32,), [read_write(B, one_to_one())])
        n_immediate = 0
        for task in tdag.tasks:
            if task.name == "init":
                continue
            for cmd in gen.process(task):
                out = la.push(cmd)
                if out and not la.queue:
                    n_immediate += 1
        # after the first allocating window flushes, the rest pass through
        assert la.stats.flushes <= 2
        # after the allocation window flushes at the 2nd horizon, the
        # remaining steady-state commands pass straight through
        assert n_immediate >= 5
