"""Model correctness: SSD vs brute-force recurrence, cached decode vs full
forward, MoE routing invariants, per-family loss sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ArchConfig, build_model
from repro.models import layers as L
from repro.models.internvl import D_VIS
from repro.models.mamba2 import ssd_chunked

jax.config.update("jax_enable_x64", False)


# -- SSD algorithm vs O(S) recurrence oracle ---------------------------------
def ssd_recurrent_oracle(x, a, B, C):
    b, s, h, p = x.shape
    n = B.shape[-1]
    hstate = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    xn, an, Bn, Cn = map(lambda t: np.asarray(t, np.float64), (x, a, B, C))
    for t in range(s):
        hstate = (np.exp(an[:, t])[:, :, None, None] * hstate
                  + np.einsum("bhp,bn->bhpn", xn[:, t], Bn[:, t]))
        ys[:, t] = np.einsum("bhpn,bn->bhp", hstate, Cn[:, t])
    return ys, hstate


@pytest.mark.parametrize("s,chunk", [(8, 4), (32, 8), (64, 64), (48, 16)])
def test_ssd_chunked_matches_recurrence(s, chunk):
    key = jax.random.PRNGKey(0)
    b, h, p, n = 2, 3, 4, 5
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (b, s, h, p))
    a = -jax.nn.softplus(jax.random.normal(k2, (b, s, h)))  # log-decay < 0
    B = jax.random.normal(k3, (b, s, n))
    C = jax.random.normal(k4, (b, s, n))
    y, hlast = ssd_chunked(x, a, B, C, chunk)
    ye, he = ssd_recurrent_oracle(x, a, B, C)
    np.testing.assert_allclose(np.asarray(y), ye, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hlast), he, rtol=2e-4, atol=2e-4)


# -- configs for decode consistency ------------------------------------------
def tiny(family, **kw):
    base = dict(num_layers=30, d_model=256, num_heads=8, num_kv_heads=2,
                d_ff=512, vocab_size=512)
    cfg = ArchConfig(name=f"tiny-{family}", family=family, **base)
    from dataclasses import replace
    return replace(cfg.reduced(), **kw)


@pytest.mark.parametrize("family,kw", [
    ("dense", {}),
    ("dense", {"sliding_window": 8}),
    ("dense", {"qkv_bias": True}),
    # capacity_factor high enough that no token drops: capacity-based MoE
    # routing is only prefix-consistent when nothing is dropped
    ("moe", {"num_experts": 4, "top_k": 2, "capacity_factor": 16.0}),
    ("ssm", {"num_heads": 0, "num_kv_heads": 0, "d_ff": 0,
             "ssm_state": 16, "tie_embeddings": True}),
    ("hybrid", {"ssm_state": 16, "attn_every": 2, "num_layers": 4}),
])
def test_decode_matches_forward(family, kw):
    """prefill + N decode steps must reproduce teacher-forced logits."""
    cfg = tiny(family, **kw)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S, S0 = 1, 16, 8
    ids = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full_logits, _ = m.forward(params, ids)

    logits, cache = m.prefill(params, ids[:, :S0], max_len=S)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, S0 - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(S0, S):
        logits, cache = m.decode_step(params, cache, ids[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{family}{kw} decode step t={t}")


def test_sliding_window_decode_ring_buffer():
    """With window < prompt length the ring cache must still be exact."""
    cfg = tiny("dense", sliding_window=6)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S, S0 = 1, 20, 10
    ids = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full_logits, _ = m.forward(params, ids)
    logits, cache = m.prefill(params, ids[:, :S0], max_len=S)
    assert cache["k"].shape[2] == 6     # O(window) cache, not O(S)
    for t in range(S0, S):
        logits, cache = m.decode_step(params, cache, ids[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, t]),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"t={t}")


# -- MoE invariants -------------------------------------------------------------
def test_moe_routing_weights_normalized():
    cfg = tiny("moe", num_experts=8, top_k=2, d_model=64, d_ff=32)
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    out, aux = L.moe(p, cfg, x, group_size=16)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3    # aux loss lower bound is 1 (balanced)
    assert not bool(jnp.isnan(out).any())


def test_moe_capacity_drops_tokens_gracefully():
    from dataclasses import replace
    cfg = replace(tiny("moe", num_experts=4, top_k=2, d_model=64, d_ff=32),
                  capacity_factor=0.25)   # aggressively small capacity
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 64))
    out, _ = L.moe(p, cfg, x, group_size=32)
    assert not bool(jnp.isnan(out).any())


# -- attention variants -----------------------------------------------------------
def test_gqa_equals_mha_when_groups_1():
    """num_kv_heads == num_heads degenerates to standard MHA."""
    cfg = tiny("dense", num_heads=4, num_kv_heads=4)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    ids = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    logits, _ = m.forward(p, ids)
    assert logits.shape == (2, 8, cfg.vocab_size)


def test_causality():
    """Perturbing a future token must not change past logits."""
    cfg = tiny("dense")
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    ids = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    l1, _ = m.forward(p, ids)
    ids2 = ids.at[0, 8].set((ids[0, 8] + 1) % cfg.vocab_size)
    l2, _ = m.forward(p, ids2)
    np.testing.assert_allclose(np.asarray(l1[0, :8]), np.asarray(l2[0, :8]),
                               rtol=1e-5, atol=1e-5)


def test_ssm_causality():
    cfg = tiny("ssm", num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=16,
               tie_embeddings=True)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    ids = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab_size)
    l1, _ = m.forward(p, ids)
    ids2 = ids.at[0, 12].set((ids[0, 12] + 1) % cfg.vocab_size)
    l2, _ = m.forward(p, ids2)
    np.testing.assert_allclose(np.asarray(l1[0, :12]), np.asarray(l2[0, :12]),
                               rtol=1e-4, atol=1e-4)


# -- grad flow -------------------------------------------------------------------
@pytest.mark.parametrize("family,kw", [
    ("dense", {}), ("moe", {"num_experts": 4, "top_k": 2}),
    ("ssm", {"num_heads": 0, "num_kv_heads": 0, "d_ff": 0, "ssm_state": 16,
             "tie_embeddings": True}),
    ("hybrid", {"ssm_state": 16, "attn_every": 2, "num_layers": 4}),
])
def test_grads_finite(family, kw):
    cfg = tiny(family, **kw)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    ids = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    g = jax.grad(lambda p: m.loss(p, {"tokens": ids, "labels": ids}))(p)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf).all())


def test_whisper_loss_and_shapes():
    cfg = ArchConfig("w", "audio", 4, 384, 6, 6, 1536, 51865, rope_theta=0.0,
                     tie_embeddings=True, enc_layers=4).reduced()
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {"frames": jax.random.normal(jax.random.PRNGKey(1),
                                         (B, cfg.enc_frames, cfg.d_model)),
             "tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    logits, _ = m.forward(p, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(m.loss(p, batch)))


def test_internvl_loss_and_shapes():
    cfg = ArchConfig("v", "vlm", 48, 6144, 48, 8, 16384, 92553).reduced()
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {"vis": jax.random.normal(jax.random.PRNGKey(1),
                                      (B, cfg.vis_tokens, D_VIS)),
             "tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    logits, _ = m.forward(p, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(m.loss(p, batch)))
