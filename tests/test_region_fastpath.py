"""Deterministic (hypothesis-free) regression tests for the region fast paths.

The region algebra grew bounding-box prefilters, a trusted-disjoint
constructor and a sort-and-sweep merge; this file pits those fast paths
against the same brute-force bitmap oracle the hypothesis suite uses, but
with a seeded PRNG so it always runs, even without optional deps.
"""

import random

import numpy as np
import pytest

from repro.core.region import (Box, Region, RegionMap, _merge_adjacent,
                               split_box)

BOUND = 12


def bitmap(r: Region, rank: int) -> np.ndarray:
    grid = np.zeros((BOUND,) * rank, dtype=bool)
    for b in r.boxes:
        sl = tuple(slice(max(0, a), min(BOUND, c)) for a, c in zip(b.min, b.max))
        grid[sl] = True
    return grid


def rand_box(rng: random.Random, rank: int) -> Box:
    lo_hi = [(rng.randint(0, BOUND), rng.randint(0, BOUND)) for _ in range(rank)]
    return Box(tuple(min(a, b) for a, b in lo_hi),
               tuple(max(a, b) for a, b in lo_hi))


def rand_region(rng: random.Random, rank: int, max_boxes: int = 4) -> Region:
    return Region([rand_box(rng, rank) for _ in range(rng.randint(0, max_boxes))])


@pytest.mark.parametrize("rank", [1, 2, 3])
@pytest.mark.parametrize("seed", [0, 1, 2])
class TestRegionOracle:
    N_CASES = 60

    def _pairs(self, rank, seed):
        rng = random.Random(1000 * rank + seed)
        for _ in range(self.N_CASES):
            yield rand_region(rng, rank), rand_region(rng, rank)

    def test_union(self, rank, seed):
        for a, b in self._pairs(rank, seed):
            assert np.array_equal(bitmap(a.union(b), rank),
                                  bitmap(a, rank) | bitmap(b, rank))

    def test_intersect(self, rank, seed):
        for a, b in self._pairs(rank, seed):
            assert np.array_equal(bitmap(a.intersect(b), rank),
                                  bitmap(a, rank) & bitmap(b, rank))

    def test_difference(self, rank, seed):
        for a, b in self._pairs(rank, seed):
            assert np.array_equal(bitmap(a.difference(b), rank),
                                  bitmap(a, rank) & ~bitmap(b, rank))

    def test_results_stay_disjoint(self, rank, seed):
        """Trusted-constructor outputs must preserve the disjoint invariant."""
        for a, b in self._pairs(rank, seed):
            for r in (a.union(b), a.intersect(b), a.difference(b)):
                for i, x in enumerate(r.boxes):
                    assert not x.empty()
                    for y in r.boxes[i + 1:]:
                        assert not x.overlaps(y), f"{x} overlaps {y} in {r}"
                assert r.volume() == int(bitmap(r, rank).sum())

    def test_contains_and_eq(self, rank, seed):
        for a, b in self._pairs(rank, seed):
            assert a.contains(b) == bool(
                (bitmap(b, rank) & ~bitmap(a, rank)).sum() == 0)
            assert (a == b) == np.array_equal(bitmap(a, rank), bitmap(b, rank))
            if a == b:
                assert hash(a) == hash(b)

    def test_contains_box(self, rank, seed):
        rng = random.Random(7000 * rank + seed)
        for _ in range(self.N_CASES):
            a, b = rand_region(rng, rank), rand_box(rng, rank)
            want = bool((bitmap(Region.from_box(b), rank)
                         & ~bitmap(a, rank)).sum() == 0)
            assert a.contains_box(b) == want

    def test_intersect_box(self, rank, seed):
        rng = random.Random(9000 * rank + seed)
        for _ in range(self.N_CASES):
            a, b = rand_region(rng, rank), rand_box(rng, rank)
            assert np.array_equal(
                bitmap(a.intersect_box(b), rank),
                bitmap(a, rank) & bitmap(Region.from_box(b), rank))


def test_from_disjoint_trusts_caller():
    """from_disjoint must not renormalize — box identity is preserved."""
    boxes = (Box((0, 0), (2, 2)), Box((5, 5), (7, 9)))
    r = Region.from_disjoint(boxes)
    assert r.boxes == boxes
    assert r.volume() == 4 + 8


def test_merge_adjacent_collapses_rows():
    rows = [Box((i, 0), (i + 1, 8)) for i in range(16)]
    random.Random(3).shuffle(rows)
    merged = _merge_adjacent(rows)
    assert merged == [Box((0, 0), (16, 8))]


def test_merge_adjacent_multi_axis_fixpoint():
    # 2x2 grid of unit boxes: merging along one axis enables the other
    quads = [Box((i, j), (i + 1, j + 1)) for i in range(2) for j in range(2)]
    assert _merge_adjacent(quads) == [Box((0, 0), (2, 2))]


def test_empty_region_singleton_and_bbox_cache():
    assert Region.empty() is Region.empty()
    r = Region([Box((0, 1), (4, 5)), Box((8, 1), (9, 5))])
    assert r.bounding_box() == Box((0, 1), (9, 5))
    assert r.bounding_box() is r.bounding_box()      # cached


def test_region_map_oracle():
    """RegionMap.update must behave like painting on a grid."""
    rng = random.Random(42)
    for _ in range(40):
        bounds = Box((0, 0), (BOUND, BOUND))
        rm = RegionMap(bounds, default=0)
        grid = np.zeros((BOUND, BOUND), dtype=int)
        for val in range(1, rng.randint(2, 7)):
            r = rand_region(rng, 2)
            rm.update(r, val)
            grid[bitmap(r, 2)] = val
        for sub, v in rm.query(Region.from_box(bounds)):
            for b in sub.boxes:
                sl = tuple(slice(a, c) for a, c in zip(b.min, b.max))
                assert (grid[sl] == v).all(), f"value mismatch in {b}"
        # entries stay disjoint and cover exactly the painted area
        seen = Region.empty()
        for r, _ in rm.entries:
            assert not seen.overlaps(r)
            seen = seen.union(r)
        assert seen == Region.from_box(bounds)
        # covered() equals the union of entries
        assert rm.covered() == seen


def test_region_map_query_prefilter_misses_nothing():
    """Sorted bbox index: querying a narrow strip sees exactly the overlap."""
    bounds = Box((0,), (100,))
    rm = RegionMap(bounds)
    for i in range(10):
        rm.update(Region.from_box(Box((10 * i,), (10 * i + 5,))), i)
    got = rm.query(Region.from_box(Box((12,), (48,))))
    vals = sorted(v for _, v in got)
    assert vals == [1, 2, 3, 4]
    assert all(not sub.is_empty() for sub, _ in got)


def test_region_map_coalesce_merges_values():
    bounds = Box((0,), (16,))
    rm = RegionMap(bounds, default="a")
    rm.update(Region.from_box(Box((4,), (8,))), "b")
    rm.update(Region.from_box(Box((8,), (12,))), "b")
    rm.coalesce()
    assert len(rm.entries) == 2
    by_val = {v: r for r, v in rm.entries}
    assert by_val["b"] == Region.from_box(Box((4,), (12,)))
    assert by_val["a"].volume() == 8


def test_split_box_partition_deterministic():
    for extent, chunks, gran in [(64, 16, 4), (7, 3, 2), (1, 4, 1), (33, 8, 3)]:
        box = Box((0, 0), (extent, 5))
        parts = split_box(box, chunks, dims=(0,), granularity=(gran,))
        assert Region(parts) == Region.from_box(box)
        assert sum(p.volume() for p in parts) == box.volume()
        assert len(parts) <= chunks
        for p in parts[:-1]:
            assert (p.max[0] - p.min[0]) % gran == 0
